"""Pallas TPU kernels for the data-movement hot spots.

Per-kernel modules hold ``pl.pallas_call`` + BlockSpec tiling; ``ref.py``
holds the pure-jnp oracles; ``ops.py`` is the public jit-able API with
backend dispatch.  Validated in interpret mode on CPU (tests/test_kernels).
"""

from jax.experimental.pallas import tpu as _pltpu


def tpu_compiler_params(**kwargs):
    """Version-portable ``pallas_call`` compiler params.

    The class was renamed ``TPUCompilerParams`` -> ``CompilerParams`` across
    jax releases; resolve whichever this install provides.
    """
    cls = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams
    return cls(**kwargs)


# tpu_compiler_params must be bound before the kernel modules import it
# back from this package (ops -> per-kernel modules -> here).
from repro.kernels import ops  # noqa: E402,F401
from repro.kernels.ref import NEG_INF  # noqa: E402,F401
