"""Pallas TPU kernels for the data-movement hot spots.

Per-kernel modules hold ``pl.pallas_call`` + BlockSpec tiling; ``ref.py``
holds the pure-jnp oracles; ``ops.py`` is the public jit-able API with
backend dispatch.  Validated in interpret mode on CPU (tests/test_kernels).
"""

from repro.kernels import ops  # noqa: F401
from repro.kernels.ref import NEG_INF  # noqa: F401
