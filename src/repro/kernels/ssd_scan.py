"""Mamba-2 SSD (state-space duality) chunked-scan kernel (Pallas, TPU).

The SSD algorithm (arXiv:2405.21060) is itself a data-movement argument of
the kind the paper makes: the same recurrence can be evaluated as a
sequential scan (latency-bound, no MXU) or as chunked quadratic blocks
(MXU-friendly, VMEM-resident tiles) plus a tiny inter-chunk state
recurrence.  This kernel implements the chunked form with the chunk loop as
the *sequential* grid axis, carrying the (P, N) state in VMEM scratch —
HBM traffic is exactly one read of x/dt/B/C and one write of y.

Grid: (batch, heads, chunks); chunks is ``arbitrary`` (sequential).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

DEFAULT_CHUNK = 64


def _ssd_kernel(
    x_ref,    # (1, c, 1, P)
    dt_ref,   # (1, c, 1)
    a_ref,    # (1,)
    b_ref,    # (1, c, N)
    c_ref,    # (1, c, N)
    y_ref,    # (1, c, 1, P)
    h_scr,    # (P, N) f32 state
    *, chunk,
):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (c,)
    A = a_ref[0].astype(jnp.float32)                 # scalar
    Bm = b_ref[0].astype(jnp.float32)                # (c, N)
    Cm = c_ref[0].astype(jnp.float32)                # (c, N)

    a = A * dt                                       # (c,) log-decays
    cum = jnp.cumsum(a)                              # inclusive
    li = cum[:, None]
    lj = cum[None, :]
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    L = jnp.where(mask, jnp.exp(li - lj), 0.0)       # (c, c)

    G = jax.lax.dot_general(                         # C_i . B_j
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    M = G * L                                        # (c, c)
    xdt = x * dt[:, None]                            # (c, P)
    y_intra = jax.lax.dot_general(
        M, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (c, P)

    # inter-chunk: y_inter[i] = exp(cum_i) * C_i . h_prev
    h_prev = h_scr[...]                              # (P, N)
    ch = jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (c, P)
    y = y_intra + jnp.exp(cum)[:, None] * ch
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: h = exp(cum_end) * h_prev + sum_j decay_to_end_j dt_j x_j B_j
    decay_to_end = jnp.exp(cum[-1] - cum)            # (c,)
    sx = xdt * decay_to_end[:, None]                 # (c, P)
    add = jax.lax.dot_general(                       # (P, N)
        sx, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h_scr[...] = h_prev * jnp.exp(cum[-1]) + add


def ssd_scan(
    x: jax.Array,     # (B, T, H, P)
    dt: jax.Array,    # (B, T, H)
    A: jax.Array,     # (H,)
    Bmat: jax.Array,  # (B, T, N)
    Cmat: jax.Array,  # (B, T, N)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = True,
) -> jax.Array:
    Bsz, T, H, P = x.shape
    N = Bmat.shape[-1]
    c = min(chunk, T)
    assert T % c == 0, (T, c)
    nchunks = T // c
    grid = (Bsz, H, nchunks)

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, 1, P), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, c, 1), lambda b, h, i: (b, i, h)),
            pl.BlockSpec((1,), lambda b, h, i: (h,)),
            pl.BlockSpec((1, c, N), lambda b, h, i: (b, i, 0)),
            pl.BlockSpec((1, c, N), lambda b, h, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, 1, P), lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, T, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A, Bmat, Cmat)
