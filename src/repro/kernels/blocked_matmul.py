"""Tiled GEMM (Pallas, TPU): the paper's GEMM study at the VMEM tier.

Paper Figs. 15-16 show one GEMM flipping between compute- and memory-bound
purely as a function of where its operands live.  On a TPU chip the same
experiment exists one tier down: the BlockSpec *is* the placement decision.
With (bm, bn, bk) tiles, HBM traffic per output tile is
``bm·bk + bk·bn`` reads amortized over ``2·bm·bn·bk`` FLOPs — arithmetic
intensity grows with tile size until the working set
``(bm·bk + bk·bn + bm·bn·2)`` no longer fits VMEM.  ``traffic_model``
exposes this analytically; bench_gemm sweeps it.

Grid: (M/bm, N/bn, K/bk), K sequential with an f32 VMEM accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _mm_kernel(a_ref, b_ref, o_ref, acc_scr, *, out_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_idx == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_scr[...].astype(out_dtype)


def blocked_matmul(
    a: jax.Array,   # (M, K)
    b: jax.Array,   # (K, N)
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    out_dtype = out_dtype or a.dtype

    return pl.pallas_call(
        functools.partial(_mm_kernel, out_dtype=out_dtype),
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)


def traffic_model(
    M: int, N: int, K: int, bm: int, bn: int, bk: int, itemsize: int = 2
) -> dict[str, float]:
    """Analytic HBM traffic + VMEM footprint of the tiling.

    Every A tile is read N/bn times, every B tile M/bm times — the
    'how many times does each byte cross the bus' question is the paper's
    central one, answered for the on-chip datapath.
    """
    a_reads = M * K * (N // bn)
    b_reads = K * N * (M // bm)
    c_writes = M * N
    vmem = (bm * bk + bk * bn) * itemsize + bm * bn * 4 + bm * bn * itemsize
    flops = 2.0 * M * N * K
    traffic = (a_reads + b_reads + c_writes) * itemsize
    return {
        "hbm_bytes": float(traffic),
        "vmem_bytes": float(vmem),
        "flops": flops,
        "arithmetic_intensity": flops / traffic,
    }


def best_tiling(
    M: int, N: int, K: int,
    vmem_budget: int = 96 * 2**20,
    itemsize: int = 2,
    candidates=(128, 256, 512, 1024),
) -> tuple[int, int, int]:
    """Pick the tiling with max arithmetic intensity that fits VMEM."""
    best = None
    for bm in candidates:
        for bn in candidates:
            for bk in candidates:
                if M % bm or N % bn or K % bk:
                    continue
                t = traffic_model(M, N, K, bm, bn, bk, itemsize)
                if t["vmem_bytes"] > vmem_budget:
                    continue
                key = (t["arithmetic_intensity"], -t["vmem_bytes"])
                if best is None or key > best[0]:
                    best = (key, (bm, bn, bk))
    return best[1] if best else (min(128, M), min(128, N), min(128, K))
