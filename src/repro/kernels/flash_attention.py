"""Blocked flash attention (Pallas, TPU target).

TPU adaptation of the FlashAttention idea through the paper's lens: the
datapath that matters on-chip is HBM→VMEM.  A naive attention materializes
the (Sq, Sk) score matrix in HBM — `2·Sq·Sk·2B` of traffic per head; the
blocked kernel keeps a (bq, bk) tile plus the running (m, l, acc) statistics
in VMEM, so HBM traffic drops to the Q/K/V/O tensors themselves.  BlockSpec
shapes are the on-chip placement policy: bq/bk are chosen so
``(bq + 2·bk)·D·2B + bq·bk·4B`` fits VMEM with MXU-aligned dims
(multiples of 128).

Supports the mask kinds of the assigned architectures (causal, sliding
window, chunked, bidirectional) and GQA via q-head grouping; fully-masked
KV blocks are *compute-skipped* with ``pl.when`` (the TPU analogue of not
launching the CUDA block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params
from repro.kernels.ref import NEG_INF

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _block_reachable(kind: str, window: int, chunk: int,
                     q_lo, q_hi, k_lo, k_hi):
    """Static/traced predicate: can *any* (q, k) pair in this tile attend?

    q in [q_lo, q_hi), k in [k_lo, k_hi).  Used for compute-skipping.
    """
    if kind == "bidirectional":
        return True
    causal_ok = q_hi - 1 >= k_lo
    if kind == "causal":
        return causal_ok
    if kind == "sliding":
        # need q - k < window for some pair: min over tile of (q-k) is
        # q_lo - (k_hi-1); also q >= k possible.
        return jnp.logical_and(causal_ok, (q_hi - 1) - k_lo >= 0) & (
            (k_hi - 1) >= q_lo - window + 1
        )
    if kind == "chunked":
        return jnp.logical_and(causal_ok, q_lo // chunk <= (k_hi - 1) // chunk) & (
            (q_hi - 1) // chunk >= k_lo // chunk
        )
    raise ValueError(kind)


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bq, bk, scale, kind, window, chunk, q_offset,
):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = q_offset + q_idx * bq
    k_lo = kv_idx * bk

    @pl.when(
        _block_reachable(kind, window, chunk, q_lo, q_lo + bq, k_lo, k_lo + bk)
    )
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bq, bk)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if kind == "bidirectional":
            mask = jnp.ones((bq, bk), bool)
        else:
            mask = q_pos >= k_pos
            if kind == "sliding":
                mask &= (q_pos - k_pos) < window
            elif kind == "chunked":
                mask &= (q_pos // chunk) == (k_pos // chunk)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)   # fully-masked rows -> 0 output
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,      # (B, Hq, Sq, D)
    k: jax.Array,      # (B, Hkv, Sk, D)
    v: jax.Array,      # (B, Hkv, Sk, D)
    *,
    kind: str = "causal",
    window: int = 0,
    chunk: int = 0,
    scale: float | None = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Pallas flash attention. GQA handled by repeating KV heads blockwise."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale = (D ** -0.5) if scale is None else scale

    # collapse (B, Hq) into one parallel grid axis; map each q-head block
    # to its kv head: h_kv = h_q // G.
    qf = q.reshape(B * Hq, Sq, D)
    grid = (B * Hq, Sq // bq, Sk // bk)

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        b = bh // Hq
        hkv = (bh % Hq) // G
        return (b * Hkv + hkv, j, 0)

    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel,
            bq=bq, bk=bk, scale=scale, kind=kind,
            window=window, chunk=chunk, q_offset=q_offset,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), q_map),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, D)


def _prefill_kernel(
    q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bq, bk, scale, kind, window, chunk,
):
    """Chunked-prefill attention: causal within chunk, full vs prior cache.

    Same online-softmax loop as ``_fa_kernel``, but positions come from the
    prefetched ``qpos``/``kpos`` tensors instead of iota — the KV axis is
    the concatenation [prior cache slots ++ chunk keys], where cache slots
    carry a recovered absolute position (ring caches wrap, every batch row
    sits at its own offset) and ``kpos < 0`` marks holes (unwritten tail,
    padding past this row's ``new_lens``).
    """
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qp = qpos_ref[0]                                 # (bq,) int32
    kp = kpos_ref[0]                                 # (bk,) int32
    mask = (qp[:, None] >= kp[None, :]) & (kp[None, :] >= 0)
    if kind == "sliding":
        mask &= (qp[:, None] - kp[None, :]) < window
    elif kind == "chunked":
        mask &= (qp[:, None] // chunk) == (kp[None, :] // chunk)

    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_prefill(
    q: jax.Array,       # (B, Hq, Sq, D) chunk queries
    k: jax.Array,       # (B, Hkv, Sk, D) prior cache ++ chunk keys
    v: jax.Array,       # (B, Hkv, Sk, D)
    q_pos: jax.Array,   # (B, Sq) int32 absolute query positions
    k_pos: jax.Array,   # (B, Sk) int32 absolute key positions; < 0 = hole
    *,
    kind: str = "causal",
    window: int = 0,
    chunk: int = 0,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Pallas chunked-prefill attention (ref: ``ref.prefill_attention``).

    One HBM pass over the prior cache per chunk instead of one per token —
    the kernel-level half of the serve engine's batched prefill.  The KV
    axis is padded up to a block multiple with ``k_pos = -1`` holes, which
    the mask (and the fully-masked-block compute skip) eliminates.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    bq = min(block_q, Sq)
    if Sq % bq:
        bq = Sq
    bk = min(block_k, Sk)
    pad = (-Sk) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        Sk += pad

    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)
    grid = (B * Hq, Sq // bq, Sk // bk)

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        return ((bh // Hq) * Hkv + (bh % Hq) // G, j, 0)

    def qpos_map(bh, i, j):
        return (bh // Hq, i)

    def kpos_map(bh, i, j):
        return (bh // Hq, j)

    out = pl.pallas_call(
        functools.partial(
            _prefill_kernel,
            bq=bq, bk=bk, scale=scale, kind=kind, window=window, chunk=chunk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), q_map),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bq), qpos_map),
            pl.BlockSpec((1, bk), kpos_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf, q_pos.astype(jnp.int32), k_pos.astype(jnp.int32))
    return out.reshape(B, Hq, Sq, D)


def vmem_footprint_bytes(bq: int, bk: int, d: int, itemsize: int = 2) -> int:
    """Predicted VMEM working set of one grid step (for tiling choices)."""
    tiles = (bq * d + 2 * bk * d) * itemsize      # q, k, v tiles
    scores = bq * bk * 4                          # f32 scores
    stats = (2 * bq + bq * d) * 4                 # m, l, acc
    out = bq * d * itemsize
    return tiles + scores + stats + out
