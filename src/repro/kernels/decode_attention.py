"""Flash-decode (split-K) attention kernel for single-token decode.

The decode shapes (``decode_32k``, ``long_500k``) are the paper's Fig. 17
regime: one token's worth of compute against a huge read-mostly buffer —
pure data movement.  Arithmetic intensity is ~1 FLOP/byte, so the *only*
lever is keeping the KV read stream at full HBM bandwidth; this kernel
streams the cache through VMEM in ``block_k`` tiles, carrying the online
softmax statistics in scratch, with all ``G = Hq/Hkv`` query heads of a KV
head processed per tile (the KV tile is read ONCE for all of them — the
kernel-level expression of the paper's "reads dominate" GEMM finding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params
from repro.kernels.ref import NEG_INF

DEFAULT_BLOCK_K = 512


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bk, scale,
):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[pl.program_id(0)]   # whole (B,) vector lives in SMEM
    k_lo = kv_idx * bk

    @pl.when(k_lo < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (G, bk)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,        # (B, Hq, D) — one new token per row
    k_cache: jax.Array,  # (B, Hkv, Smax, D)
    v_cache: jax.Array,  # (B, Hkv, Smax, D)
    lengths: jax.Array,  # (B,) int32 valid lengths
    *,
    scale: float | None = None,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    B, Hq, D = q.shape
    _, Hkv, Smax, _ = k_cache.shape
    G = Hq // Hkv
    bk = min(block_k, Smax)
    assert Smax % bk == 0, (Smax, bk)
    scale = (D ** -0.5) if scale is None else scale

    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, Smax // bk)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # lengths
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, Hq, D)
