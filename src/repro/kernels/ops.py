"""Public jit'd wrappers over the Pallas kernels with ref dispatch.

The model zoo calls these.  ``backend='ref'`` (default) runs the pure-jnp
oracle — the path the multi-pod dry-run lowers (Pallas custom-calls carry
no cost signal for the CPU-hosted roofline, and interpret mode is slow).
``backend='pallas'`` runs the TPU-targeted kernels (interpret=True on CPU);
tests sweep both and assert allclose.

Training gradients: when the Pallas forward is selected, attention ops are
wrapped in ``jax.custom_vjp`` whose backward *recomputes* with the oracle —
numerically exact, flash-style-memory only in forward.  (A Pallas backward
kernel is a further optimization documented in EXPERIMENTS.md §Perf.)
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import blocked_matmul as _bm
from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref

Backend = Literal["ref", "pallas"]

_DEFAULT: Backend = "ref"


def set_default_backend(backend: Backend) -> None:
    global _DEFAULT
    assert backend in ("ref", "pallas")
    _DEFAULT = backend


def get_default_backend() -> Backend:
    return _DEFAULT


def _resolve(backend: Backend | None) -> Backend:
    return backend or _DEFAULT


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def _pallas_attention(q, k, v, kind, window, chunk, scale, q_offset):
    return _fa.flash_attention(
        q, k, v, kind=kind, window=window, chunk=chunk,
        scale=scale, q_offset=q_offset,
    )


def _pallas_attention_fwd(q, k, v, kind, window, chunk, scale, q_offset):
    out = _pallas_attention(q, k, v, kind, window, chunk, scale, q_offset)
    return out, (q, k, v)


def _pallas_attention_bwd(kind, window, chunk, scale, q_offset, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.attention(
            q_, k_, v_, kind=kind, window=window, chunk=chunk,
            scale=scale, q_offset=q_offset,
        ),
        q, k, v,
    )
    return vjp(g)


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


def attention(
    q, k, v, *,
    kind: str = "causal",
    window: int = 0,
    chunk: int = 0,
    scale: float | None = None,
    q_offset: int = 0,
    k_lengths=None,
    backend: Backend | None = None,
):
    """(B, Hq, Sq, D) x (B, Hkv, Sk, D) GQA attention with mask kinds."""
    if _resolve(backend) == "pallas" and k_lengths is None:
        return _pallas_attention(q, k, v, kind, window, chunk, scale, q_offset)
    if k_lengths is None and q.shape[2] >= 2048:
        # long sequences: flash-style chunked evaluation (memory O(S·bq))
        return _ref.attention_chunked(
            q, k, v, kind=kind, window=window, chunk=chunk,
            scale=scale, q_offset=q_offset,
        )
    return _ref.attention(
        q, k, v, kind=kind, window=window, chunk=chunk,
        scale=scale, q_offset=q_offset, k_lengths=k_lengths,
    )


def decode_attention(
    q, k_cache, v_cache, lengths, *,
    scale: float | None = None,
    backend: Backend | None = None,
):
    """(B, Hq, D) single-token decode against a padded KV cache."""
    if _resolve(backend) == "pallas":
        return _da.flash_decode(q, k_cache, v_cache, lengths, scale=scale)
    return _ref.decode_attention(q, k_cache, v_cache, lengths, scale=scale)


def prefill_attention(
    q, k, v, q_pos, k_pos, *,
    kind: str = "causal",
    window: int = 0,
    chunk: int = 0,
    scale: float | None = None,
    backend: Backend | None = None,
):
    """(B, Hq, Sq, D) chunk queries vs (B, Hkv, Sk, D) [cache ++ chunk] keys.

    Position-tensor masked attention for the serving engine's chunked
    batched prefill: causal within the chunk, full (windowed / chunk-local)
    against the prior cache, ``k_pos < 0`` slots masked out.  Inference
    only — no VJP is registered for the Pallas path.
    """
    if _resolve(backend) == "pallas":
        return _fa.flash_prefill(
            q, k, v, q_pos, k_pos,
            kind=kind, window=window, chunk=chunk, scale=scale,
        )
    return _ref.prefill_attention(
        q, k, v, q_pos, k_pos,
        kind=kind, window=window, chunk=chunk, scale=scale,
    )


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _pallas_ssd(x, dt, A, Bmat, Cmat, chunk):
    return _ssd_pallas_fwd_only(x, dt, A, Bmat, Cmat, chunk)


def _ssd_pallas_fwd_only(x, dt, A, Bmat, Cmat, chunk):
    from repro.kernels.ssd_scan import ssd_scan as _k

    return _k(x, dt, A, Bmat, Cmat, chunk=chunk)


def _pallas_ssd_fwd(x, dt, A, Bmat, Cmat, chunk):
    return _pallas_ssd(x, dt, A, Bmat, Cmat, chunk), (x, dt, A, Bmat, Cmat)


def _pallas_ssd_bwd(chunk, res, g):
    x, dt, A, Bmat, Cmat = res
    _, vjp = jax.vjp(
        lambda *a: _ref.ssd_scan(*a, chunk=chunk), x, dt, A, Bmat, Cmat
    )
    return vjp(g)


_pallas_ssd.defvjp(_pallas_ssd_fwd, _pallas_ssd_bwd)


def ssd_scan(
    x, dt, A, Bmat, Cmat, *,
    chunk: int = 64,
    init_state=None,
    return_state: bool = False,
    backend: Backend | None = None,
):
    if (
        _resolve(backend) == "pallas"
        and init_state is None
        and not return_state
    ):
        return _pallas_ssd(x, dt, A, Bmat, Cmat, chunk)
    return _ref.ssd_scan(
        x, dt, A, Bmat, Cmat, chunk=chunk,
        init_state=init_state, return_state=return_state,
    )


def ssd_decode_step(x, dt, A, Bvec, Cvec, state):
    return _ref.ssd_decode_step(x, dt, A, Bvec, Cvec, state)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

def matmul(
    a, b, *,
    out_dtype=None,
    bm: int = _bm.DEFAULT_BM,
    bn: int = _bm.DEFAULT_BN,
    bk: int = _bm.DEFAULT_BK,
    backend: Backend | None = None,
):
    if _resolve(backend) == "pallas":
        return _bm.blocked_matmul(a, b, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype)
    return _ref.matmul(a, b, out_dtype=out_dtype)
