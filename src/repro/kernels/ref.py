"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references (``assert_allclose`` targets in
tests/test_kernels.py) *and* the default compute path of the model zoo:
the dry-run lowers these — they express identical math and sharding, so the
roofline derived from them is the roofline of the algorithm, while the
Pallas kernels express the VMEM-tiled TPU implementation of the same ops.

All attention references compute softmax in f32 regardless of input dtype
(matching the kernels) and support the mask kinds used by the assigned
architectures:

* ``causal``             — standard decoder mask
* ``sliding``            — causal ∧ (q - k < window)        [gemma3 local]
* ``chunked``            — causal ∧ same-chunk(q, k)        [llama4 local]
* ``bidirectional``      — none                              [encoders]
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

MaskKind = Literal["causal", "sliding", "chunked", "bidirectional"]

NEG_INF = -1e30  # large-negative instead of -inf: keeps fully-masked rows
                 # (sliding windows near t=0, padded decode) NaN-free.


def mask_fn(
    kind: MaskKind,
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    window: int = 0,
    chunk: int = 0,
) -> jax.Array:
    """Boolean mask (True = attend) for positions q_pos x k_pos."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    if kind == "bidirectional":
        return jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    causal = q >= k
    if kind == "causal":
        return causal
    if kind == "sliding":
        return causal & (q - k < window)
    if kind == "chunked":
        return causal & (q // chunk == k // chunk)
    raise ValueError(f"unknown mask kind {kind!r}")


def attention(
    q: jax.Array,          # (B, Hq, Sq, D)
    k: jax.Array,          # (B, Hkv, Sk, D)
    v: jax.Array,          # (B, Hkv, Sk, Dv)
    *,
    kind: MaskKind = "causal",
    window: int = 0,
    chunk: int = 0,
    scale: float | None = None,
    q_offset: int | jax.Array = 0,
    k_lengths: jax.Array | None = None,  # (B,) valid KV length (decode)
) -> jax.Array:
    """Grouped-query attention oracle.

    ``q_offset`` places the query block inside the global position space
    (prefill chunk / decode step).  ``k_lengths`` masks cache tail slots.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * scale

    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    m = mask_fn(kind, q_pos, k_pos, window=window, chunk=chunk)
    if k_lengths is not None:
        valid = k_pos[None, :] < k_lengths[:, None]           # (B, Sk)
        m = m[None, :, :] & valid[:, None, :]
        m = m[:, None, None]                                   # (B,1,1,Sq,Sk)
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, Hq, Sq, Dv).astype(q.dtype)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    kind: MaskKind = "causal",
    window: int = 0,
    chunk: int = 0,
    scale: float | None = None,
    q_offset: int = 0,
    block_q: int = 512,
) -> jax.Array:
    """Flash-style memory profile in pure jnp: map over query blocks.

    Identical math to :func:`attention`; peak live intermediate is one
    (B, H, block_q, Sk) score block instead of the full (Sq, Sk) matrix —
    the jnp expression of the kernel's HBM→VMEM tiling, used by the model
    zoo for long sequences so the dry-run's memory analysis reflects a
    production attention, not a naive one.
    """
    B, Hq, Sq, D = q.shape
    from repro.models.sharding import current_mesh, current_rules
    from repro.models.sharding import shard as _shard

    # Heads that don't divide the TP axis (llama4: 40 vs 16) leave the
    # score tensors sharded by batch only; shrink the q block so the live
    # (B_local, Hq, bq, Sk) f32 block stays ~1 GiB — the same working-set
    # reasoning as the Pallas BlockSpec, applied to the jnp expression.
    # K/V are RE-READ once per q block, so bq is a peak-memory vs
    # HBM-traffic dial (exactly the Pallas block_q trade) — overridable
    # per run via rules["attn_block_q"] (§Perf llama4 iterations).
    override = current_rules().get("attn_block_q")
    if override:
        block_q = int(override)
    else:
        mesh = current_mesh()
        tp = dict(mesh.shape).get("model", 1) if mesh else 1
        if tp > 1 and Hq % tp:
            block_q = max(64, block_q // 4)
    bq = min(block_q, Sq)
    assert Sq % bq == 0, (Sq, bq)
    nblocks = Sq // bq

    @jax.checkpoint
    def one(i):
        # rematerialized per chunk in the backward (flash-bwd recompute):
        # without this the map saves all chunks' f32 probabilities at once
        # (observed 3 x 2 GiB/device on yi-6b train_4k).
        qi = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=2)
        # re-pin shardings: without the constraints XLA resolves the
        # slice-inside-scan by replicating q/out when heads don't divide
        # the TP axis (observed 20 GiB/device f32 gathers on llama4).
        qi = _shard(qi, "batch", "heads", None, "head_dim")
        out = attention(
            qi, k, v, kind=kind, window=window, chunk=chunk,
            scale=scale, q_offset=q_offset + i * bq,
        )
        return _shard(out, "batch", "heads", None, "head_dim")

    out = jax.lax.map(one, jnp.arange(nblocks))      # (nb, B, H, bq, Dv)
    out = jnp.moveaxis(out, 0, 2)                    # (B, H, nb, bq, Dv)
    return out.reshape(B, Hq, Sq, v.shape[-1])


def prefill_attention(
    q: jax.Array,          # (B, Hq, Sq, D) — one prefill chunk of queries
    k: jax.Array,          # (B, Hkv, Sk, D) — prior cache ++ chunk keys
    v: jax.Array,          # (B, Hkv, Sk, Dv)
    q_pos: jax.Array,      # (B, Sq) absolute position of each query
    k_pos: jax.Array,      # (B, Sk) absolute position of each key; < 0 = hole
    *,
    kind: MaskKind = "causal",
    window: int = 0,
    chunk: int = 0,
    scale: float | None = None,
) -> jax.Array:
    """Chunked-prefill oracle: per-tensor positions instead of iota.

    The serving engine's batched prefill attends one chunk of new queries
    against the concatenation of the existing KV cache and the chunk's own
    keys.  Cache slots don't carry their position implicitly (ring caches
    wrap; every batch row sits at a different fill offset), so positions
    arrive as explicit ``q_pos``/``k_pos`` tensors and masking happens on
    *absolute* positions: causal within the chunk, full (or windowed /
    chunk-local) against the prior cache.  ``k_pos < 0`` marks invalid
    slots (unwritten cache tail, per-row padding past ``new_lens``).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale

    qp = q_pos[:, :, None]                       # (B, Sq, 1)
    kp = k_pos[:, None, :]                       # (B, 1, Sk)
    m = (qp >= kp) & (kp >= 0)
    if kind == "sliding":
        m &= (qp - kp) < window
    elif kind == "chunked":
        m &= (qp // chunk) == (kp // chunk)
    elif kind not in ("causal",):
        raise ValueError(f"prefill mask kind {kind!r}")
    s = jnp.where(m[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, Dv).astype(q.dtype)


def decode_attention(
    q: jax.Array,          # (B, Hq, D) — one new token
    k_cache: jax.Array,    # (B, Hkv, Smax, D)
    v_cache: jax.Array,    # (B, Hkv, Smax, Dv)
    lengths: jax.Array,    # (B,) valid entries per batch row
    *,
    scale: float | None = None,
) -> jax.Array:
    """Single-token decode against a (possibly padded) KV cache."""
    out = attention(
        q[:, :, None, :],
        k_cache,
        v_cache,
        kind="bidirectional",
        scale=scale,
        k_lengths=lengths,
    )
    return out[:, :, 0, :]


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) — arXiv:2405.21060
# ---------------------------------------------------------------------------

def ssd_scan(
    x: jax.Array,     # (B, T, H, P)   inputs per head
    dt: jax.Array,    # (B, T, H)      softplus-activated step sizes
    A: jax.Array,     # (H,)           negative decay rates
    Bmat: jax.Array,  # (B, T, N)      input projections (shared across heads)
    Cmat: jax.Array,  # (B, T, N)      output projections
    *,
    chunk: int = 64,
    init_state: jax.Array | None = None,  # (B, H, P, N)
    return_state: bool = False,
):
    """Chunked SSD reference: O(T/c · c² + T·N) like the paper's algorithm.

    The recurrence (per head, per channel p, state n):
        h_t = exp(A·dt_t) · h_{t-1} + dt_t · B_t[n] · x_t[p]
        y_t = Σ_n C_t[n] · h_t[p,n]

    Chunked evaluation: intra-chunk term is a masked quadratic form
    (the "attention" dual); inter-chunk term carries the state.
    """
    Bsz, T, H, Pdim = x.shape
    N = Bmat.shape[-1]
    assert T % chunk == 0, (T, chunk)
    C_ = T // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)

    # reshape into chunks
    xc = xf.reshape(Bsz, C_, chunk, H, Pdim)
    dtc = dtf.reshape(Bsz, C_, chunk, H)
    Bc = Bf.reshape(Bsz, C_, chunk, N)
    Cc = Cf.reshape(Bsz, C_, chunk, N)

    # per-position log decay a_t = A * dt_t  (negative)
    a = Af[None, None, None, :] * dtc                     # (B,C,c,H)
    cum = jnp.cumsum(a, axis=2)                           # inclusive
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j  (decay j+1..i)
    li = cum[:, :, :, None, :]                            # i
    lj = cum[:, :, None, :, :]                            # j
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # double-where: masked entries have cum_i - cum_j > 0 and exp overflows;
    # clamping INSIDE keeps the cotangent finite (where alone does not).
    delta = jnp.where(mask, li - lj, 0.0)
    L = jnp.where(mask, jnp.exp(delta), 0.0)

    # scores G[i,j] = C_i · B_j
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)             # (B,C,c,c)
    M = G[..., None] * L                                  # (B,C,c,c,H)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", M, dtc, xc)

    # chunk summaries: state contribution of chunk k
    # S_k[h,p,n] = Σ_j exp(cum_last - cum_j) dt_j x_j[p] B_j[n]
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,C,c,H)
    S = jnp.einsum("bcjh,bcjh,bcjhp,bcjn->bchpn",
                   decay_to_end, dtc, xc, Bc)             # per-chunk state add
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,C,H) total decay

    # inter-chunk recurrence over C_ chunks (tiny sequential scan)
    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((Bsz, H, Pdim, N), jnp.float32)
    )

    def step(h, inputs):
        dec, add = inputs                                  # (B,H), (B,H,P,N)
        h_out = h                                          # state BEFORE chunk
        h_new = h * dec[:, :, None, None] + add
        return h_new, h_out

    dec_t = jnp.moveaxis(chunk_decay, 1, 0)                # (C,B,H)
    add_t = jnp.moveaxis(S, 1, 0)                          # (C,B,H,P,N)
    h_final, h_befores = jax.lax.scan(step, h0, (dec_t, add_t))
    h_befores = jnp.moveaxis(h_befores, 0, 1)              # (B,C,H,P,N)

    # inter-chunk output: y_inter[i] = C_i · (decay_0..i · h_before)
    decay_from_start = jnp.exp(cum)                        # (B,C,c,H)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cc, decay_from_start, h_befores)

    y = (y_intra + y_inter).reshape(Bsz, T, H, Pdim).astype(x.dtype)
    if return_state:
        return y, h_final.astype(jnp.float32)
    return y


def ssd_decode_step(
    x: jax.Array,      # (B, H, P)
    dt: jax.Array,     # (B, H)
    A: jax.Array,      # (H,)
    Bvec: jax.Array,   # (B, N)
    Cvec: jax.Array,   # (B, N)
    state: jax.Array,  # (B, H, P, N)
):
    """One recurrence step (decode path). Returns (y, new_state)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    dec = jnp.exp(A[None, :] * dtf)                        # (B,H)
    add = jnp.einsum("bh,bhp,bn->bhpn", dtf, xf, Bvec.astype(jnp.float32))
    new_state = state * dec[:, :, None, None] + add
    y = jnp.einsum("bn,bhpn->bhp", Cvec.astype(jnp.float32), new_state)
    return y.astype(x.dtype), new_state


def ssd_scan_sequential(
    x, dt, A, Bmat, Cmat, *, init_state=None
):
    """O(T) literal recurrence — the oracle's oracle (tests only)."""
    Bsz, T, H, Pdim = x.shape
    N = Bmat.shape[-1]
    h = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((Bsz, H, Pdim, N), jnp.float32)
    )

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        y, h = ssd_decode_step(xt, dtt, A, Bt, Ct, h)
        return h, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bmat, 1, 0),
        jnp.moveaxis(Cmat, 1, 0),
    )
    _, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1)


# ---------------------------------------------------------------------------
# Blocked matmul (the paper's GEMM study at the VMEM tier)
# ---------------------------------------------------------------------------

def matmul(a: jax.Array, b: jax.Array, *, out_dtype=None) -> jax.Array:
    """f32-accumulating matmul oracle."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)
