"""Layer 2: an ``ast`` lint framework encoding the aliasing discipline.

Successor to ``tools/check_deprecated.py``: instead of one grep script,
a registry of rules, each with its own allowlist, checked over the parsed
AST (semantic rules) or the raw source (migrated pattern rules).

Suppression:

* per-line — trailing ``# repro: lint-disable=<rule>[,<rule>...]`` on the
  offending line;
* per-file — the same pragma alone on a comment line anywhere in the file;
* per-rule allowlist — repo-relative paths baked into the rule (for the
  modules that *define* a deprecated shim, say).

Rule catalog (see docs/analysis.md):

* ``mutated-host-mirror-alias`` — ``jnp.asarray``/``np.asarray`` zero-copy
  construction from a buffer that the same class later mutates: the PR 2/3
  race shape (the device view aliases host memory on CPU backends, so the
  mutation changes data already handed to a dispatch).
* ``blocking-transfer-in-hot-path`` — ``.item()`` / ``np.asarray`` /
  ``np.array`` / ``jax.device_get`` inside serve step/decode code: each is
  a synchronous host↔device round trip on the once-per-token datapath.
* ``donate-without-out-shardings`` — ``donate_argnums`` without pinned
  ``out_shardings``: XLA is free to move the result, silently breaking the
  placement the planner priced.
* ``injected-fault-raise`` — raising the fault-injection harness's
  exception types (``TierLossError`` & co.) outside ``core/faults.py``:
  production control flow must not impersonate injected faults — the
  allowlist is the harness module itself, and ``tools/audit.py
  --selftest`` asserts it stays that narrow.
* ``cross-pool-device-put`` — raw ``device_put`` in a serve module:
  the disaggregated cluster's pools may only exchange data through the
  :mod:`repro.serve.handoff` bridge (which owns the ``donor_pod`` mesh
  and the crossing ledger); an ad-hoc ``device_put`` onto another
  pool's mesh would move KV without accounting or checksum coverage.
* ``deprecated-*`` — the migrated deprecation-hygiene patterns.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Iterable, Iterator

__all__ = [
    "LintViolation",
    "Rule",
    "PatternRule",
    "register",
    "registered_rules",
    "get_rule",
    "lint_source",
    "lint_file",
    "lint_repo",
    "SCAN_DIRS",
]

SCAN_DIRS = ("src", "tests", "examples", "benchmarks", "tools")

_PRAGMA_RE = re.compile(r"#\s*repro:\s*lint-disable=([\w\-,\s]+)")


@dataclasses.dataclass(frozen=True)
class LintViolation:
    rule: str
    path: str          # repo-relative posix path ("<string>" for lint_source)
    line: int
    message: str
    severity: str = "error"
    snippet: str = ""

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Rule:
    """One lint rule.  Subclasses implement :meth:`check`."""

    name: str = ""
    description: str = ""
    severity: str = "error"
    #: repo-relative paths exempt from this rule
    allow: frozenset[str] = frozenset()
    #: if set, only paths matching this regex are checked
    path_filter: re.Pattern | None = None

    def applies(self, relpath: str) -> bool:
        if relpath in self.allow:
            return False
        if self.path_filter is not None and not self.path_filter.search(relpath):
            return False
        return True

    def check(
        self, relpath: str, source: str, tree: ast.AST | None
    ) -> Iterable[LintViolation]:
        raise NotImplementedError

    def _violation(
        self, relpath: str, line: int, message: str, snippet: str = ""
    ) -> LintViolation:
        return LintViolation(
            rule=self.name,
            path=relpath,
            line=line,
            message=message,
            severity=self.severity,
            snippet=snippet,
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_RULES: dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    if not rule.name:
        raise ValueError("rule needs a name")
    if rule.name in _RULES:
        raise ValueError(f"duplicate lint rule {rule.name!r}")
    _RULES[rule.name] = rule
    return rule


def registered_rules() -> dict[str, Rule]:
    return dict(_RULES)


def get_rule(name: str) -> Rule:
    return _RULES[name]


# ---------------------------------------------------------------------------
# Pragma handling
# ---------------------------------------------------------------------------

def _parse_pragmas(source: str) -> tuple[set[str], dict[int, set[str]]]:
    """(file-level disabled rules, line -> disabled rules)."""
    file_level: set[str] = set()
    per_line: dict[int, set[str]] = {}
    for lineno, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        if line.strip().startswith("#"):
            file_level |= rules
        else:
            per_line.setdefault(lineno, set()).update(rules)
    return file_level, per_line


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def lint_source(
    source: str,
    relpath: str = "<string>",
    rules: Iterable[Rule] | None = None,
) -> list[LintViolation]:
    """Lint one source blob; pragma- and allowlist-filtered."""
    active = list(rules) if rules is not None else list(_RULES.values())
    file_off, line_off = _parse_pragmas(source)
    try:
        tree: ast.AST | None = ast.parse(source)
    except SyntaxError:
        tree = None
    out: list[LintViolation] = []
    for rule in active:
        if not rule.applies(relpath):
            continue
        if rule.name in file_off:
            continue
        for v in rule.check(relpath, source, tree):
            if rule.name in line_off.get(v.line, ()):
                continue
            out.append(v)
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def lint_file(
    path: pathlib.Path,
    root: pathlib.Path,
    rules: Iterable[Rule] | None = None,
) -> list[LintViolation]:
    rel = path.resolve().relative_to(root.resolve()).as_posix()
    return lint_source(path.read_text(), rel, rules)


def lint_repo(
    root: pathlib.Path,
    dirs: Iterable[str] = SCAN_DIRS,
    rules: Iterable[Rule] | None = None,
) -> list[LintViolation]:
    out: list[LintViolation] = []
    for top in dirs:
        base = root / top
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            out.extend(lint_file(path, root, rules))
    return out


# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------

def _call_name(node: ast.Call) -> str:
    """Dotted name of a call target (``jnp.asarray`` / ``float``)."""
    f = node.func
    parts: list[str] = []
    while isinstance(f, ast.Attribute):
        parts.append(f.attr)
        f = f.value
    if isinstance(f, ast.Name):
        parts.append(f.id)
    return ".".join(reversed(parts))


def _store_key(target: ast.expr) -> str | None:
    """Key of the buffer a subscript/attr mutation writes into.

    ``self.x[i] = v`` → ``self.x``; ``toks[i] = v`` → ``toks``;
    nested subscripts peel to the base.
    """
    t = target
    while isinstance(t, ast.Subscript):
        t = t.value
    if isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name) \
            and t.value.id == "self":
        return f"self.{t.attr}"
    if isinstance(t, ast.Name):
        return t.id
    return None


def _walk_functions(cls: ast.ClassDef) -> Iterator[ast.FunctionDef]:
    """Every function in the class, nested closures included."""
    for node in ast.walk(cls):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _own_nodes(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk ``fn``'s body excluding nested function/lambda subtrees, so a
    closure's locals aren't conflated with the enclosing scope's."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# Rule: mutated-host-mirror-alias
# ---------------------------------------------------------------------------

_ALIAS_CTORS = {"jnp.asarray", "np.asarray", "numpy.asarray", "jax.numpy.asarray"}


class MutatedHostMirrorAlias(Rule):
    """Zero-copy device view of a host buffer the same class mutates.

    ``jnp.asarray(host_buf)`` on CPU backends aliases ``host_buf``'s
    memory; mutating it afterwards changes data already captured by a
    dispatch — the PR 2 serve-loop race and the PR 3 deferred-upload race.
    Self-attribute sources are flagged on mutation anywhere in the class
    (method call order is not statically known); local-name sources only
    when mutated *after* the aliasing call in the same function.
    """

    name = "mutated-host-mirror-alias"
    description = (
        "jnp/np.asarray zero-copy view of a buffer that is later mutated "
        "in the same class"
    )

    def check(self, relpath, source, tree):
        if tree is None:
            return
        for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
            # (source key, alias lineno, enclosing function) per asarray call
            aliases: list[tuple[str, int, str]] = []
            # mutation key -> [(lineno, funcname)]
            mutations: dict[str, list[tuple[int, str]]] = {}
            for fn in _walk_functions(cls):
                for node in _own_nodes(fn):
                    if isinstance(node, ast.Call) and node.args:
                        if _call_name(node) in _ALIAS_CTORS:
                            key = _store_key(node.args[0])
                            if key is not None and not isinstance(
                                node.args[0], ast.Subscript
                            ):
                                aliases.append((key, node.lineno, fn.name))
                    targets: list[ast.expr] = []
                    if isinstance(node, ast.Assign):
                        targets = [
                            t for t in node.targets
                            if isinstance(t, ast.Subscript)
                        ]
                    elif isinstance(node, ast.AugAssign) and isinstance(
                        node.target, (ast.Subscript, ast.Attribute)
                    ):
                        targets = [node.target]
                    for t in targets:
                        key = _store_key(t)
                        if key is not None:
                            mutations.setdefault(key, []).append(
                                (node.lineno, fn.name)
                            )
            for key, lineno, fname in aliases:
                muts = mutations.get(key, [])
                if key.startswith("self."):
                    hits = muts  # any order: method call order unknown
                else:
                    hits = [
                        (ln, fn) for ln, fn in muts
                        if fn == fname and ln > lineno
                    ]
                if hits:
                    mln, mfn = hits[0]
                    yield self._violation(
                        relpath, lineno,
                        f"zero-copy view of {key!r} aliases host memory "
                        f"mutated at line {mln} (in {mfn}); copy explicitly "
                        f"or mutate before constructing the view",
                    )


# ---------------------------------------------------------------------------
# Rule: blocking-transfer-in-hot-path
# ---------------------------------------------------------------------------

_BLOCKING_CALLS = {
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "jax.device_get",
}
#: scalar casts that synchronize when fed a device array
_CAST_CALLS = {"float", "int"}
#: a hot function is named (or suffixed) step/decode; builders like
#: _build_steps are not on the per-token path
_HOT_FN_RE = re.compile(r"(?:^|_)(?:step|decode)$")


class BlockingTransferInHotPath(Rule):
    """Synchronous device→host fetch on the serve per-token path.

    Each ``.item()`` / ``np.asarray`` inside a step/decode function is a
    blocking host round trip per token — the exact traffic class the
    zero-copy serve rebuild (PR 3) removed.  The one sanctioned fetch (the
    single (B,) token readback) carries a pragma.
    """

    name = "blocking-transfer-in-hot-path"
    description = (
        ".item()/float()/np.asarray/jax.device_get inside serve "
        "step/decode code"
    )
    path_filter = re.compile(r"^src/repro/serve/")

    def check(self, relpath, source, tree):
        if tree is None:
            return
        fns = [
            n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and _HOT_FN_RE.search(n.name)
        ]
        for fn in fns:
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                cname = _call_name(node)
                hit = None
                if cname.endswith(".item"):
                    hit = ".item()"
                elif cname in _BLOCKING_CALLS:
                    hit = f"{cname}()"
                elif cname in _CAST_CALLS and node.args:
                    hit = f"{cname}()"
                if hit:
                    yield self._violation(
                        relpath, node.lineno,
                        f"{hit} in {fn.name}() blocks on a host↔device "
                        f"round trip on the per-token path; batch the "
                        f"fetch or keep it on device",
                    )


# ---------------------------------------------------------------------------
# Rule: donate-without-out-shardings
# ---------------------------------------------------------------------------

class DonateWithoutOutShardings(Rule):
    """``donate_argnums`` without pinned ``out_shardings``.

    Donation lets XLA reuse input buffers for outputs — but without
    ``out_shardings`` the output placement is XLA's choice, so the buffer
    the planner placed deliberately can come back on a different tier.
    The serve Executor always pins both; everyone else must too (or
    pragma the call if the output placement is genuinely don't-care).
    """

    name = "donate-without-out-shardings"
    description = "donate_argnums jit call missing pinned out_shardings"

    def check(self, relpath, source, tree):
        if tree is None:
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            kws = {k.arg for k in node.keywords if k.arg}
            if ("donate_argnums" in kws or "donate_argnames" in kws) \
                    and "out_shardings" not in kws:
                # anchor to the donate_argnums keyword itself so a
                # same-line pragma works on multi-line jit calls
                donate_kw = next(
                    k for k in node.keywords
                    if k.arg in ("donate_argnums", "donate_argnames")
                )
                yield self._violation(
                    relpath, donate_kw.value.lineno,
                    "donate_argnums without out_shardings: XLA may "
                    "re-place the donated result off the planner-chosen "
                    "tier; pin out_shardings (or pragma if placement is "
                    "genuinely don't-care)",
                )


# ---------------------------------------------------------------------------
# Migrated pattern rules (ex tools/check_deprecated.py)
# ---------------------------------------------------------------------------

class PatternRule(Rule):
    """Regex-over-source rule (comment text stripped per line)."""

    def __init__(
        self,
        name: str,
        pattern: str,
        message: str,
        allow: Iterable[str] = (),
        path_filter: str | None = None,
    ):
        self.name = name
        self.description = message
        self.pattern = re.compile(pattern)
        self.message = message
        self.allow = frozenset(allow)
        if path_filter is not None:
            self.path_filter = re.compile(path_filter)

    def check(self, relpath, source, tree):
        for lineno, line in enumerate(source.splitlines(), start=1):
            code = line.split("#", 1)[0]
            if self.pattern.search(code):
                yield self._violation(
                    relpath, lineno, self.message, snippet=line.strip()
                )


#: shim-defining modules + sanctioned consumers, carried over verbatim
#: from the old check_deprecated ALLOWLIST
_DEPRECATION_ALLOW = frozenset({
    "src/repro/core/placement.py",
    "src/repro/core/__init__.py",
    "src/repro/core/hardware.py",
    "src/repro/models/sharding.py",
    "src/repro/models/__init__.py",
    "src/repro/api.py",
    "tests/test_placement_api.py",
    "src/repro/serve/__init__.py",
    "src/repro/serve/engine.py",
    "src/repro/serve/scheduler.py",
    "src/repro/serve/disagg.py",
    "src/repro/serve/sampling.py",
    "src/repro/serve/state.py",
    "src/repro/analysis/lint.py",
    "tools/check_deprecated.py",
})

register(MutatedHostMirrorAlias())
register(BlockingTransferInHotPath())
register(DonateWithoutOutShardings())
register(PatternRule(
    "deprecated-policies", r"\bPOLICIES\b",
    "POLICIES is deprecated: use registered_policies()/get_policy()/"
    "parse_policy()", _DEPRECATION_ALLOW,
))
register(PatternRule(
    "deprecated-policy-specs", r"\bpolicy_specs\b",
    "policy_specs is deprecated: use Runtime.specs / Runtime.realize",
    _DEPRECATION_ALLOW,
))
register(PatternRule(
    "deprecated-put-like", r"\bput_like\b",
    "put_like is deprecated: use Runtime.realize", _DEPRECATION_ALLOW,
))
register(PatternRule(
    "deprecated-engine-import",
    r"(from\s+repro\.serve\.engine\s+import"
    r"|import\s+repro\.serve\.engine"
    r"|\brepro\.serve\.engine\.)",
    "import the repro.serve package, not the engine module (Executor-only "
    "now; Request/ServeConfig/Server live in the scheduler layer)",
    _DEPRECATION_ALLOW,
))
register(PatternRule(
    "deprecated-stats-dict", r"\.stats\[",
    "Server.stats is a method now: call .stats(), not .stats[...]",
    _DEPRECATION_ALLOW,
))
register(PatternRule(
    "injected-fault-raise",
    r"\braise\s+(?:faults\.)?(?:InjectedFault|TransientFault|TierLossError|"
    r"MigrationFault|SpillCorruptionError|TicketLossError)\b",
    "injected fault types may only be raised by the harness "
    "(core/faults.py): production code must signal failures with its own "
    "error types, never impersonate an injected fault",
    frozenset({"src/repro/core/faults.py"}),
))
register(PatternRule(
    "cross-pool-device-put",
    r"\b(?:jax\s*\.\s*)?device_put\s*\(",
    "raw device_put in a serve module: cross-pool data movement must go "
    "through the Handoff (serve/handoff.py owns the bridge mesh and the "
    "crossing ledger); pool-local placement goes through Runtime.realize "
    "or Executor.place_state",
    frozenset({
        # the one sanctioned crossing site
        "src/repro/serve/handoff.py",
        # pool-local: place_state commits onto the executor's own mesh
        "src/repro/serve/engine.py",
    }),
    path_filter=r"^src/repro/serve/",
))
register(PatternRule(
    "deprecated-default-system", r"\bDEFAULT_SYSTEM\b",
    "DEFAULT_SYSTEM is retired: price through Runtime / "
    "get_active_system() so --calibration re-prices everything "
    "(repro.api re-exports SPEC_SYSTEM for explicit comparisons)",
    _DEPRECATION_ALLOW,
))
