"""Shared warn-once registry.

Every once-per-process warning in the repo (deprecation shims, unknown
mesh-axis link fallbacks, the encdec decode-replay slow-path notice) used
to keep its own module-level ``set`` — so whether a test observed the
warning depended on which test ran first.  They all register here instead:
one keyed registry, resettable by the autouse test fixture in
``tests/conftest.py``.

Keys are namespaced strings, e.g. ``axis_link:donor``,
``deprecated:<shim name>``, ``decode_replay:seamless-m4t``.
"""

from __future__ import annotations

import threading
import warnings as _warnings

_LOCK = threading.Lock()
_SEEN: set[str] = set()


def warn_once(
    key: str,
    message: str,
    category: type[Warning] = UserWarning,
    stacklevel: int = 3,
) -> bool:
    """Emit ``message`` the first time ``key`` is seen; return whether the
    warning fired.  Thread-safe; reset via :func:`reset_warnings`."""
    with _LOCK:
        if key in _SEEN:
            return False
        _SEEN.add(key)
    _warnings.warn(message, category, stacklevel=stacklevel)
    return True


def warned(key: str) -> bool:
    """Has ``key`` fired since the last reset?"""
    with _LOCK:
        return key in _SEEN


def mark(key: str) -> bool:
    """Register ``key`` without emitting anything (for once-only side
    effects that aren't ``warnings.warn`` — e.g. a log line).  Returns
    True the first time, False after."""
    with _LOCK:
        if key in _SEEN:
            return False
        _SEEN.add(key)
        return True


def reset_warnings(prefix: str | None = None) -> None:
    """Forget fired keys (all, or those under ``prefix:``/exact match)."""
    with _LOCK:
        if prefix is None:
            _SEEN.clear()
        else:
            drop = {
                k for k in _SEEN if k == prefix or k.startswith(prefix + ":")
            }
            _SEEN.difference_update(drop)
