"""Static analysis for the data-movement discipline.

Two layers, one motivation (Schieffer et al., PAPERS.md): transparent
unified-memory access makes unintended transfers *silent* — every aliasing
race and stray copy this repo has shipped (PR 2's ``jnp.asarray`` zero-copy
race, PR 3's deferred-upload race, PR 7's ICI mispricing) was found at
runtime by flaky tests.  This package checks the invariants statically:

* :mod:`repro.analysis.hlo_audit` — diff the data movement XLA actually
  compiled (``compiled.as_text()``: copies, host memory spaces, donation
  aliasing) against the planner's expected byte plan for the policy.
* :mod:`repro.analysis.lint` — an ``ast``-based rule registry encoding
  the repo coding discipline (host-mirror aliasing, blocking transfers in
  the serve hot path, donation without pinned out_shardings, deprecation
  hygiene), with per-rule allowlists and ``# repro: lint-disable=<rule>``
  pragmas.
* :mod:`repro.analysis.warnings_registry` — the shared warn-once registry
  backing every once-per-process warning in the repo, resettable so tests
  stop depending on execution order.

Only the warnings registry is imported eagerly: core modules depend on it,
so ``hlo_audit``/``lint`` (which import core back) load lazily.
"""

from __future__ import annotations

from repro.analysis.warnings_registry import (  # noqa: F401
    reset_warnings,
    warn_once,
    warned,
)

_LAZY = {
    "hlo_audit": "repro.analysis.hlo_audit",
    "lint": "repro.analysis.lint",
}

__all__ = ["warn_once", "warned", "reset_warnings", "hlo_audit", "lint"]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(_LAZY[name])
        globals()[name] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
