"""Layer 1: diff compiled-HLO data movement against the planner's plan.

The paper's core claim is that *where bytes move* decides performance on
tightly coupled systems; Schieffer et al. (PAPERS.md) show the failure
mode — transparent access makes unintended transfers silent.  This module
makes them loud, statically: given ``compiled.as_text()`` (post-SPMD, so
every quantity is per chip) and an :class:`ExpectedMovement` derived from
the placement policy, it checks

* **donation coverage** — every donation-compatible buffer (placement
  strategy is not STREAM) must appear in the module's
  ``input_output_alias`` header; a donated-but-unaliased buffer is a
  silent full-size copy per dispatch (``missed-donation``);
* **donation prohibition** — STREAM placements must *not* be aliased:
  the streaming window still reads the source after dispatch
  (``forbidden-donation``, the PR 3 rule);
* **host↔device budget** — total bytes crossing the host memory space
  (``S(5)`` layouts on ``copy``/``copy-start``) must stay within the
  policy's allowance — for serve decode, exactly one ``(B,)`` token
  vector per step (Fig. 17's once-per-token datapath)
  (``stray-host-transfer``);
* **byte plan** — per-role parameter bytes vs the planner's
  ``bytes_per_role`` within tolerance (``byte-plan-mismatch``, warning:
  planner estimates legitimately diverge from padded/sharded reality).

Violations carry the op, bytes, tier edge, and the planner term they
break, so a CI failure reads like a planner line item, not a grep hit.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Mapping

from repro.core.hlo_analysis import (
    AliasPair,
    TransferStat,
    analyze_hlo_text,
    entry_parameters,
    parse_input_output_alias,
)


class DonationAliasError(RuntimeError):
    """A donation the policy requires did not materialize (or one it
    forbids did).  Raised at Executor build time so the cost is a clear
    error, not a silent extra copy on every dispatch."""


# ---------------------------------------------------------------------------
# Expectations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoleExpectation:
    """What the policy says about one jit argument (planner role)."""

    role: str                     # planner role name, e.g. "kv_cache"
    arg_root: str                 # jax arg-path root in HLO metadata, e.g. "caches"
    donate: bool                  # donation-compatible => must alias
    planner_term: str = "hbm"     # predict() term pricing this movement
    plan_bytes: float | None = None   # planner's per-step byte plan, if priced
    tolerance: float = 0.5        # relative tolerance for plan_bytes


@dataclasses.dataclass(frozen=True)
class ExpectedMovement:
    """The policy-derived movement contract for one compiled executable."""

    roles: tuple[RoleExpectation, ...] = ()
    #: host↔device byte allowance per dispatch (serve decode: one (B,)
    #: token vector; 0 for fully device-resident steps)
    host_bytes_allowed: float = 0.0
    label: str = ""

    def role_for_root(self, root: str) -> RoleExpectation | None:
        for r in self.roles:
            if r.arg_root == root:
                return r
        return None


# ---------------------------------------------------------------------------
# Violations / report
# ---------------------------------------------------------------------------

#: gate-failing violation kinds (severity "error")
ERROR_KINDS = ("missed-donation", "forbidden-donation", "stray-host-transfer")


@dataclasses.dataclass(frozen=True)
class AuditViolation:
    kind: str          # one of ERROR_KINDS or "byte-plan-mismatch"
    severity: str      # "error" | "warning"
    op: str            # HLO instruction / parameter the violation anchors to
    nbytes: float      # bytes at stake, per dispatch
    tier_edge: str     # the datapath edge being (mis)used, e.g. "host<->hbm"
    planner_term: str  # which predict() term the movement breaks
    detail: str

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AuditReport:
    """Everything the audit observed, plus the diff against expectations."""

    label: str
    violations: list[AuditViolation]
    transfers: list[TransferStat]
    aliases: list[AliasPair]
    #: observed entry-parameter bytes per planner role
    role_bytes: dict[str, float]
    host_transfer_bytes: float
    donation_expected: int
    donation_materialized: int

    @property
    def ok(self) -> bool:
        return not any(v.severity == "error" for v in self.violations)

    @property
    def donation_coverage(self) -> float:
        """Fraction of donation-required buffers that actually aliased."""
        if self.donation_expected == 0:
            return 1.0
        return self.donation_materialized / self.donation_expected

    def to_json(self) -> dict[str, Any]:
        return {
            "label": self.label,
            "ok": self.ok,
            "violations": [v.to_json() for v in self.violations],
            "host_transfer_bytes": self.host_transfer_bytes,
            "donation_expected": self.donation_expected,
            "donation_materialized": self.donation_materialized,
            "donation_coverage": self.donation_coverage,
            "role_bytes": dict(self.role_bytes),
            "n_transfers": len(self.transfers),
            "n_aliases": len(self.aliases),
        }

    def raise_on_donation_errors(self) -> None:
        bad = [
            v for v in self.violations
            if v.kind in ("missed-donation", "forbidden-donation")
        ]
        if bad:
            lines = "\n".join(f"  [{v.kind}] {v.op}: {v.detail}" for v in bad)
            raise DonationAliasError(
                f"{self.label or 'executable'}: donation contract not "
                f"honored by the compiled module "
                f"({self.donation_materialized}/{self.donation_expected} "
                f"aliased):\n{lines}"
            )


# ---------------------------------------------------------------------------
# The audit
# ---------------------------------------------------------------------------

def audit_hlo_text(
    text: str,
    expected: ExpectedMovement,
    mesh_axes: Mapping[str, int] | None = None,
) -> AuditReport:
    """Audit one compiled module's text against ``expected``."""
    cost = analyze_hlo_text(text, mesh_axes)
    params = entry_parameters(text)
    aliases = parse_input_output_alias(text)
    aliased_params = {a.param_number for a in aliases}
    violations: list[AuditViolation] = []

    by_root: dict[str, list] = defaultdict(list)
    for p in params:
        by_root[p.arg_root].append(p)

    role_bytes: dict[str, float] = {}
    donation_expected = donation_materialized = 0
    for exp in expected.roles:
        leaves = by_root.get(exp.arg_root, [])
        observed = float(sum(p.nbytes for p in leaves))
        role_bytes[exp.role] = observed
        for p in leaves:
            label = f"parameter({p.number}) {p.op_name}".strip()
            if exp.donate:
                donation_expected += 1
                if p.number in aliased_params:
                    donation_materialized += 1
                else:
                    violations.append(AuditViolation(
                        kind="missed-donation",
                        severity="error",
                        op=label,
                        nbytes=float(p.nbytes),
                        tier_edge="hbm",
                        planner_term=exp.planner_term,
                        detail=(
                            f"role {exp.role!r} is donation-compatible but "
                            f"has no input_output_alias entry: every "
                            f"dispatch pays a silent {p.nbytes}-byte copy "
                            f"the planner never priced"
                        ),
                    ))
            elif p.number in aliased_params:
                violations.append(AuditViolation(
                    kind="forbidden-donation",
                    severity="error",
                    op=label,
                    nbytes=float(p.nbytes),
                    tier_edge="hbm",
                    planner_term=exp.planner_term,
                    detail=(
                        f"role {exp.role!r} has a STREAM placement — the "
                        f"window still reads the source after dispatch, so "
                        f"aliasing its buffer is a use-after-donate race"
                    ),
                ))
        if exp.plan_bytes is not None and exp.plan_bytes > 0:
            rel = abs(observed - exp.plan_bytes) / exp.plan_bytes
            if rel > exp.tolerance:
                violations.append(AuditViolation(
                    kind="byte-plan-mismatch",
                    severity="warning",
                    op=f"role:{exp.role}",
                    nbytes=observed,
                    tier_edge=exp.planner_term,
                    planner_term=exp.planner_term,
                    detail=(
                        f"planner prices {exp.plan_bytes:.0f} B/step for "
                        f"role {exp.role!r} but the compiled module holds "
                        f"{observed:.0f} B ({rel:.0%} off, tolerance "
                        f"{exp.tolerance:.0%})"
                    ),
                ))

    host_bytes = cost.host_transfer_bytes
    if host_bytes > expected.host_bytes_allowed:
        for t in cost.transfers:
            if not t.crosses_host:
                continue
            violations.append(AuditViolation(
                kind="stray-host-transfer",
                severity="error",
                op=f"{t.opcode} %{t.name}" + (f" ({t.op_name})" if t.op_name else ""),
                nbytes=t.nbytes,
                tier_edge="host<->hbm",
                planner_term="pcie",
                detail=(
                    f"host↔device traffic is {host_bytes:.0f} B/dispatch, "
                    f"over the policy allowance of "
                    f"{expected.host_bytes_allowed:.0f} B (Fig. 17: decode "
                    f"moves exactly one (B,) token vector per step)"
                ),
            ))

    return AuditReport(
        label=expected.label,
        violations=violations,
        transfers=cost.transfers,
        aliases=aliases,
        role_bytes=role_bytes,
        host_transfer_bytes=host_bytes,
        donation_expected=donation_expected,
        donation_materialized=donation_materialized,
    )


def audit_compiled(
    compiled: Any,
    expected: ExpectedMovement,
    mesh_axes: Mapping[str, int] | None = None,
) -> AuditReport:
    """Audit a jax ``Compiled`` (or anything with ``as_text()``)."""
    return audit_hlo_text(compiled.as_text(), expected, mesh_axes)
