"""``repro.api`` — the one placement-aware runtime facade.

The paper's §IV studies (and Schieffer et al.'s follow-up on the unified
GH200 address space) show that *per-role, per-phase* physical placement
decides performance, and that the best placement changes as the workload
changes: prefill vs decode, a KV cache growing toward the HBM ceiling, a
train→serve handover.  Acting on that requires three things the scattered
pre-facade wiring could not express:

1. **Placements as values** — :func:`repro.core.placement.policy`,
   :class:`~repro.core.placement.PolicyBuilder` and the policy string/JSON
   grammar build arbitrary :class:`~repro.core.placement.PlacementPolicy`
   objects; the registry makes them nameable.
2. **One facade** — a :class:`Runtime` owns mesh + policy + planner.
   :meth:`Runtime.auto` runs the planner restricted to the tiers this
   runtime realizes; :meth:`Runtime.realize` / :meth:`Runtime.specs`
   subsume the per-call-site ``policy_specs``/``put_like`` wiring;
   :meth:`Runtime.explain` surfaces the planner's prediction table.
3. **Re-placement as a runtime primitive** — :meth:`Runtime.migrate`
   moves *live* tensors between tiers mid-run: donation-aware
   ``device_put`` onto the new (donor-extended) shardings, validated
   against the mesh (:class:`~repro.core.placement.DonorAxisError`, never
   a silent local landing), with registered ``Strategy.STREAM`` staging
   buffers rebuilt around the moved tree.  ``Server.replan()`` in
   :mod:`repro.serve.scheduler` uses it to re-place the KV cache and params
   when occupancy crosses planner-priced thresholds — the first point in
   the repo where the paper's placement tradeoffs are acted on *during*
   execution instead of only at startup.
"""

from __future__ import annotations

import dataclasses
import json
import logging
from typing import Iterable, Mapping, Sequence

import jax
from jax.sharding import PartitionSpec as P

from repro.core.datapath import copy_bound
from repro.core.hardware import (
    DEFAULT_SYSTEM,
    MemoryTier,
    SystemSpec,
    get_active_system,
    set_active_system,
)
from repro.core.faults import NO_FAULTS, FaultPlan
from repro.core.replay import ReplayLog
from repro.core.placement import (
    HOST_TIERS,
    PEER_TIERS,
    REMOTE_TIERS,
    DonorStream,
    Placement,
    PlacementPolicy,
    Role,
    _put_like,
    donor_allow_flags,
    get_policy,
    parse_policy,
    parse_role,
    parse_tier,
    registered_policies,
    validate_policy_for_mesh,
)
from repro.core.planner import PolicyPrediction, plan, predict
from repro.models.sharding import _policy_specs, donation_compatible

log = logging.getLogger("repro.api")

__all__ = ["Runtime", "PhasePlan", "SPEC_SYSTEM"]

#: The spec-sheet baseline, re-exported so spec-vs-calibrated comparisons
#: (benchmarks, placement sweeps) never re-import the hardware singleton:
#: this facade is the one sanctioned consumer of the raw constant.
SPEC_SYSTEM = DEFAULT_SYSTEM

#: decode-step EWMA weights (old, new) — matches the serve Executor's
#: historical smoothing so pricing behavior is unchanged, just owned here.
_EWMA_OLD, _EWMA_NEW = 0.8, 0.2


@dataclasses.dataclass
class PhasePlan:
    """One planner pass: the pick plus everything it was compared against.

    ``predictions`` maps policy name to the phase's (possibly combined)
    :class:`~repro.core.planner.PolicyPrediction`; ``score`` is the
    quantity the pick minimized (plain ``step_s`` for single-profile
    phases, the combined per-token time for ``serve``).
    """

    phase: str
    picked: str
    predictions: dict[str, PolicyPrediction]
    score: dict[str, float]
    feasible: frozenset[str]

    def table(self, top: int = 3) -> str:
        """Human-readable top-``top`` candidate table (the pick always
        included), feasible candidates first, fastest first."""
        ranked = sorted(
            self.predictions,
            key=lambda n: (n not in self.feasible, self.score[n]),
        )
        show = ranked[:top]
        if self.picked in self.predictions and self.picked not in show:
            show.append(self.picked)
        lines = [f"phase={self.phase} picked={self.picked}"]
        for name in show:
            mark = "=> " if name == self.picked else "   "
            lines.append(f"{mark}{self.predictions[name].explain()}")
        return "\n".join(lines)


def _resolve_candidates(
    candidates: Iterable[PlacementPolicy | str] | None,
) -> list[PlacementPolicy] | None:
    if candidates is None:
        return None
    return [parse_policy(c) for c in candidates]


def _candidate_index(
    cand: list[PlacementPolicy] | None,
) -> dict[str, PlacementPolicy]:
    """Name -> policy over the candidate set the planner enumerated
    (the registry when no explicit candidates were given)."""
    return {
        p.name: p
        for p in (registered_policies().values() if cand is None else cand)
    }


class Runtime:
    """Mesh + placement policy + planner behind one object.

    Construct directly to force a policy (any spelling
    :func:`~repro.core.placement.parse_policy` accepts — a registered
    name, the compact grammar, JSON, or a
    :class:`~repro.core.placement.PlacementPolicy` value), or via
    :meth:`auto` to let the planner pick for a phase.  Either way the
    policy is validated against the mesh up front: a peer/remote
    placement on a donor-less mesh raises
    :class:`~repro.core.placement.DonorAxisError` at construction, never
    a silent local landing at realize time.
    """

    def __init__(
        self,
        bundle,
        mesh=None,
        policy: PlacementPolicy | str | Mapping | None = None,
        *,
        rules: Mapping | None = None,
        system: SystemSpec | None = None,
    ):
        self.bundle = bundle
        self.mesh = mesh
        self.rules = rules
        # the runtime owns the (possibly calibrated) system every pricing
        # path consumes; None adopts the process-wide active system.
        self.system = system if system is not None else get_active_system()
        self.policy = (
            get_policy("hbm_resident") if policy is None
            else parse_policy(policy)
        )
        validate_policy_for_mesh(self.policy, mesh)
        #: planner passes run by auto()/plan_phase(), newest last per phase
        self.plans: dict[str, PhasePlan] = {}
        self._streams: dict[Role, tuple[DonorStream, tuple]] = {}
        self._step_estimates: dict[tuple, float] = {}
        #: measured decode-step EWMA per (batch_slots, max_len, policy)
        self._step_observed: dict[tuple, float] = {}
        #: the last Calibration adopted by calibrate() (None = spec)
        self.calibration = None
        #: predicted-vs-measured log fed by observe_decode_step()
        self.replay = ReplayLog()
        #: injected-fault schedule; the falsy NO_FAULTS default means
        #: production paths pay one truthiness test (see core/faults.py)
        self.faults: FaultPlan = NO_FAULTS
        #: tiers declared unusable by mark_tier_lost()/evacuate();
        #: _allow_flags() masks them out of every subsequent planner
        #: pass, spill-placement pick and migration target
        self.lost_tiers: set[MemoryTier] = set()

    # -- construction ------------------------------------------------------
    @classmethod
    def auto(
        cls,
        bundle,
        mesh=None,
        *,
        phase: str = "decode",
        rules: Mapping | None = None,
        system: SystemSpec | None = None,
        candidates: Iterable[PlacementPolicy | str] | None = None,
        require_fit: bool = False,
        **phase_kw,
    ) -> "Runtime":
        """Planner-selected Runtime for ``phase``.

        ``phase`` is ``"train"``, ``"decode"``, ``"prefill"`` or
        ``"serve"`` (decode + chunked prefill priced together, the serve
        engine's combined per-token objective).  ``phase_kw`` are the
        workload knobs of :meth:`plan_phase` (``batch``/``seq``/``remat``
        for train; ``batch_slots``/``max_len``/``prefill_chunk`` for the
        serve-side phases).  The candidate set defaults to the policy
        registry restricted to the tiers this mesh/backend realizes
        (:func:`~repro.core.placement.donor_allow_flags`), so the pick is
        always realizable.
        """
        rt = cls(bundle, mesh, None, rules=rules, system=system)
        rt.plan_phase(
            phase, candidates=candidates, require_fit=require_fit,
            **phase_kw,
        )
        return rt

    @property
    def num_chips(self) -> int:
        return int(self.mesh.devices.size) if self.mesh is not None else 1

    # -- degraded-tier bookkeeping -----------------------------------------
    def mark_tier_lost(self, tier: "MemoryTier | str") -> MemoryTier:
        """Declare ``tier`` unusable for the rest of this runtime's life.

        Tier loss happens at donor-axis granularity — losing the peer
        link takes peer HBM *and* peer DRAM with it (same ``donor``
        axis), so the sibling tier on the same axis is marked too.
        Planner passes, :meth:`spill_placement` and :meth:`evacuate`
        all consult :attr:`lost_tiers` via :meth:`_allow_flags`.
        """
        tier = parse_tier(tier)
        self.lost_tiers.add(tier)
        if tier in PEER_TIERS:
            self.lost_tiers |= PEER_TIERS
        if tier in REMOTE_TIERS:
            self.lost_tiers |= REMOTE_TIERS
        log.warning(
            "tier %s marked lost (now excluded: %s)",
            tier.value, sorted(t.value for t in self.lost_tiers),
        )
        return tier

    def _allow_flags(self) -> dict:
        """``donor_allow_flags(mesh)`` masked by :attr:`lost_tiers` — the
        one place every planning/spill/migration path gets its tier
        eligibility, so a lost tier disappears from all of them at once."""
        allow = donor_allow_flags(self.mesh)
        if not self.lost_tiers:
            return allow
        allow = dict(allow)
        if MemoryTier.HOST in self.lost_tiers:
            allow["allow_host"] = False
        if self.lost_tiers & PEER_TIERS:
            allow["allow_peer"] = False
        if self.lost_tiers & REMOTE_TIERS:
            allow["allow_remote"] = False
        return allow

    # -- planning ----------------------------------------------------------
    def plan_phase(
        self,
        phase: str = "decode",
        *,
        batch: int = 8,
        seq: int = 128,
        remat: bool = True,
        batch_slots: int = 8,
        max_len: int = 512,
        prefill_chunk: int = 32,
        kv_utilization: float = 1.0,
        candidates: Iterable[PlacementPolicy | str] | None = None,
        require_fit: bool = False,
        log_table: bool = True,
    ) -> PolicyPrediction:
        """Run the planner for ``phase`` and adopt its pick.

        Restricted to tiers this runtime realizes; ``kv_utilization``
        scales the KV-cache bytes of the serve-side profiles to the
        *current* cache occupancy — what :meth:`repro.serve.scheduler.
        Server.replan` feeds so spill/promote thresholds are priced on
        live
        state, not the worst case.  Returns the winning (decode-side for
        ``serve``) prediction; the full comparison lands in
        :attr:`plans` and :meth:`explain`.
        """
        from repro.configs import ShapeSpec

        cand = _resolve_candidates(candidates)
        if cand is None and self.mesh is None:
            # With no mesh the runtime realizes no placements (realize()
            # is a no-op), whatever the backend's memory kinds — restrict
            # the auto pick to the default placement so the planner never
            # adopts a policy this runtime would silently fail to realize.
            cand = [get_policy("hbm_resident")]
        allow = self._allow_flags()
        num_chips = self.num_chips

        if phase == "train":
            axes = dict(self.mesh.shape) if self.mesh is not None else {}
            prof = self.bundle.train_workload(
                ShapeSpec("auto", seq, batch, "train"),
                num_chips=num_chips,
                data_axis_size=axes.get("data", 1),
                pod_axis_size=axes.get("pod", 1),
                remat=remat,
            )
            best, preds = plan(
                prof, cand, self.system, require_fit=require_fit, **allow
            )
            score = {p.policy: p.step_s for p in preds}
            combined = {p.policy: p for p in preds}
        elif phase in ("decode", "prefill"):
            shape = ShapeSpec("auto", max_len, batch_slots, "decode")
            if phase == "decode":
                prof = self.bundle.decode_workload(shape, num_chips=num_chips)
            else:
                prof = self.bundle.prefill_workload(
                    shape, chunk_tokens=prefill_chunk, num_chips=num_chips
                )
            prof = _scale_kv(prof, kv_utilization)
            best, preds = plan(
                prof, cand, self.system, require_fit=require_fit, **allow
            )
            score = {p.policy: p.step_s for p in preds}
            combined = {p.policy: p for p in preds}
        elif phase == "serve":
            best, score, combined = self._plan_serve(
                cand, batch_slots=batch_slots, max_len=max_len,
                prefill_chunk=prefill_chunk, kv_utilization=kv_utilization,
                require_fit=require_fit,
            )
        else:
            raise ValueError(
                f"unknown phase {phase!r}; one of train/decode/prefill/serve"
            )

        self.policy = _candidate_index(cand)[best.policy]
        self.plans[phase] = PhasePlan(
            phase=phase,
            picked=best.policy,
            predictions=combined,
            score=score,
            feasible=frozenset(n for n, p in combined.items() if p.fits),
        )
        if log_table:
            log.info("planner\n%s", self.explain(phase))
        return best

    def _plan_serve(
        self,
        cand,
        *,
        batch_slots: int,
        max_len: int,
        prefill_chunk: int,
        kv_utilization: float,
        require_fit: bool,
    ):
        """Price decode AND chunked prefill; minimize combined per-token
        time over policies that fit both phases (one decode step yields
        ``batch_slots`` tokens; one prefill dispatch ingests
        ``batch_slots * prefill_chunk`` prompt tokens — amortized 1:1).
        When nothing fits, fall back to the least-HBM decode prediction
        (a slower placement that runs beats an OOM), unless
        ``require_fit``."""
        from repro.configs import ShapeSpec
        from repro.core.planner import PlacementOOMError

        shape = ShapeSpec("serve", max_len, batch_slots, "decode")
        dec_prof = _scale_kv(
            self.bundle.decode_workload(shape, num_chips=self.num_chips),
            kv_utilization,
        )
        pre_prof = _scale_kv(
            self.bundle.prefill_workload(
                shape, chunk_tokens=prefill_chunk, num_chips=self.num_chips
            ),
            kv_utilization,
        )
        allow = self._allow_flags()
        _, dec_preds = plan(dec_prof, cand, self.system, **allow)
        by_name = _candidate_index(cand)
        pre_preds = {
            d.policy: predict(pre_prof, by_name[d.policy], self.system)
            for d in dec_preds
        }

        def per_token(d: PolicyPrediction) -> float:
            return d.step_s + pre_preds[d.policy].step_s / max(
                prefill_chunk, 1
            )

        score = {d.policy: per_token(d) for d in dec_preds}
        combined = {d.policy: d for d in dec_preds}
        feasible = [
            d for d in dec_preds if d.fits and pre_preds[d.policy].fits
        ]
        if feasible:
            best = min(feasible, key=per_token)
        elif require_fit:
            raise PlacementOOMError(dec_preds, self.system)
        else:
            best = min(dec_preds, key=lambda d: d.hbm_bytes)
            for d in dec_preds:
                log.warning(
                    "planner OOM: %s overflows pools %s (decode) / %s "
                    "(prefill)",
                    d.policy,
                    ", ".join(d.overflow_pools) or "none",
                    ", ".join(pre_preds[d.policy].overflow_pools) or "none",
                )
        # mark serve feasibility as BOTH-phase fit for the PhasePlan
        combined = {
            n: dataclasses.replace(d, fits=d.fits and pre_preds[n].fits)
            for n, d in combined.items()
        }
        return best, score, combined

    def explain(self, phase: str | None = None, top: int = 3) -> str:
        """The planner's prediction table for ``phase`` (default: every
        phase planned so far): the top-``top`` candidates with their
        per-term datapath seconds, pool residency and fit, the pick
        marked.  Empty string when nothing was planned (forced policy)."""
        plans = (
            list(self.plans.values()) if phase is None
            else [self.plans[phase]] if phase in self.plans else []
        )
        return "\n".join(pl.table(top) for pl in plans)

    def describe(self) -> dict:
        """JSON-serializable record of what this runtime runs under —
        benchmark artifacts embed it so the numbers name their placement."""
        return {
            "policy": json.loads(self.policy.to_json()),
            "mesh_axes": dict(self.mesh.shape) if self.mesh is not None else None,
            "phases": {
                name: {
                    "picked": pl.picked,
                    "top3": pl.table(3),
                }
                for name, pl in self.plans.items()
            },
        }

    # -- realization -------------------------------------------------------
    def specs(
        self,
        role: Role | str,
        defs=None,
        *,
        fsdp_axes: Sequence[str] = (),
        policy: PlacementPolicy | None = None,
    ):
        """NamedShardings realizing the policy's placement of ``role``.

        ``defs`` is a Param-def pytree (defaults to the bundle's param
        defs for ``Role.PARAMS``).  Returns ``None`` with no mesh — the
        single-device path where placement is a no-op.
        """
        if self.mesh is None:
            return None
        role = parse_role(role)
        if defs is None:
            if role is not Role.PARAMS:
                raise ValueError(
                    f"specs({role}): a def pytree is required for every "
                    "role but PARAMS (params default to bundle.param_defs())"
                )
            defs = self.bundle.param_defs()
        return _policy_specs(
            defs, self.mesh, self.rules, role, policy or self.policy,
            fsdp_axes=fsdp_axes,
        )

    def realize(
        self,
        tree,
        role: Role | str,
        defs=None,
        *,
        specs=None,
        fsdp_axes: Sequence[str] = (),
        policy: PlacementPolicy | None = None,
    ):
        """device_put ``tree`` under the policy's placement for ``role``.

        With ``defs`` (or for ``Role.PARAMS``, where the bundle's defs
        are implied) the placement is realized through the logical-axis
        rule table; a def-less tree is placed leaf-wise with ``specs``
        (a PartitionSpec or matching pytree, default replicated) extended
        over the tier's donor axes.  No mesh -> returns ``tree``
        unchanged (nothing to realize).
        """
        if self.mesh is None:
            return tree
        if self.faults:
            self.faults.check("realize")
        role = parse_role(role)
        pol = policy or self.policy
        if defs is None and specs is None and role is Role.PARAMS:
            defs = self.bundle.param_defs()
        if defs is not None:
            shardings = self.specs(role, defs, fsdp_axes=fsdp_axes,
                                   policy=pol)
            return jax.tree.map(jax.device_put, tree, shardings)
        return _put_like(
            tree, self.mesh, P() if specs is None else specs, role, pol
        )

    def donate_ok(self, role: Role | str) -> bool:
        """May a jitted step donate ``role``'s buffers under the current
        policy?  (STREAM placements must keep their resident buffer.)"""
        return donation_compatible(self.policy, parse_role(role))

    # -- static data-movement audit ----------------------------------------
    def audit(
        self,
        target,
        arg_roles: Mapping[str, "Role | str"],
        *,
        donated: Iterable[str] = (),
        host_bytes_allowed: float = 0.0,
        workload=None,
        tolerance: float = 0.5,
        label: str = "",
    ):
        """Diff a compiled executable's data movement against this policy.

        ``target`` is a jax ``Compiled`` (anything with ``as_text()``) or
        raw HLO text.  ``arg_roles`` maps jit argument names (the roots of
        the ``op_name`` arg paths in the entry parameters, e.g.
        ``{"caches": Role.KV_CACHE, "p": Role.PARAMS}``) to planner roles;
        ``donated`` names the arguments the call actually donates.  A
        donation-compatible donated argument must appear in the module's
        ``input_output_alias`` header (else ``missed-donation``); an
        argument the policy forbids donating (STREAM) must not
        (``forbidden-donation``).  Host↔device traffic beyond
        ``host_bytes_allowed`` is ``stray-host-transfer`` — serve decode's
        allowance is the one (B,) token vector each way of Fig. 17.  With
        a planner ``workload`` (:class:`~repro.core.planner.
        WorkloadProfile`), each role's observed parameter bytes are also
        checked against ``bytes_per_role`` within ``tolerance``
        (warning-severity: padding and sharding legitimately skew these).

        Returns a :class:`repro.analysis.hlo_audit.AuditReport`.
        """
        from repro.analysis.hlo_audit import (
            ExpectedMovement,
            RoleExpectation,
            audit_hlo_text,
        )

        donated = set(donated)
        plan_bytes = dict(getattr(workload, "bytes_per_role", None) or {})
        term_by_tier = {
            MemoryTier.HBM: "hbm",
            MemoryTier.HOST: "pcie",
            MemoryTier.PEER_HBM: "ici",
            MemoryTier.PEER_HOST: "ici",
            MemoryTier.REMOTE_HBM: "dcn",
        }
        roles = []
        for root, role in arg_roles.items():
            role = parse_role(role)
            roles.append(RoleExpectation(
                role=role.value,
                arg_root=root,
                donate=root in donated and self.donate_ok(role),
                planner_term=term_by_tier.get(
                    self.policy.placement(role).tier, "hbm"
                ),
                plan_bytes=(
                    float(plan_bytes[role]) if role in plan_bytes else None
                ),
                tolerance=tolerance,
            ))
        expected = ExpectedMovement(
            roles=tuple(roles),
            host_bytes_allowed=float(host_bytes_allowed),
            label=label or f"{self.bundle.cfg.name}:{self.policy.name}",
        )
        text = target if isinstance(target, str) else target.as_text()
        mesh_axes = (
            dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
            if self.mesh is not None else None
        )
        return audit_hlo_text(text, expected, mesh_axes)

    # -- eviction pricing --------------------------------------------------
    def price_copy(
        self,
        nbytes: float,
        dst: "Placement | MemoryTier | str",
        src: "Placement | MemoryTier | str | None" = None,
    ) -> float:
        """Planner-priced seconds to move ``nbytes`` between tiers.

        The datapath ``copy_bound`` (twice-traversed-link halving rule +
        per-segment latencies) between ``src`` (default: the current
        policy's KV-cache tier) and ``dst`` — the cost model behind
        preemption decisions: what does parking these cache rows off-HBM
        actually cost on this machine?
        """
        if src is None:
            src = self.policy.placement(Role.KV_CACHE)
        src_t = src.tier if isinstance(src, Placement) else parse_tier(src)
        dst_t = dst.tier if isinstance(dst, Placement) else parse_tier(dst)
        return copy_bound(src_t, dst_t, self.system).time(nbytes)

    def spill_placement(self, allow: dict | None = None) -> Placement:
        """The cheapest *realizable* far-tier parking spot for evicted KV
        rows: host DRAM when the backend exposes it, the peer/remote
        donor pools when the mesh has the donor axis — whichever round
        trip the datapath model prices lowest.  Falls back to local HBM
        (a placement-neutral parking copy: the slot is still freed, just
        without relieving HBM capacity) when no far tier is realizable.

        ``allow`` lets a caller pin one ``_allow_flags()`` snapshot
        across the pick *and* whatever pricing it derives from the pick
        (:meth:`preemption_price` does) — :meth:`mark_tier_lost` racing a
        concurrent evacuation must not let the two disagree.
        """
        if allow is None:
            allow = self._allow_flags()
        tiers: list[MemoryTier] = []
        if allow["allow_host"]:
            tiers.append(MemoryTier.HOST)
        if allow["allow_peer"]:
            tiers += [MemoryTier.PEER_HOST, MemoryTier.PEER_HBM]
        if allow["allow_remote"]:
            tiers.append(MemoryTier.REMOTE_HBM)
        if not tiers:
            return Placement(MemoryTier.HBM)
        one_mb = 1 << 20   # round trip at a representative row size
        best = min(
            tiers,
            key=lambda t: self.price_copy(one_mb, t)
            + self.price_copy(one_mb, self.policy.placement(Role.KV_CACHE),
                              src=t),
        )
        return Placement(best)

    def preemption_price(self, nbytes: float) -> tuple[Placement, float]:
        """(spill placement, round-trip seconds) for parking ``nbytes``
        of KV rows off-cache and bringing them back — what the scheduler
        weighs against the planner-predicted natural slot-free time
        before evicting a victim.

        The spill-target pick and the price read the *same*
        ``_allow_flags()`` snapshot: a ``mark_tier_lost`` landing between
        them (tier-loss recovery runs concurrently with the scheduler's
        preemption scan) must not price a tier the pick no longer
        considers realizable, or vice versa.
        """
        allow = self._allow_flags()
        spill = self.spill_placement(allow=allow)
        kv = self.policy.placement(Role.KV_CACHE)
        return spill, (
            self.price_copy(nbytes, spill)
            + self.price_copy(nbytes, kv, src=spill)
        )

    def decode_step_seconds(
        self, batch_slots: int, max_len: int
    ) -> float:
        """Decode-step seconds under the current policy — the other side
        of the preemption ledger (how long until a slot frees naturally).

        Measurement-backed: once :meth:`observe_decode_step` has fed real
        Executor step timings for this (batch, len, policy) shape, the
        observed EWMA is returned; before any observation the planner's
        analytic prediction is the fallback.
        """
        observed = self.measured_step_s(batch_slots, max_len)
        if observed is not None:
            return observed
        return self._analytic_step_seconds(batch_slots, max_len)

    def _analytic_step_seconds(self, batch_slots: int, max_len: int
                               ) -> float:
        from repro.configs import ShapeSpec

        key = (batch_slots, max_len, self.policy.name)
        cached = self._step_estimates.get(key)
        if cached is not None:
            return cached
        prof = self.bundle.decode_workload(
            ShapeSpec("serve", max_len, batch_slots, "decode"),
            num_chips=self.num_chips,
        )
        est = predict(prof, self.policy, self.system).step_s
        self._step_estimates[key] = est
        return est

    def measured_step_s(self, batch_slots: int, max_len: int
                        ) -> float | None:
        """The observed decode-step EWMA for this shape under the current
        policy, or None before any observation."""
        return self._step_observed.get(
            (batch_slots, max_len, self.policy.name)
        )

    def observe_decode_step(
        self, batch_slots: int, max_len: int, seconds: float
    ) -> float:
        """Feed one measured decode-step time into the runtime.

        This is the serve Executor's per-step timing becoming a
        calibration observation: it updates the EWMA that
        :meth:`decode_step_seconds` (and through it
        :meth:`preemption_price` users like the scheduler's preemption
        ledger) returns, and logs predicted-vs-measured into
        :attr:`replay` so step-time drift shows up in the same report as
        the link calibrations.  Returns the updated EWMA.
        """
        seconds = float(seconds)
        if seconds <= 0.0:
            return self.decode_step_seconds(batch_slots, max_len)
        key = (batch_slots, max_len, self.policy.name)
        prev = self._step_observed.get(key)
        ewma = (seconds if prev is None
                else _EWMA_OLD * prev + _EWMA_NEW * seconds)
        self._step_observed[key] = ewma
        self.replay.record(
            "decode_step",
            f"decode[{self.policy.name},b{batch_slots},l{max_len}]",
            self._analytic_step_seconds(batch_slots, max_len),
            seconds,
            source="executor",
        )
        return ewma

    # -- calibration -------------------------------------------------------
    def calibrate(
        self,
        path=None,
        *,
        activate: bool = True,
        **kwargs,
    ):
        """Adopt a measurement-calibrated system for every pricing path.

        Runs :func:`repro.core.calibration.calibrate` (or loads the
        persisted ``calibration.json`` at ``path`` — which is also where
        a fresh run is saved), derives ``self.system`` via
        :meth:`SystemSpec.with_measurements`, and drops cached analytic
        step estimates so planner passes, ``price_copy``,
        ``preemption_price`` and ``decode_step_seconds`` all re-price
        under measured constants.  ``activate=True`` (default) also
        installs the calibrated system process-wide
        (:func:`repro.core.hardware.set_active_system`) so module-level
        helpers price consistently with this runtime.

        Calibration changes *pricing only* — realized placements and
        computed values are untouched (greedy serve tokens are
        bit-identical before/after; asserted in tests).  Returns the
        :class:`repro.core.calibration.Calibration`.
        """
        from repro.core.calibration import load_or_calibrate

        cal = load_or_calibrate(path, system=self.system, **kwargs)
        self.calibration = cal
        self.system = cal.apply(self.system)
        if activate:
            set_active_system(self.system)
        self._step_estimates.clear()
        self.replay.extend(cal.replay.records())
        log.info("calibrated hardware model:\n%s", cal.summary())
        return cal

    # -- live migration ----------------------------------------------------
    def migrate(
        self,
        tree,
        role: Role | str,
        to_policy: "PlacementPolicy | str | Mapping | Placement",
        defs=None,
        *,
        specs=None,
        fsdp_axes: Sequence[str] = (),
        donate: bool | None = None,
    ):
        """Re-place ``role``'s *live* tensors under ``to_policy`` mid-run.

        The runtime primitive behind phase-boundary re-placement (spill
        KV to host as occupancy grows, promote back as slots free, move
        params at a train→serve handover):

        * ``to_policy`` may be a full policy (any
          :func:`~repro.core.placement.parse_policy` spelling) or a bare
          :class:`~repro.core.placement.Placement` applied to ``role``
          on top of the current policy.
        * The target is validated against the mesh first — migrating to
          a peer/remote tier on a donor-less mesh raises
          :class:`~repro.core.placement.DonorAxisError`; a live buffer
          never silently lands in local memory.
        * The move is one ``device_put`` per leaf onto the new
          (donor-extended) shardings, **donation-aware**: when the
          *source* placement is donation-compatible (RESIDENT — nothing
          streams from the old buffer), the old tier's bytes are donated
          to the transfer and freed as the copy lands; a STREAM source
          keeps its resident buffer undonated until the new tree is up
          (in-flight staged windows still read it).
        * Registered ``Strategy.STREAM`` staging buffers for ``role``
          (see :meth:`open_stream`) are rebuilt around the migrated tree.

        Adopts the resulting policy as the runtime's current policy and
        returns the migrated tree; values are bit-identical (it is a
        copy, not a recompute).  Requires a mesh — with no mesh there is
        no second tier to move to.
        """
        if self.mesh is None:
            raise ValueError(
                "Runtime.migrate needs a mesh: with no mesh the runtime "
                "realizes no placements, so there is nothing to move "
                "between"
            )
        # pre-dispatch injection: before validation and before any
        # device_put, so a faulted migrate adopts nothing and donates
        # nothing — a retry sees the exact pre-call state.
        if self.faults:
            self.faults.check("migrate")
        role = parse_role(role)
        if isinstance(to_policy, Placement):
            new_policy = self.policy.with_placement(role, to_policy)
            new_policy = new_policy.renamed(
                f"{self.policy.name}+{role.value}={to_policy.to_str()}"
            )
        else:
            new_policy = parse_policy(to_policy)
        validate_policy_for_mesh(new_policy, self.mesh)

        if donate is None:
            # old STREAM buffers may still be feeding staged windows
            donate = donation_compatible(self.policy, role)
        if defs is None and specs is None and role is Role.PARAMS:
            defs = self.bundle.param_defs()
        if defs is not None:
            new_specs = _policy_specs(
                defs, self.mesh, self.rules, role, new_policy,
                fsdp_axes=fsdp_axes,
            )
            moved = jax.tree.map(
                lambda x, s: jax.device_put(x, s, donate=donate),
                tree, new_specs,
            )
        else:
            # def-less path: the same realizer realize() uses, donating
            moved = _put_like(
                tree, self.mesh, P() if specs is None else specs, role,
                new_policy, donate=donate,
            )

        old = self.policy.placement(role)
        self.policy = new_policy
        self._rebuild_stream(role, moved)
        log.info(
            "migrated %s: %s -> %s under policy %s",
            role.value, old.to_str(),
            new_policy.placement(role).to_str(), new_policy.name,
        )
        return moved

    def migrate_roles(
        self,
        trees: dict,
        target: "PlacementPolicy | str | Mapping",
        defs: Mapping | None = None,
        *,
        force: bool = False,
    ) -> list[Role]:
        """Migrate several roles' live trees to ``target`` in one pass.

        ``trees`` maps :class:`Role` to its live pytree and is mutated
        **in place** as each role lands — deliberately: a migrated role's
        old buffers may have been donated (freed), so the moved tree must
        survive a later role's failure.  Roles whose placement is
        unchanged are skipped unless ``force``.  ``defs`` maps roles to
        def pytrees (PARAMS defaults to the bundle's).

        On partial failure the adopted policy is the *old* policy with
        the already-moved placements swapped in — it always describes
        what the live buffers actually are — and the error re-raises.
        On success adopts ``target``.  Returns the roles moved.
        """
        if self.mesh is None:
            return []
        target = parse_policy(target)
        validate_policy_for_mesh(target, self.mesh)
        old = self.policy
        defs = defs or {}
        moved: list[Role] = []
        try:
            for role in list(trees):
                role = parse_role(role)
                if not force and target.placement(role) == old.placement(role):
                    continue
                trees[role] = self.migrate(
                    trees[role], role, target, defs.get(role),
                    donate=donation_compatible(old, role),
                )
                # migrate() adopted target; hold the handover until every
                # role lands so a failure can report the true partial state
                self.policy = old
                moved.append(role)
        except BaseException:
            partial = old
            for r in moved:
                partial = partial.with_placement(r, target.placement(r))
            if moved:
                partial = partial.renamed(
                    old.name + "+" + ",".join(
                        f"{r.value}={target.placement(r).to_str()}"
                        for r in moved
                    )
                )
            self.policy = partial
            raise
        self.policy = target
        return moved

    def evacuate(
        self,
        tier: "MemoryTier | str",
        trees: dict,
        defs: Mapping | None = None,
        *,
        phase: str | None = None,
        **phase_kw,
    ) -> tuple[PlacementPolicy, list[Role]]:
        """Abandon ``tier`` and re-place every affected role off it.

        The graceful-degradation primitive: :meth:`mark_tier_lost`
        excludes the tier (and its donor-axis siblings) from every
        future planner pass and spill pick, then the roles in ``trees``
        whose current placement sits on a lost tier are migrated to a
        realizable target — the planner's re-pick for ``phase`` when
        given (priced by the same ``migrate`` cost model as any replan),
        else the current policy with each lost placement swapped to
        local HBM (the placement that always exists).  Reuses
        :meth:`migrate_roles`' adopt-nothing-on-failure semantics.

        Tier loss is a *degradation notice*, not a crash: the lost
        tier's buffers are assumed still readable (the GH200 failure
        mode is an order-of-magnitude slowdown, not data loss), so the
        evacuation copy itself may read from them one last time.
        Returns ``(adopted policy, roles moved)``.
        """
        tier = self.mark_tier_lost(tier)
        old = self.policy
        affected = [
            r for r in trees if old.placement(parse_role(r)).tier
            in self.lost_tiers
        ]
        if self.mesh is None or not affected:
            return old, []
        if phase is not None:
            try:
                self.plan_phase(phase, log_table=False, **phase_kw)
                target = self.policy
            finally:
                self.policy = old
            # the planner minimizes step time, not realizability of the
            # degraded set: guard against a pick that still touches a
            # lost tier (possible only with explicit candidates)
            if any(
                target.placement(parse_role(r)).tier in self.lost_tiers
                for r in trees
            ):
                target = None
        else:
            target = None
        if target is None:
            target = old
            for r, p in old.placements.items():
                if p.tier in self.lost_tiers:
                    target = target.with_placement(
                        r, Placement(MemoryTier.HBM)
                    )
            target = target.renamed(f"{old.name}-evac-{tier.value}")
        moved = self.migrate_roles(trees, target, defs)
        log.warning(
            "evacuated %s off %s: policy %s -> %s",
            ",".join(r.value for r in moved) or "nothing",
            tier.value, old.name, self.policy.name,
        )
        return self.policy, moved

    # -- streaming ---------------------------------------------------------
    def open_stream(
        self,
        tree,
        role: Role | str,
        n_windows: int,
        *,
        specs=P(),
        depth: int = 2,
    ) -> DonorStream:
        """Double-buffered window streamer over ``role``'s donor-resident
        stack, registered with the runtime so :meth:`migrate` rebuilds
        its staging buffers around the migrated tree (stale staged
        windows from the old tier are dropped)."""
        role = parse_role(role)
        stream = DonorStream(tree, self.mesh, specs, n_windows, depth=depth)
        self._streams[role] = (stream, (specs, n_windows, depth))
        return stream

    def stream(self, role: Role | str) -> DonorStream | None:
        """The registered stream for ``role`` (None when none is open)."""
        entry = self._streams.get(parse_role(role))
        return entry[0] if entry else None

    def _rebuild_stream(self, role: Role, tree) -> None:
        entry = self._streams.get(role)
        if entry is None:
            return
        _, (specs, n_windows, depth) = entry
        self._streams[role] = (
            DonorStream(tree, self.mesh, specs, n_windows, depth=depth),
            (specs, n_windows, depth),
        )


def _scale_kv(profile, utilization: float):
    """Scale a profile's KV-cache bytes to the live cache occupancy
    (replan pricing); clamped to [1/16, 1] so an empty server still
    prices a nonzero cache."""
    u = min(max(float(utilization), 1.0 / 16.0), 1.0)
    if u >= 1.0 or Role.KV_CACHE not in profile.bytes_per_role:
        return profile
    scaled = dict(profile.bytes_per_role)
    scaled[Role.KV_CACHE] = scaled[Role.KV_CACHE] * u
    return dataclasses.replace(profile, bytes_per_role=scaled)
