"""Int8 gradient compression with error feedback for the DCN (pod) axis.

The paper's internode measurements (Figs. 14, 19) show the NIC is the
weakest datapath — two orders of magnitude under HBM.  The TPU analogue is
the inter-pod DCN link, which carries exactly one traffic class in training:
the cross-pod gradient all-reduce.  This module quantizes that traffic to
int8 (4x fewer wire bytes) with error feedback so the quantization error is
re-injected next step (1-bit-Adam-style convergence behavior).

Mechanics: inside a ``shard_map`` over the ``pod`` axis, the all-reduce is
decomposed into all-to-all(int8 segments) -> local f32 sum -> requantize ->
all-gather(int8): every wire crossing is int8, every accumulation is f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import (
    axis_size_compat,
    shard_map_compat,
    shard_map_partial_ok,
)


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantized_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` with int8 wire traffic (call inside shard_map).

    x is this shard's f32 gradient (replicated-layout w.r.t. the axis).
    """
    n = axis_size_compat(axis_name)
    if n == 1:
        return x
    orig_shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad))
    segs = flat.reshape(n, -1)                       # segment s for rank s

    q, scale = quantize(segs)
    # everyone sends segment s to rank s: all_to_all over leading dim
    q_recv = jax.lax.all_to_all(
        q, axis_name, split_axis=0, concat_axis=0, tiled=False
    )                                                # (n, seg) int8 on wire
    scales = jax.lax.all_gather(scale, axis_name)    # (n,) f32 (tiny)
    local_sum = jnp.sum(
        q_recv.astype(jnp.float32) * scales[:, None], axis=0
    ) / n                                            # mean, f32 accumulate

    q2, scale2 = quantize(local_sum)
    q_all = jax.lax.all_gather(q2, axis_name)        # (n, seg) int8 on wire
    scale_all = jax.lax.all_gather(scale2, axis_name)
    out = (q_all.astype(jnp.float32) * scale_all[:, None]).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(orig_shape)


def init_error_feedback(grads):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads
    )


def compressed_grad_sync(
    grads,
    ef,
    mesh: Mesh,
    axis: str = "pod",
):
    """Cross-pod gradient mean with int8 wire + error feedback.

    ``grads`` are the per-pod means (already synced over in-pod axes by
    pjit); ``ef`` is the persistent error-feedback pytree.  Returns
    (synced_grads, new_ef).  No-op (exact mean preserved) if the mesh has
    no ``axis``.
    """
    if axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return grads, ef

    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    def leaf_sync(g, e):
        gf = g.astype(jnp.float32) + e
        synced = quantized_all_reduce(gf, axis)
        new_e = gf - synced                      # residual re-injected later
        return synced.astype(g.dtype), new_e

    def tree_sync(gs, es):
        return jax.tree.map(leaf_sync, gs, es, is_leaf=None), None

    # shard_map: everything replicated over `axis` (grads are identical
    # within a pod after pjit's automatic in-pod reduction).  Two maps, not
    # one returning tuples — tree.map would recurse INTO the tuples; XLA
    # CSEs the duplicated sync.
    def fn(gs, es):
        new_g = jax.tree.map(lambda g, e: leaf_sync(g, e)[0], gs, es)
        new_e = jax.tree.map(lambda g, e: leaf_sync(g, e)[1], gs, es)
        return new_g, new_e

    spec = P()  # replicated over every axis; collectives only over `axis`
    specs_g = jax.tree.map(lambda _: spec, grads)
    specs_e = jax.tree.map(lambda _: spec, ef)
    # Partial-manual shard_map (manual over `axis` only) miscompiles on old
    # jax/XLA (spmd_partitioner manual-subgroup check failure); there, run
    # fully manual — the P() specs replicate the grads first, which costs an
    # all-gather over the non-pod axes but keeps identical numerics.
    axis_names = {axis} if shard_map_partial_ok else None
    fn_mapped = shard_map_compat(
        fn,
        mesh=mesh,
        in_specs=(specs_g, specs_e),
        out_specs=(specs_g, specs_e),
        axis_names=axis_names,
        check=False,
    )
    return fn_mapped(grads, ef)
