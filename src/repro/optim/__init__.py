from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compressed_grad_sync,
    dequantize,
    init_error_feedback,
    quantize,
    quantized_all_reduce,
)
