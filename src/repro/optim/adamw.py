"""Sharded AdamW with placement-aware optimizer-state offload.

Mixed precision: live params bf16; f32 master copy + two f32 moments.
That is 12 bytes/param of optimizer state against 2 bytes/param of live
weights — precisely the tensors the paper's placement tradeoff targets
(read twice per step, never touched by forward compute).  Under the
``opt_host`` policy the state pytree carries ``memory_kind='pinned_host'``
shardings; the update step moves each tensor to HBM, updates, and moves it
back — under jit these become host<->HBM DMAs the scheduler overlaps with
the rest of the step (TPU "managed memory" in the paper's Table II sense).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.placement import PlacementPolicy, Role, Strategy


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> dict:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t
    )
    return {
        "master": f32(params),
        "mu": zeros(params),
        "nu": zeros(params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(sum(leaves))


def apply_updates(
    params,
    grads,
    state: dict,
    cfg: AdamWConfig,
    *,
    to_compute=None,
    to_storage=None,
):
    """One AdamW step. ``to_compute``/``to_storage`` are the placement
    hooks: identity for HBM-resident state, host<->device moves for
    offloaded state (see train_step)."""
    to_compute = to_compute or (lambda t: t)
    to_storage = to_storage or (lambda t: t)

    step = state["step"] + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)

    master = to_compute(state["master"])
    mu = to_compute(state["mu"])
    nu = to_compute(state["nu"])

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                         + cfg.weight_decay * p)

    master = jax.tree.map(upd, master, mu, nu)
    new_params = jax.tree.map(
        lambda mp, p: mp.astype(p.dtype), master, params
    )
    new_state = {
        "master": to_storage(master),
        "mu": to_storage(mu),
        "nu": to_storage(nu),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_state_memory_kind(policy: PlacementPolicy) -> str:
    return policy.memory_kind(Role.OPT_STATE)
