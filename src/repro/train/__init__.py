from repro.train.train_step import (  # noqa: F401
    TrainConfig,
    init_train_state,
    make_state_specs,
    make_train_step,
)
from repro.train.pipeline_parallel import pipelined_forward  # noqa: F401
