"""Training step: value_and_grad + sharded AdamW + placement policies.

Structure per step (paper-faithful baseline, then the optimization levers):

* loss/grads under pjit — TP collectives on the ``model`` axis (ICI),
  gradient reduction over ``data``(+``pod``) inserted by SPMD;
* optional **microbatch accumulation**: grads of microbatch *i* are summed
  while *i+1*'s forward runs — XLA's latency-hiding scheduler overlaps the
  per-microbatch reduction with compute (the collective-overlap trick);
* optional **cross-pod int8 compression** (optim/compression.py) applied to
  the DCN-axis reduction inside a manual-``pod`` shard_map;
* AdamW update with the placement policy's storage hooks (host-offloaded
  master/moments stream through PCIe once per step).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api import Runtime
from repro.core.placement import (
    HBM_RESIDENT,
    PlacementPolicy,
    Role,
    Strategy,
    resolve_memory_kind,
)
from repro.models.model_zoo import ModelBundle
from repro.models.sharding import (
    spec_for,
    use_sharding,
)
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state
from repro.optim.compression import compressed_grad_sync, init_error_feedback


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    remat: str = "full"             # none | full | dots
    n_microbatches: int = 1
    compress_pod_grads: bool = False
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    rules: dict | None = None       # sharding-rule overrides (hillclimb knob)
    fsdp_axes: tuple = ("data",)    # ZeRO axes for optimizer state (+ params)
    zero_stage: int = 3             # 3: shard params+opt; 1: opt only
                                    # (ZeRO-1 drops the per-layer param
                                    #  all-gathers at the cost of replicated
                                    #  bf16 params across the data axis)


def make_state_specs(
    bundle: ModelBundle,
    mesh: Mesh,
    policy: PlacementPolicy = HBM_RESIDENT,
    rules: dict | None = None,
    fsdp_axes: tuple = ("data",),
    zero_stage: int = 3,
):
    """NamedShardings for (params, opt_state) under the placement policy.

    Realized via :meth:`repro.api.Runtime.specs`, so a peer/remote
    placement (e.g. ``opt_peer_host``'s spill of master+moments to a
    donor's host DRAM) lands on the mesh's donor axis — and raises
    ``DonorAxisError`` when the mesh has none, instead of silently
    staying local.
    """
    rt = Runtime(bundle, mesh, policy, rules=rules)
    defs = bundle.param_defs()
    param_specs = rt.specs(
        Role.PARAMS, defs,
        fsdp_axes=fsdp_axes if zero_stage >= 3 else (),
    )
    opt_member = rt.specs(Role.OPT_STATE, defs, fsdp_axes=fsdp_axes)
    opt_specs = {
        "master": opt_member,
        "mu": opt_member,
        "nu": opt_member,
        "step": NamedSharding(mesh, P()),
    }
    return param_specs, opt_specs


def _batch_spec(batch, mesh: Mesh, rules):
    def one(x):
        axes = ("batch",) + (None,) * (x.ndim - 1)
        return NamedSharding(mesh, spec_for(x.shape, axes, mesh, rules))

    return jax.tree.map(one, batch)


def make_train_step(
    bundle: ModelBundle,
    mesh: Mesh,
    tcfg: TrainConfig,
    policy: PlacementPolicy = HBM_RESIDENT,
):
    """Returns a jit-able fn: (params, opt_state, ef, batch) ->
    (params, opt_state, ef, metrics)."""

    opt_on_host = policy.placement(Role.OPT_STATE).on_host
    # expose the FSDP axes to model bodies through the rule table (used by
    # shard_defs inside scan bodies) and keep specs consistent with it.
    rules = dict(tcfg.rules or {})
    rules["fsdp"] = tuple(tcfg.fsdp_axes) if tcfg.zero_stage >= 3 else ()
    param_specs, _ = make_state_specs(
        bundle, mesh, policy, rules, tcfg.fsdp_axes, tcfg.zero_stage
    )
    grad_specs = jax.tree.map(
        lambda s: NamedSharding(mesh, s.spec), param_specs
    )

    # In-jit H2D (to_compute) lowers on every backend; the in-jit D2H
    # return trip (to_storage) only lowers on TPU — elsewhere the state
    # returns in device memory and repin_opt_state moves it back outside
    # jit (same bytes over the same link, without the scheduler overlap).
    in_jit_storage = jax.default_backend() == "tpu"

    def to_compute(tree):
        if not opt_on_host:
            return tree
        # host -> HBM, preserving each leaf's sharding spec
        kind = resolve_memory_kind("device")

        def mv(x):
            s = getattr(x, "sharding", None)
            spec = s.spec if isinstance(s, NamedSharding) else P()
            return jax.device_put(
                x, NamedSharding(mesh, spec, memory_kind=kind)
            )
        return jax.tree.map(mv, tree)

    def to_storage(tree):
        if not opt_on_host or not in_jit_storage:
            return tree
        kind = resolve_memory_kind("pinned_host")

        def mv(x):
            s = getattr(x, "sharding", None)
            spec = s.spec if isinstance(s, NamedSharding) else P()
            return jax.device_put(
                x, NamedSharding(mesh, spec, memory_kind=kind)
            )
        return jax.tree.map(mv, tree)

    def loss_fn(params, batch):
        loss, metrics = bundle.train_loss(params, batch, remat=tcfg.remat)
        return loss, metrics

    def step(params, opt_state, ef, batch):
        with use_sharding(mesh, rules):
            if tcfg.n_microbatches > 1:
                n = tcfg.n_microbatches

                def micro(carry, mb):
                    gsum, _ = carry
                    (loss, metrics), g = jax.value_and_grad(
                        loss_fn, has_aux=True
                    )(params, mb)
                    gsum = jax.tree.map(jnp.add, gsum, g)
                    return (gsum, metrics), loss

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                mbs = jax.tree.map(
                    lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]),
                    batch,
                )
                (gsum, metrics), losses = jax.lax.scan(
                    micro, (zeros, {"ce": 0.0, "aux": 0.0}), mbs
                )
                grads = jax.tree.map(lambda g: g / n, gsum)
                loss = jnp.mean(losses)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, batch)

            # pin gradient shardings to the (FSDP) param layout; without
            # this XLA materializes full f32 replicated grad stacks before
            # the optimizer (observed: 5.4 GiB/device all-gathers).
            grads = jax.tree.map(
                jax.lax.with_sharding_constraint, grads, grad_specs
            )

            if tcfg.compress_pod_grads:
                grads, ef = compressed_grad_sync(grads, ef, mesh, "pod")

            new_params, new_opt, opt_metrics = apply_updates(
                params, grads, opt_state, tcfg.optimizer,
                to_compute=to_compute, to_storage=to_storage,
            )
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_params, new_opt, ef, out_metrics

    return step


def repin_opt_state(opt_state, opt_specs):
    """Re-place optimizer state per its policy shardings OUTSIDE jit —
    the CPU-backend path for host-offloaded state (no-op when shardings
    already match, e.g. hbm_resident or TPU in-jit round-trip)."""
    return jax.tree.map(jax.device_put, opt_state, opt_specs)


def init_train_state(
    bundle: ModelBundle,
    mesh: Mesh,
    key,
    tcfg: TrainConfig,
    policy: PlacementPolicy = HBM_RESIDENT,
):
    """Initialize params + optimizer state with policy placements applied."""
    param_specs, opt_specs = make_state_specs(
        bundle, mesh, policy, tcfg.rules, tcfg.fsdp_axes, tcfg.zero_stage
    )
    with use_sharding(mesh, tcfg.rules):
        params = bundle.init_params(key)
        params = jax.tree.map(jax.device_put, params, param_specs)
        opt_state = init_opt_state(params)
        opt_state = jax.tree.map(jax.device_put, opt_state, opt_specs)
        ef = (
            init_error_feedback(params)
            if tcfg.compress_pod_grads
            else jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
        )
    return params, opt_state, ef
