"""GPipe pipeline parallelism over the ``pod`` axis (shard_map + ppermute).

For multi-pod meshes the ``pod`` axis crosses DCN — the weakest link in the
datapath model.  Pure DP on that axis all-reduces *every gradient byte*
across it each step; pipelining instead sends only **microbatch activations**
across the cut, shrinking DCN traffic by params/activations ratio (the
planner quantifies this; §Perf uses it as a lever).

Implementation: parameters are stacked over a leading ``stage`` dimension
sharded onto the pipeline axis; microbatches advance through stages with
``jax.lax.ppermute`` handoffs in a (n_micro + n_stages - 1)-tick schedule.
Differentiable (ppermute transposes to the reverse permute), validated
against the sequential model in tests.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.compat import shard_map_compat, shard_map_partial_ok
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable,          # (stage_params, x) -> x
    axis_name: str,
    n_stages: int,
    n_micro: int,
):
    """Build the per-shard pipelined apply: (stacked_params, x_micro) -> y.

    Call inside ``shard_map`` with the stage dim of params sharded over
    ``axis_name`` and microbatches stacked on the leading dim of x.
    """

    def apply(params_local, x_micro):
        # params_local: (1, ...) this stage's slice; x_micro: (n_micro, B, ...)
        params_local = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis_name)
        n_ticks = n_micro + n_stages - 1
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        buf = jnp.zeros_like(x_micro[0])
        outs = jnp.zeros_like(x_micro)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = jnp.where(
                jnp.logical_and(stage == 0, t < n_micro),
                x_micro[mb_idx],
                buf,
            )
            y = stage_fn(params_local, inject)
            # last stage emits microbatch (t - n_stages + 1)
            out_idx = jnp.clip(t - n_stages + 1, 0, n_micro - 1)
            emit = jnp.logical_and(stage == n_stages - 1, t >= n_stages - 1)
            outs = jax.lax.cond(
                emit,
                lambda o: o.at[out_idx].set(y),
                lambda o: o,
                outs,
            )
            buf = jax.lax.ppermute(y, axis_name, fwd_perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(
            tick, (buf, outs), jnp.arange(n_ticks)
        )
        # only the last stage holds real outputs (others kept zeros):
        # one psum broadcasts them to every stage.
        return jax.lax.psum(outs, axis_name)

    return apply


def pipelined_forward(
    mesh: Mesh,
    stage_fn: Callable,
    stacked_params,              # leading dim = n_stages
    x_micro,                     # (n_micro, B_local, ...)
    axis_name: str = "pod",
):
    """shard_map wrapper: returns outputs gathered from the last stage.

    Non-pipeline mesh axes stay automatic (the body still runs TP/DP via
    pjit-style constraint propagation within each stage).
    """
    n_stages = mesh.shape[axis_name]
    n_micro = x_micro.shape[0]
    apply = pipeline_apply(stage_fn, axis_name, n_stages, n_micro)

    pspec = jax.tree.map(lambda _: P(axis_name), stacked_params)
    # New API: axis_names={pipe axis} keeps the other mesh axes automatic,
    # so stage bodies still run TP/DP via constraint propagation.  Old jax
    # rejects partial-manual shard_map on multi-axis meshes — there, run
    # fully manual: the P() specs replicate the microbatches over the
    # non-pipe axes (no in-stage TP/DP, identical numerics).
    fn = shard_map_compat(
        apply,
        mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        axis_names={axis_name} if shard_map_partial_ok else None,
        check=False,
    )
    outs = fn(stacked_params, x_micro)
    return outs
