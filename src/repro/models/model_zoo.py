"""Unified model bundle: one object per architecture, four entry points.

``ModelBundle`` is what the launcher, dry-run, trainer, server, tests and
benchmarks all consume: param/cache/input *defs* (shape+sharding
declarations — materializable as arrays, ShapeDtypeStructs, or
NamedShardings) plus the jit-able ``train_loss`` / ``prefill`` /
``decode_step`` functions, plus the analytic MODEL_FLOPS used by the
roofline's useful-compute ratio.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeSpec, get_config
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.models.multimodal import frontend_embeds, frontend_input_defs
from repro.models.sharding import Param, materialize


@dataclasses.dataclass
class ModelBundle:
    cfg: ArchConfig

    # -- defs ----------------------------------------------------------------
    def param_defs(self):
        if self.cfg.family == "audio" and self.cfg.n_encoder_layers:
            return encdec_mod.encdec_defs(self.cfg)
        return tf_mod.lm_defs(self.cfg)

    def cache_defs(self, batch: int, max_len: int):
        if self.cfg.family == "audio" and self.cfg.n_encoder_layers:
            return encdec_mod.encdec_cache_defs(self.cfg, batch, max_len)
        return tf_mod.lm_cache_defs(self.cfg, batch, max_len)

    def input_defs(self, shape: ShapeSpec) -> dict:
        """Batch-input defs for one assigned (shape) cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        enc_dec = cfg.family == "audio" and cfg.n_encoder_layers > 0
        text_len = S if enc_dec else S - cfg.frontend_tokens
        toks = ("batch", "seq")

        if shape.mode == "train":
            d = {
                "tokens": Param((B, text_len), toks, dtype="int32"),
                "labels": Param((B, text_len), toks, dtype="int32"),
            }
            d.update(frontend_input_defs(cfg, B))
            return d
        if shape.mode == "prefill":
            d = {"tokens": Param((B, text_len), toks, dtype="int32")}
            d.update(frontend_input_defs(cfg, B))
            return d
        # decode: one new token against a cache of S entries
        return {
            "tokens": Param((B, 1), toks, dtype="int32"),
            "lengths": Param((B,), ("batch",), dtype="int32"),
        }

    def decode_cache_len(self, shape: ShapeSpec) -> int:
        return shape.seq_len

    # -- materialization -------------------------------------------------
    def init_params(self, key, dtype=None):
        return materialize(self.param_defs(), key, dtype or self.cfg.dtype)

    def init_cache(self, batch: int, max_len: int, dtype=None):
        defs = self.cache_defs(batch, max_len)
        return materialize(defs, jax.random.PRNGKey(0), dtype or self.cfg.dtype)

    # -- compute entry points ---------------------------------------------
    def train_loss(self, params, batch: dict, *, remat: str = "full"):
        cfg = self.cfg
        if cfg.family == "audio" and cfg.n_encoder_layers:
            return encdec_mod.encdec_train_loss(
                params, batch["frame_embeds"], batch["tokens"],
                batch["labels"], cfg,
            )
        return tf_mod.lm_loss(
            params, batch["tokens"], batch["labels"], cfg,
            extra_embeds=frontend_embeds(batch), remat=remat,
        )

    def prefill(self, params, batch: dict, caches):
        cfg = self.cfg
        if cfg.family == "audio" and cfg.n_encoder_layers:
            return encdec_mod.encdec_prefill(
                params, batch["frame_embeds"], batch["tokens"], caches, cfg
            )
        return tf_mod.lm_prefill(
            params, batch["tokens"], caches, cfg,
            extra_embeds=frontend_embeds(batch),
        )

    def prefill_at(self, params, batch: dict, caches, offsets):
        """Chunked batched prefill at per-row cache offsets.

        ``batch`` holds ``tokens`` (B, S) — one prompt chunk per row — and
        ``new_lens`` (B,) — how many of the chunk's positions are real for
        each row (0 = leave the row untouched).  ``offsets`` (B,) is each
        row's current cache fill.  Returns (last-valid-position logits,
        updated caches).  Encoder-decoder (audio) bundles route through
        :func:`~repro.models.encdec.encdec_prefill_at`: the decoder's
        self cache fills chunk-at-offset like the LM path, and the
        cross-attention KV — read-only during generation — rides through
        unchanged, so token-only serving no longer needs the O(B·L)
        decode-step replay.
        """
        cfg = self.cfg
        if cfg.family == "audio" and cfg.n_encoder_layers:
            return encdec_mod.encdec_prefill_at(
                params, batch["tokens"], caches, offsets,
                batch["new_lens"], cfg,
            )
        return tf_mod.lm_prefill_at(
            params, batch["tokens"], caches, offsets, batch["new_lens"], cfg
        )

    def decode_step(self, params, batch: dict, caches):
        cfg = self.cfg
        if cfg.family == "audio" and cfg.n_encoder_layers:
            return encdec_mod.encdec_decode_step(
                params, batch["tokens"], caches, batch["lengths"], cfg
            )
        return tf_mod.lm_decode_step(
            params, batch["tokens"], caches, batch["lengths"], cfg
        )

    # -- analytics ---------------------------------------------------------
    def model_bytes(self, shape: ShapeSpec) -> float:
        """Bytes that must cross the HBM bus per step: one read of the
        active parameters (+ the decode-state read for decode shapes)."""
        itemsize = 2  # bf16
        nbytes = self.cfg.active_params() * itemsize
        if shape.mode == "decode":
            nbytes += self.cache_bytes(shape)
        return nbytes

    def cache_bytes(self, shape: ShapeSpec) -> float:
        return self.cache_bytes_for(shape.global_batch, shape.seq_len)

    def cache_bytes_for(self, batch: int, max_len: int) -> float:
        """Total decode-cache bytes for an explicit (batch, max_len)."""
        import math as _m

        defs = self.cache_defs(batch, max_len)
        leaves = jax.tree.leaves(
            defs, is_leaf=lambda x: hasattr(x, "axes")
        )
        total = 0.0
        for p in leaves:
            width = 4 if str(p.dtype) == "float32" else 2
            total += _m.prod(p.shape) * width
        return total

    def model_flops(self, shape: ShapeSpec) -> float:
        """MODEL_FLOPS per step: 6·N·D train (N=active for MoE), 2·N·D fwd."""
        n = self.cfg.active_params()
        if shape.mode == "train":
            return 6.0 * n * shape.global_batch * shape.seq_len
        if shape.mode == "prefill":
            return 2.0 * n * shape.global_batch * shape.seq_len
        return 2.0 * n * shape.global_batch  # one token per row

    # -- planner profiles ---------------------------------------------------
    # The single source of the workload accounting (param/activation bytes,
    # flops, streaming granularity) consumed by the launchers, the policy
    # benchmarks, and the placement-sweep example.

    def train_workload(
        self,
        shape: ShapeSpec,
        *,
        num_chips: int = 1,
        data_axis_size: int = 1,
        pod_axis_size: int = 1,
        remat: bool = True,
    ):
        """Planner :func:`~repro.core.planner.train_profile` for ``shape``."""
        from repro.core.planner import train_profile

        cfg = self.cfg
        return train_profile(
            name=cfg.name,
            param_bytes=cfg.num_params() * 2,
            step_flops=self.model_flops(shape),
            activation_bytes=2.0 * shape.global_batch * shape.seq_len
            * cfg.d_model * cfg.n_layers,
            num_chips=num_chips,
            remat=remat,
            n_layers=max(cfg.n_layers, 1),
            data_axis_size=data_axis_size,
            pod_axis_size=pod_axis_size,
        )

    def decode_workload(self, shape: ShapeSpec, *, num_chips: int = 1):
        """Planner :func:`~repro.core.planner.decode_profile` for ``shape``."""
        from repro.core.planner import decode_profile

        cfg = self.cfg
        return decode_profile(
            name=cfg.name,
            param_bytes=cfg.num_params() * 2,
            kv_bytes=self.cache_bytes(shape),
            step_flops=self.model_flops(shape),
            num_chips=num_chips,
            n_layers=max(cfg.n_layers, 1),
        )

    def prefill_workload(
        self, shape: ShapeSpec, *, chunk_tokens: int, num_chips: int = 1
    ):
        """Planner :func:`~repro.core.planner.prefill_profile` for one
        chunked-prefill dispatch of ``chunk_tokens`` per row of ``shape``'s
        batch (the serve engine's admission phase)."""
        from repro.core.planner import prefill_profile

        cfg = self.cfg
        chunk_shape = ShapeSpec(
            shape.name, chunk_tokens, shape.global_batch, "prefill"
        )
        return prefill_profile(
            name=cfg.name,
            param_bytes=cfg.num_params() * 2,
            kv_bytes=self.cache_bytes(shape),
            chunk_flops=self.model_flops(chunk_shape),
            activation_bytes=2.0 * shape.global_batch * chunk_tokens
            * cfg.d_model * cfg.n_layers,
            num_chips=num_chips,
            n_layers=max(cfg.n_layers, 1),
        )


def get_bundle(arch: str) -> ModelBundle:
    return ModelBundle(get_config(arch))


def get_smoke_bundle(arch: str) -> ModelBundle:
    from repro.configs import smoke_config

    return ModelBundle(smoke_config(arch))
