"""Mixture-of-Experts: GShard-style grouped dispatch with expert parallelism.

TPU-native formulation (no torch.distributed semantics): routing produces
one-hot dispatch/combine tensors per token *group*; einsums against them
reshape tokens to (experts, capacity, d); sharding constraints place the
expert dimension on the 'model' mesh axis, so XLA SPMD materializes the
dispatch as an **all-to-all over ICI** — the highest-volume collective of
MoE archs and exactly the class of traffic the paper's Fig. 18/19 studies.

Capacity overflow drops tokens (standard GShard); the aux load-balancing
loss is returned to the train step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import MoESpec
from repro.models.layers import apply_mlp, mlp_defs
from repro.models.sharding import Param, shard


def moe_defs(d: int, spec: MoESpec) -> dict:
    ff = spec.d_ff_expert
    defs = {
        "router": Param((d, spec.n_experts), ("embed", None)),
        "w_gate": Param(
            (spec.n_experts, d, ff), ("experts", "embed", "d_ff")
        ),
        "w_up": Param(
            (spec.n_experts, d, ff), ("experts", "embed", "d_ff")
        ),
        "w_down": Param(
            (spec.n_experts, ff, d), ("experts", "d_ff", "embed")
        ),
    }
    if spec.n_shared:
        defs["shared"] = mlp_defs(d, spec.n_shared * ff)
    return defs


#: tokens per dispatch group.  Dispatch-tensor bytes scale with
#: total_tokens x E x C and C ∝ G/E, so bytes ∝ tokens x G: smaller groups
#: mean less dispatch traffic (at some routing-drop cost) — a direct
#: data-movement knob in the paper's sense, swept in §Perf.
DEFAULT_GROUP = 2048


def capacity(group: int, spec: MoESpec) -> int:
    c = int(group * spec.top_k / spec.n_experts * spec.capacity_factor)
    c = max(spec.top_k, c, 4)
    return (c + 3) // 4 * 4


def apply_moe(
    params: dict,
    x: jax.Array,
    spec: MoESpec,
    act: str = "silu",
    group_size: int = DEFAULT_GROUP,
):
    """x: (B, S, d) -> (out, aux_loss). Tokens regrouped to fixed-size
    dispatch groups (GShard); group dim carries the 'batch' sharding."""
    B, S, d = x.shape
    E, K = spec.n_experts, spec.top_k
    T = B * S
    G = min(group_size, T)
    n_groups = T // G
    assert T % G == 0, (T, G)
    C = capacity(G, spec)

    xg = x.reshape(n_groups, G, d)
    xg = shard(xg, "batch", None, "embed")

    logits = jnp.einsum("gsd,de->gse", xg, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k choice per token
    gate_vals, gate_idx = jax.lax.top_k(probs, K)           # (g,G,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # position of each (token, choice) in its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (g,G,K,E)
    pos = jnp.cumsum(
        onehot.reshape(n_groups, G * K, E), axis=1
    ).reshape(n_groups, G, K, E) * onehot - 1.0
    in_cap = (pos < C) & (pos >= 0)

    # dispatch (g,G,E,C) = Σ_k onehot_e ⊗ onehot_c — contracted over K so
    # the (g,G,K,E,C) 5-D tensor is never materialized (naively it is
    # hundreds of TiB for deepseek-v2's E=160, top-6 at 1M tokens).
    pos_sk = jnp.sum(jnp.where(in_cap, pos, 0.0), axis=3)     # (g,G,K)
    onehot_c = jax.nn.one_hot(
        pos_sk.astype(jnp.int32), C, dtype=jnp.float32
    )                                                          # (g,G,K,C)
    keep_e = onehot * in_cap.astype(jnp.float32)               # (g,G,K,E)
    dispatch = jnp.einsum("gske,gskc->gsec", keep_e, onehot_c)
    combine = jnp.einsum(
        "gske,gskc->gsec", keep_e * gate_vals[..., None], onehot_c
    )

    xin = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    xin = shard(xin, "batch", "experts", "expert_cap", "embed")

    # expert FFN (gated GLU) — experts sharded over 'model'
    g_ = jnp.einsum("gecd,edf->gecf", xin, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xin, params["w_up"])
    actfn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    h = shard(actfn(g_) * u, "batch", "experts", "expert_cap", "d_ff")
    eout = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    eout = shard(eout, "batch", "experts", "expert_cap", "embed")

    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), eout)
    out = out.reshape(B, S, d)
    out = shard(out, "batch", "seq", "embed")

    if spec.n_shared:
        out = out + apply_mlp(params["shared"], x, act)

    # GShard load-balancing aux loss
    me = probs.mean(axis=(0, 1))                              # (E,)
    ce = onehot.sum(2).mean(axis=(0, 1))                      # fraction routed
    aux = E * jnp.sum(me * ce)
    return out, aux
