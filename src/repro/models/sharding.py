"""Logical-axis sharding: one rule table instead of per-site PartitionSpecs.

Tensors are annotated with *logical* axis names ("batch", "heads", "d_ff",
"experts", ...) and a swappable rule table maps those to mesh axes.  This is
what makes sharding a hillclimbable config knob (§Perf): changing
``data→("pod","data")`` vs sequence-parallel vs FSDP is a rules swap, not a
model edit.

Divisibility-safety: a rule is silently dropped for a tensor dimension it
does not divide (e.g. kv_heads=2 over a 16-way model axis — Megatron-style
KV replication emerges naturally), and for axes absent from the active mesh
(e.g. "pod" on the single-pod mesh).
"""

from __future__ import annotations

import contextlib
import dataclasses
import logging
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_log = logging.getLogger("repro.models.sharding")


#: default rules — the paper-faithful baseline: TP over the fast 'model'
#: axis, DP over 'data'+'pod', no FSDP, no sequence parallelism.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": (),
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "d_ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_cap": (),
    "lora": (),
    "ssm_heads": ("model",),
    "d_inner": ("model",),
    "state": (),
    "conv": (),
    "layers": (),
    "fsdp": (),       # extra param-dim sharding axis; () = ZeRO off
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: dict[str, tuple[str, ...]] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def use_sharding(mesh: Mesh | None, rules: Mapping[str, Sequence[str]] | None = None):
    """Install mesh + rules for trace-time constraint resolution."""
    old_mesh, old_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    if rules is not None:
        merged = dict(DEFAULT_RULES)
        merged.update({
            k: tuple(v) if isinstance(v, (list, tuple)) else v
            for k, v in rules.items()
        })
        _CTX.rules = merged
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old_mesh, old_rules


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def current_rules() -> dict[str, tuple[str, ...]]:
    return _CTX.rules


def spec_for(
    shape: Sequence[int],
    axes: Sequence[str | None],
    mesh: Mesh | None = None,
    rules: Mapping[str, Sequence[str]] | None = None,
) -> P:
    """PartitionSpec for ``shape`` under the rules, divisibility-checked.

    ``rules`` is treated as an OVERLAY on DEFAULT_RULES — callers pass only
    the overrides (e.g. {"seq": ("model",)}) without losing the TP rules.
    """
    mesh = mesh or _CTX.mesh
    if rules is None:
        rules = _CTX.rules
    else:
        rules = {**DEFAULT_RULES, **{
            k: tuple(v) if isinstance(v, (list, tuple)) else v
            for k, v in rules.items()
        }}
    if mesh is None:
        return P()
    mesh_axes = dict(mesh.shape)
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, axes):
        assigned: list[str] = []
        if name:
            size = 1
            for m in rules.get(name, ()):
                if m not in mesh_axes or m in used:
                    continue
                if dim % (size * mesh_axes[m]) != 0:
                    continue
                assigned.append(m)
                size *= mesh_axes[m]
        for m in assigned:
            used.add(m)
        out.append(tuple(assigned) if len(assigned) > 1 else (assigned[0] if assigned else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def grad_cast(x, dtype):
    """Identity whose COTANGENT is cast to ``dtype``.

    Placed at layer boundaries it clamps the backward chain to bf16, so
    the SPMD-inserted gradient all-reduces move half the bytes (bf16 grad
    sync — the industry default; baseline keeps f32 for paper-faithful
    apples-to-apples, §Perf measures the delta)."""
    return x


def _grad_cast_fwd(x, dtype):
    return x, None


def _grad_cast_bwd(dtype, _, g):
    return (g.astype(dtype),)


grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """with_sharding_constraint under the active mesh (no-op without one)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = spec_for(x.shape, axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Param:
    """Declarative parameter: shape + logical axes + init scale.

    Also used as the shaped placeholder for non-parameter state (caches,
    token inputs); ``dtype=None`` means "the model dtype".
    """

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float | None = None    # None -> 1/sqrt(fan_in)
    dtype: str | None = None      # None -> model default

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_param(x) -> bool:
    return isinstance(x, Param)


def _init_one(p: Param, key, dtype):
    import jax.numpy as jnp

    dt = p.dtype or dtype
    if jnp.issubdtype(jnp.dtype(dt), jnp.integer):
        return jnp.zeros(p.shape, dt)
    if p.init == "zeros":
        return jnp.zeros(p.shape, dt)
    if p.init == "ones":
        return jnp.ones(p.shape, dt)
    scale = p.scale if p.scale is not None else (max(p.shape[0], 1)) ** -0.5
    return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dt)


def materialize(defs, key, dtype) -> dict:
    """Param-def pytree -> initialized array pytree."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_param)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_one(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def defs_to_shapes(defs, dtype):
    """Param-def pytree -> ShapeDtypeStruct pytree (dry-run inputs)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype or dtype),
        defs,
        is_leaf=is_param,
    )


def fsdp_extend(
    spec: P,
    shape: Sequence[int],
    mesh: Mesh,
    fsdp_axes: Sequence[str],
    logical_axes: Sequence[str | None] | None = None,
    prefer_stack: bool = False,
) -> P:
    """ZeRO-style extra sharding: place ``fsdp_axes`` on the first dim the
    base spec leaves unsharded and that they divide.  Used for parameters
    and optimizer state so per-chip residency scales with the data axis,
    not just TP (how 236B/400B archs fit 16 GiB HBM).

    The stacked ``layers`` dim is skipped when any other dim qualifies:
    sharding the scan dim makes every layer-slice a cross-data reshard and
    the AD transpose then emits full replicated f32 grad stacks (observed
    5.4 GiB/device); sharding a within-layer dim keeps slices sharded.
    ``prefer_stack=True`` flips that preference — donor-axis *streaming*
    placements want whole layers resident on the donor slices so each
    fetched window is one contiguous layer.
    """
    mesh_axes = dict(mesh.shape)
    fsdp_axes = [a for a in fsdp_axes if a in mesh_axes]
    if not fsdp_axes:
        return spec
    size = 1
    for a in fsdp_axes:
        size *= mesh_axes[a]
    used = set()
    for e in spec:
        if isinstance(e, tuple):
            used.update(e)
        elif e is not None:
            used.add(e)
    if any(a in used for a in fsdp_axes):
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))

    def assign(i: int) -> P:
        entries[i] = (
            tuple(fsdp_axes) if len(fsdp_axes) > 1 else fsdp_axes[0]
        )
        out = list(entries)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    candidates = [
        i for i, dim in enumerate(shape)
        if entries[i] is None and dim % size == 0 and dim >= size
    ]
    layer = [
        i for i in candidates
        if logical_axes and i < len(logical_axes)
        and logical_axes[i] == "layers"
    ]
    non_layer = [i for i in candidates if i not in layer]
    ordered = layer + non_layer if prefer_stack else non_layer + layer
    if ordered:
        return assign(ordered[0])
    return spec


def shard_defs(tree, defs, fsdp_axes: Sequence[str] = ()):
    """with_sharding_constraint each leaf to its def's logical spec (+FSDP).

    Used inside scan bodies on the per-layer param slice: the transpose of
    the constraint pins the *gradient* slice to the same sharding, which is
    what keeps ZeRO-3 grads sharded inside the backward loop.
    """
    mesh = _CTX.mesh
    if mesh is None:
        return tree

    def one(x, p: Param):
        spec = spec_for(p.shape, p.axes, mesh)
        if fsdp_axes:
            spec = fsdp_extend(spec, p.shape, mesh, fsdp_axes, p.axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec)
        )

    return jax.tree.map(one, tree, defs, is_leaf=lambda t: isinstance(t, Param))


def defs_to_specs(
    defs,
    mesh: Mesh,
    rules=None,
    memory_kind: str | None = None,
    fsdp_axes: Sequence[str] = (),
    donor_axes: Sequence[str] = (),
    donor_prefer_stack: bool = False,
):
    """Param-def pytree -> NamedSharding pytree.

    ``donor_axes`` extends every spec over a donor mesh axis (peer/remote
    tier realization — see :mod:`repro.core.placement`); it is applied
    after ``fsdp_axes`` so the two compose onto different dims.
    """
    def one(p: Param):
        spec = spec_for(p.shape, p.axes, mesh, rules)
        if fsdp_axes:
            spec = fsdp_extend(spec, p.shape, mesh, fsdp_axes, p.axes)
        if donor_axes:
            spec = donor_extend(
                spec, p.shape, mesh, donor_axes, p.axes,
                prefer_stack=donor_prefer_stack,
            )
        return NamedSharding(mesh, spec, memory_kind=memory_kind)

    return jax.tree.map(one, defs, is_leaf=is_param)


def spec_axes(spec: P) -> set[str]:
    """Every mesh-axis name a PartitionSpec references (tuples flattened)."""
    out: set[str] = set()
    for e in spec:
        out.update(e if isinstance(e, tuple) else [e])
    out.discard(None)
    return out


def donor_extend(
    spec: P,
    shape: Sequence[int],
    mesh: Mesh,
    donor_axes: Sequence[str],
    logical_axes: Sequence[str | None] | None = None,
    prefer_stack: bool = False,
) -> P:
    """Extend ``spec`` over the donor axes (peer/remote realization).

    Same mechanics as :func:`fsdp_extend`; ``prefer_stack=True`` targets
    the stacked ``layers`` dim first, so a ``Strategy.STREAM`` placement
    keeps whole layers on the donor slices and each streamed window is one
    contiguous layer (the planner's per-chunk ``copy_bound`` granularity).
    """
    return fsdp_extend(
        spec, shape, mesh, donor_axes, logical_axes, prefer_stack
    )


def _policy_specs(
    defs,
    mesh: Mesh,
    rules,
    role,
    policy,
    fsdp_axes: Sequence[str] = (),
):
    """NamedShardings realizing ``policy``'s placement of ``role``.

    The one entry point every realizer uses — via the
    :class:`repro.api.Runtime` facade (``Runtime.specs`` /
    ``Runtime.realize``); importing it directly as ``policy_specs`` is
    deprecated.  Resolves the role's memory kind on this backend and,
    for peer/remote tiers, the donor mesh axes that physically hold the
    bytes.  Raises :class:`repro.core.placement.DonorAxisError` if the
    mesh cannot realize the tier — the placement never silently degrades
    to local memory.
    """
    from repro.core.placement import Strategy, donor_axes_for

    pl = policy.placement(role)
    donor = donor_axes_for(mesh, pl.tier)
    specs = defs_to_specs(
        defs, mesh, rules,
        memory_kind=policy.memory_kind(role),
        fsdp_axes=fsdp_axes,
        donor_axes=donor,
        donor_prefer_stack=pl.strategy is Strategy.STREAM,
    )
    if donor:
        # Per-leaf divisibility can defeat the donor extension (no free
        # dim divisible by the axis size) — those leaves stay in LOCAL
        # memory while the planner charged them to the donor pool, so
        # make the degradation loud.
        local = sum(
            1 for s in jax.tree.leaves(specs)
            if not (spec_axes(s.spec) & set(donor))
        )
        if local:
            _log.warning(
                "policy %s/%s: %d of %d tensors could not be donor-"
                "sharded over %s (no divisible free dim) and stay in "
                "local memory — donor-pool capacity accounting is "
                "optimistic for them",
                policy.name, role.value, local,
                len(jax.tree.leaves(specs)), donor,
            )
    return specs


def __getattr__(name: str):
    # PEP 562 shim: `policy_specs` keeps resolving for external callers,
    # with a one-shot DeprecationWarning pointing at the facade.
    if name == "policy_specs":
        from repro.analysis.warnings_registry import warn_once

        warn_once(
            f"deprecated:{name}",
            "repro.models.sharding.policy_specs is deprecated; use "
            "repro.api.Runtime.specs / Runtime.realize instead",
            DeprecationWarning,
        )
        return _policy_specs
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def donation_compatible(policy, role) -> bool:
    """May a jitted step donate ``role``'s buffers under ``policy``?

    Donation is the zero-copy half of the decode hot path: XLA aliases the
    output cache onto the input cache's buffer, so the per-token update is
    in place instead of allocate+copy.  It is safe exactly for RESIDENT
    placements (local HBM, host-pinned, or donor-slice resident — the
    pinned ``out_shardings`` keep the aliased buffer in its tier).  A
    ``Strategy.STREAM`` placement must NOT donate: the jitted step computes
    on a staged copy while the far-tier resident buffer remains the source
    of truth for the next touch's migration, and donating it hands XLA the
    resident bytes as scratch mid-stream.
    """
    from repro.core.placement import Strategy

    return policy.placement(role).strategy is not Strategy.STREAM


def assert_donation_compatible(policy, role) -> None:
    """Raise if a realizer is about to donate a STREAM-placed role."""
    if not donation_compatible(policy, role):
        pl = policy.placement(role)
        raise ValueError(
            f"policy {policy.name!r} places {role.value} as "
            f"{pl.strategy.value} in {pl.tier}: streamed placements must "
            "keep their resident buffer undonated (the staging window is "
            "re-fetched from it every touch)"
        )


def stack_defs(defs, count: int, axis_name: str | None = "layers"):
    """Stack a layer's param defs ``count`` times (scan-over-layers).

    Preserves every per-def field — notably an explicit ``dtype`` (e.g.
    the SSM recurrent state pinned to float32): losing it here would
    materialize the stacked cache in the model dtype while the step
    function still emits the pinned one, a silent mismatch that breaks
    the decode step's donation alias.
    """
    return jax.tree.map(
        lambda p: Param(
            (count, *p.shape), (axis_name, *p.axes), p.init, p.scale,
            p.dtype,
        ),
        defs,
        is_leaf=is_param,
    )
