"""Decoder-only LM assembled from pattern stages (scan-over-layers).

A model is a sequence of *stages*; each stage scans one pattern period
(e.g. gemma3's ``LLLLLG``) with parameters stacked over repeats — one HLO
``while`` per stage, which keeps 512-device compiles fast and lets the
roofline analyzer multiply body costs by ``known_trip_count``.

Hybrid patterns: ``M`` layers are Mamba-2 blocks; ``S`` is the Zamba-style
*shared* attention block whose parameters live once at model level and are
closed over by every stage body (scan-invariant), with per-application KV
caches.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_embed,
    apply_head,
    apply_mlp,
    apply_norm,
    cross_entropy,
    embed_defs,
    head_defs,
    mlp_defs,
    norm_defs,
)
from repro.models.sharding import (
    Param,
    current_rules,
    grad_cast,
    shard,
    shard_defs,
    stack_defs,
)

ATTN_CODES = ("F", "L", "G", "C")


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------

def _layer_defs(cfg: ArchConfig, code: str, layer_idx: int) -> dict:
    d = cfg.d_model
    if code == "M":
        return {
            "norm": norm_defs(d, cfg.norm),
            "ssm": ssm_mod.ssm_defs(d, cfg.ssm),
        }
    if code == "S":
        return {}  # shared block params live at model level
    defs = {
        "attn_norm": norm_defs(d, cfg.norm),
        "attn": attn.attention_defs(d, cfg.attention),
        "mlp_norm": norm_defs(d, cfg.norm),
    }
    if cfg.moe is not None and cfg.moe.is_moe_layer(layer_idx):
        defs["moe"] = moe_mod.moe_defs(d, cfg.moe)
    else:
        ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.dense_d_ff:
            ff = cfg.moe.dense_d_ff
        defs["mlp"] = mlp_defs(d, ff)
    return defs


def _shared_block_defs(cfg: ArchConfig) -> dict:
    """Zamba shared attention over concat(hidden, emb0) -> d_model out."""
    dc = 2 * cfg.d_model
    a = cfg.attention
    defs = attn.attention_defs(dc, a)
    defs["w_o"] = Param(
        (a.n_heads, a.d_head, cfg.d_model), ("heads", "head_dim", "embed")
    )
    defs["norm"] = norm_defs(dc, cfg.norm)
    return defs


def _check_pattern(cfg: ArchConfig) -> None:
    if cfg.moe is not None and cfg.moe.moe_period > 1:
        assert len(cfg.layer_pattern) % cfg.moe.moe_period == 0, (
            "moe_period must divide the pattern length so scan bodies are "
            "homogeneous across repeats"
        )


def lm_defs(cfg: ArchConfig) -> dict:
    _check_pattern(cfg)
    defs: dict[str, Any] = {
        "embed": embed_defs(cfg.vocab, cfg.d_model),
        "final_norm": norm_defs(cfg.d_model, cfg.norm),
        "head": head_defs(cfg.vocab, cfg.d_model, cfg.tie_embeddings),
        "stages": [],
    }
    for codes, count, start in cfg.stages():
        stage = {
            f"{j}{code}": _layer_defs(cfg, code, start + j)
            for j, code in enumerate(codes)
        }
        defs["stages"].append(stack_defs(stage, count))
    if "S" in cfg.layer_pattern:
        defs["shared_attn"] = _shared_block_defs(cfg)
    return defs


# ---------------------------------------------------------------------------
# Cache defs
# ---------------------------------------------------------------------------

def lm_cache_defs(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    caches = {"stages": []}
    for codes, count, start in cfg.stages():
        stage = {}
        for j, code in enumerate(codes):
            if code == "M":
                stage[f"{j}{code}"] = ssm_mod.ssm_cache_defs(
                    batch, cfg.d_model, cfg.ssm
                )
            elif code == "S":
                stage[f"{j}{code}"] = attn.cache_defs(
                    batch, max_len, cfg.attention, "F"
                )
            else:
                stage[f"{j}{code}"] = attn.cache_defs(
                    batch, max_len, cfg.attention, code
                )
        caches["stages"].append(stack_defs(stage, count))
    return caches


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_layer_train(cfg, code, lp, x, emb0, shared):
    if code == "M":
        return x + ssm_mod.ssm_train(
            lp["ssm"], apply_norm(lp["norm"], x, cfg.norm), cfg.d_model, cfg.ssm
        ), 0.0
    if code == "S":
        xin = jnp.concatenate([x, emb0], axis=-1)
        xin = apply_norm(shared["norm"], xin, cfg.norm)
        return x + attn.gqa_train(shared, xin, cfg.attention, "F"), 0.0
    h = apply_norm(lp["attn_norm"], x, cfg.norm)
    x = x + attn.attn_train(lp["attn"], h, cfg.attention, code)
    h = apply_norm(lp["mlp_norm"], x, cfg.norm)
    if "moe" in lp:
        out, aux = moe_mod.apply_moe(lp["moe"], h, cfg.moe, cfg.act)
        return x + out, aux
    return x + apply_mlp(lp["mlp"], h, cfg.act), 0.0


def _apply_layer_step(
    cfg, code, lp, cache, x, emb0, lengths, shared, mode, new_lens=None
):
    """prefill/prefill_at/decode step for one layer; returns (x, new_cache).

    ``prefill_at`` is the serving engine's chunked batched prefill:
    ``lengths`` carries each row's cache fill *offset* and ``new_lens`` how
    many of the chunk's positions are real for that row (0 = untouched).
    """
    if code == "M":
        h = apply_norm(lp["norm"], x, cfg.norm)
        if mode == "prefill":
            out, c = ssm_mod.ssm_prefill(lp["ssm"], h, cache, cfg.d_model, cfg.ssm)
        elif mode == "prefill_at":
            out, c = ssm_mod.ssm_prefill_at(
                lp["ssm"], h, cache, lengths, new_lens, cfg.d_model, cfg.ssm
            )
        else:
            out, c = ssm_mod.ssm_decode(lp["ssm"], h, cache, cfg.d_model, cfg.ssm)
        return x + out, c
    if code == "S":
        xin = jnp.concatenate([x, emb0], axis=-1)
        xin = apply_norm(shared["norm"], xin, cfg.norm)
        if mode == "prefill":
            out, c = attn.gqa_prefill(shared, xin, cache, cfg.attention, "F")
        elif mode == "prefill_at":
            out, c = attn.gqa_prefill_at(
                shared, xin, cache, lengths, new_lens, cfg.attention, "F"
            )
        else:
            out, c = attn.gqa_decode(
                shared, xin, cache, lengths, cfg.attention, "F"
            )
        return x + out, c
    h = apply_norm(lp["attn_norm"], x, cfg.norm)
    if mode == "prefill":
        out, c = attn.attn_prefill(lp["attn"], h, cache, cfg.attention, code)
    elif mode == "prefill_at":
        out, c = attn.attn_prefill_at(
            lp["attn"], h, cache, lengths, new_lens, cfg.attention, code
        )
    else:
        out, c = attn.attn_decode(
            lp["attn"], h, cache, lengths, cfg.attention, code
        )
    x = x + out
    h = apply_norm(lp["mlp_norm"], x, cfg.norm)
    if "moe" in lp:
        out, _ = moe_mod.apply_moe(lp["moe"], h, cfg.moe, cfg.act)
        return x + out, c
    return x + apply_mlp(lp["mlp"], h, cfg.act), c


# ---------------------------------------------------------------------------
# Stage scans
# ---------------------------------------------------------------------------

def _run_stages_train(cfg, params, x, remat: str):
    shared = params.get("shared_attn")
    emb0 = x if "S" in cfg.layer_pattern else jnp.zeros((1,), x.dtype)
    aux_total = 0.0
    fsdp = tuple(current_rules().get("fsdp", ()))
    for (codes, count, start), stage_params in zip(
        cfg.stages(), params["stages"]
    ):
        stage_defs = {
            f"{j}{code}": _layer_defs(cfg, code, start + j)
            for j, code in enumerate(codes)
        }

        grad_dtype = current_rules().get("grad_dtype")

        def body(carry, lp, _codes=codes, _defs=stage_defs):
            x, emb0, aux = carry
            # pin the layer-slice params (and, via AD transpose, their
            # grads) to the per-layer FSDP sharding inside the loop.
            lp = shard_defs(lp, _defs, fsdp)
            for j, code in enumerate(_codes):
                x, a = _apply_layer_train(
                    cfg, code, lp[f"{j}{code}"], x, emb0, shared
                )
                aux = aux + a
            x = shard(x, "batch", "seq", "embed")
            if grad_dtype:
                x = grad_cast(x, grad_dtype)
            return (x, emb0, aux), None

        if remat == "full":
            body = jax.checkpoint(body)
        elif remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            )
        (x, emb0, aux_total), _ = jax.lax.scan(
            body, (x, emb0, aux_total), stage_params
        )
    return x, aux_total


def _run_stages_step(cfg, params, caches, x, lengths, mode, new_lens=None):
    shared = params.get("shared_attn")
    emb0 = x if "S" in cfg.layer_pattern else jnp.zeros((1,), x.dtype)
    new_caches = []
    for (codes, count, start), stage_params, stage_cache in zip(
        cfg.stages(), params["stages"], caches["stages"]
    ):
        def body(carry, slices, _codes=codes):
            x, emb0 = carry
            lp, cache = slices
            new_cache = {}
            for j, code in enumerate(_codes):
                key = f"{j}{code}"
                x, c = _apply_layer_step(
                    cfg, code, lp[key], cache[key], x, emb0, lengths,
                    shared, mode, new_lens,
                )
                new_cache[key] = c
            x = shard(x, "batch", "seq", "embed")
            return (x, emb0), new_cache

        (x, emb0), nc = jax.lax.scan(
            body, (x, emb0), (stage_params, stage_cache)
        )
        new_caches.append(nc)
    return x, {"stages": new_caches}


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def lm_forward(
    params,
    tokens: jax.Array,              # (B, S_text)
    cfg: ArchConfig,
    *,
    extra_embeds: jax.Array | None = None,   # (B, S_front, d) modality stub
    remat: str = "none",
):
    """Training-mode forward -> (logits, aux_loss)."""
    x = apply_embed(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        x = shard(x, "batch", "seq", "embed")
    x, aux = _run_stages_train(cfg, params, x, remat)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = apply_head(params["head"], params["embed"], x)
    return logits, aux


def lm_loss(
    params, tokens, labels, cfg: ArchConfig, *,
    extra_embeds=None, remat: str = "full", aux_weight: float = 0.01,
):
    from repro.models.layers import fused_cross_entropy

    # forward up to the final hidden states, then head+CE fused per
    # sequence block: the full (B,S,V) f32 logits chain never exists.
    x = apply_embed(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        x = shard(x, "batch", "seq", "embed")
    x, aux = _run_stages_train(cfg, params, x, remat)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if extra_embeds is not None:
        x = x[:, extra_embeds.shape[1]:]
    loss = fused_cross_entropy(params["head"], params["embed"], x, labels)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


def lm_prefill(params, tokens, caches, cfg: ArchConfig, *, extra_embeds=None):
    """Fill the cache from a prompt; returns (last-token logits, caches)."""
    x = apply_embed(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    lengths = jnp.full((tokens.shape[0],), x.shape[1], jnp.int32)
    x, caches = _run_stages_step(cfg, params, caches, x, lengths, "prefill")
    x = apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
    logits = apply_head(params["head"], params["embed"], x)
    return logits[:, 0], caches


def lm_prefill_at(params, tokens, caches, offsets, new_lens, cfg: ArchConfig):
    """Chunked batched prefill: write one prompt chunk per row at an offset.

    ``tokens`` (B, S) holds one chunk of each row's prompt; row ``b``
    appends ``new_lens[b] <= S`` tokens at cache positions ``offsets[b]..``
    (``new_lens == 0`` leaves the row's cache untouched — rows mid-decode
    ride through the dispatch unharmed).  Returns the logits of each row's
    last *valid* chunk position (garbage for ``new_lens == 0`` rows) and
    the updated caches.  This is the serving engine's replacement for
    replaying prompts token-by-token through full-batch decode steps:
    admitting a batch of length-L prompts costs O(L / chunk) dispatches
    instead of O(B·L), and the prior cache is read once per chunk.
    """
    x = apply_embed(params["embed"], tokens)
    x, caches = _run_stages_step(
        cfg, params, caches, x, offsets, "prefill_at", new_lens
    )
    last = jnp.clip(new_lens - 1, 0, tokens.shape[1] - 1)
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)   # (B,1,d)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = apply_head(params["head"], params["embed"], x)
    return logits[:, 0], caches


def lm_decode_step(params, tokens, caches, lengths, cfg: ArchConfig):
    """One decode step; tokens (B,1); lengths (B,) current cache fill.

    Returns (logits (B, vocab), new caches).  Caller advances lengths.
    """
    x = apply_embed(params["embed"], tokens)
    x, caches = _run_stages_step(cfg, params, caches, x, lengths, "decode")
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = apply_head(params["head"], params["embed"], x)
    return logits[:, 0], caches
