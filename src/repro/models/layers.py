"""Shared layers: norms, embeddings, RoPE, gated MLP, logits head.

Everything is a (param-defs builder, apply fn) pair over plain dict
pytrees; compute is bf16 with f32 normalization statistics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.sharding import Param, shard


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_defs(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": Param((d,), ("embed",), init="ones")}
    if kind == "layernorm":
        return {
            "scale": Param((d,), ("embed",), init="ones"),
            "bias": Param((d,), ("embed",), init="zeros"),
        }
    if kind == "nonparametric":
        return {}
    raise ValueError(kind)


def apply_norm(params: dict, x: jax.Array, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    # layernorm / olmo's non-parametric layernorm
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32
        )
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding + logits
# ---------------------------------------------------------------------------

def embed_defs(vocab: int, d: int) -> dict:
    return {"embedding": Param((vocab, d), ("vocab", "embed"), scale=0.02)}


def apply_embed(params: dict, tokens: jax.Array, *, scale: bool = False):
    e = params["embedding"]
    out = jnp.take(e, tokens, axis=0)
    if scale:
        out = out * jnp.asarray(e.shape[1] ** 0.5, out.dtype)
    return shard(out, "batch", "seq", "embed")


def head_defs(vocab: int, d: int, tied: bool) -> dict:
    if tied:
        return {}
    return {"unembed": Param((d, vocab), ("embed", "vocab"))}


def apply_head(params: dict, embed_params: dict, x: jax.Array):
    """Final logits; vocab dim sharded over 'model' (Megatron head)."""
    if "unembed" in params:
        w = params["unembed"]
    else:
        w = embed_params["embedding"].T
    logits = jnp.einsum(
        "bsd,dv->bsv", x, w, preferred_element_type=jnp.float32
    )
    return shard(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float):
    """Apply rotary embedding.

    x: (..., S, D) with D even; positions: broadcastable to (..., S).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (GLU family)
# ---------------------------------------------------------------------------

def mlp_defs(d: int, ff: int) -> dict:
    return {
        "w_gate": Param((d, ff), ("embed", "d_ff")),
        "w_up": Param((d, ff), ("embed", "d_ff")),
        "w_down": Param((ff, d), ("d_ff", "embed")),
    }


def apply_mlp(params: dict, x: jax.Array, act: str = "silu"):
    actfn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    h = shard(actfn(g) * u, "batch", "seq", "d_ff")
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"])
    return shard(out, "batch", "seq", "embed")


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE; logits (B,S,V) f32, labels (B,S) int."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None], axis=-1
    )[..., 0]
    return jnp.mean(logz - gold)


def fused_cross_entropy(
    params: dict,
    embed_params: dict,
    x: jax.Array,            # (B, S, d) final hidden states
    labels: jax.Array,       # (B, S)
    block: int = 512,
) -> jax.Array:
    """Head projection fused into a seq-chunked CE.

    Never materializes the full (B, S, V) f32 logits — at vocab 202k that
    tensor chain is ~15 GiB/device (observed on llama4) — one (B, block, V)
    slab lives at a time, rematerialized in the backward.  The projection
    keeps the unembed in bf16 with f32 accumulation, and the vocab dim
    keeps its Megatron sharding (logsumexp/gather reduce over it).
    """
    if "unembed" in params:
        w = params["unembed"]                        # (d, V)
    else:
        w = embed_params["embedding"].T
    B, S, d = x.shape
    blk = min(block, S)
    if S % blk:
        blk = S
    nblocks = S // blk

    @jax.checkpoint
    def one(i):
        xs = jax.lax.dynamic_slice_in_dim(x, i * blk, blk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * blk, blk, axis=1)
        logits = jnp.einsum(
            "bsd,dv->bsv", xs, w, preferred_element_type=jnp.float32
        )
        logits = shard(logits, "batch", None, "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    total = jnp.sum(jax.lax.map(one, jnp.arange(nblocks)))
    return total / (B * S)
