"""Model zoo: all 10 assigned architectures as composable JAX modules."""

from repro.models.model_zoo import ModelBundle, get_bundle, get_smoke_bundle  # noqa: F401
from repro.models.sharding import (  # noqa: F401
    DEFAULT_RULES,
    Param,
    defs_to_shapes,
    defs_to_specs,
    donor_extend,
    materialize,
    shard,
    spec_for,
    use_sharding,
)


def __getattr__(name: str):
    # deprecated: forwards to sharding's PEP 562 shim (one-shot warning)
    if name == "policy_specs":
        from repro.models import sharding

        return getattr(sharding, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
