"""Model zoo: all 10 assigned architectures as composable JAX modules."""

from repro.models.model_zoo import ModelBundle, get_bundle, get_smoke_bundle  # noqa: F401
from repro.models.sharding import (  # noqa: F401
    DEFAULT_RULES,
    Param,
    defs_to_shapes,
    defs_to_specs,
    donor_extend,
    materialize,
    policy_specs,
    shard,
    spec_for,
    use_sharding,
)
