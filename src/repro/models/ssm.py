"""Mamba-2 block (SSD) with train/prefill/decode paths.

Train/prefill run the chunked SSD scan (kernels/ssd_scan oracle or Pallas);
decode is the O(1) recurrence against the (conv, ssm) state cache — the SSM
answer to the KV cache, and the reason ``long_500k`` is *runnable* for
SSM/hybrid archs: decode-state bytes are constant in sequence length
(the paper's Fig. 17 workload with the big read-mostly buffer designed
away — we quantify exactly this in the roofline tables).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SSMSpec
from repro.kernels import ops
from repro.models.sharding import Param, shard

SSD_CHUNK = 256


def ssm_defs(d_model: int, spec: SSMSpec) -> dict:
    di = spec.d_inner(d_model)
    h = spec.n_heads(d_model)
    n = spec.d_state
    conv_dim = di + 2 * n
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": Param(
            (d_model, 2 * di + 2 * n + h), ("embed", "d_inner")
        ),
        "conv_w": Param((spec.d_conv, conv_dim), (None, "d_inner")),
        "conv_b": Param((conv_dim,), ("d_inner",), init="zeros"),
        "a_log": Param((h,), ("ssm_heads",), init="zeros"),
        "dt_bias": Param((h,), ("ssm_heads",), init="zeros"),
        "d_skip": Param((h,), ("ssm_heads",), init="ones"),
        "norm_scale": Param((di,), ("d_inner",), init="ones"),
        "w_out": Param((di, d_model), ("d_inner", "embed")),
    }


def ssm_cache_defs(batch: int, d_model: int, spec: SSMSpec) -> dict:
    di = spec.d_inner(d_model)
    h = spec.n_heads(d_model)
    n = spec.d_state
    return {
        "conv": Param(
            (batch, spec.d_conv - 1, di + 2 * n),
            ("batch", None, "d_inner"), init="zeros",
        ),
        "ssm": Param(
            (batch, h, spec.head_dim, n),
            ("batch", "ssm_heads", None, "state"), init="zeros",
            dtype="float32",   # recurrent state accumulates in f32
        ),
    }


def _split(proj, di, n, h):
    z = proj[..., :di]
    xs = proj[..., di : 2 * di]
    b = proj[..., 2 * di : 2 * di + n]
    c = proj[..., 2 * di + n : 2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n :]
    return z, xs, b, c, dt


def _gated_rmsnorm(y, z, scale, eps=1e-6):
    yz = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = yz.astype(jnp.float32)
    out = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + eps)
    return (out * scale.astype(jnp.float32)).astype(y.dtype)


def ssm_train(params, x, d_model: int, spec: SSMSpec):
    """x: (B,S,d) -> (B,S,d)."""
    B, S, _ = x.shape
    di = spec.d_inner(d_model)
    h = spec.n_heads(d_model)
    n = spec.d_state
    p = spec.head_dim

    proj = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xs, bmat, cmat, dt = _split(proj, di, n, h)

    # causal depthwise conv over [x, B, C]
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    xbc = shard(xbc, "batch", "seq", "d_inner")
    pad = jnp.pad(xbc, ((0, 0), (spec.d_conv - 1, 0), (0, 0)))
    kern = params["conv_w"]
    conv = sum(
        pad[:, i : i + S] * kern[i][None, None, :]
        for i in range(spec.d_conv)
    ) + params["conv_b"]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, bmat, cmat = conv[..., :di], conv[..., di : di + n], conv[..., di + n :]

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(params["a_log"].astype(jnp.float32))

    xh = xs.reshape(B, S, h, p)
    chunk = min(SSD_CHUNK, S)
    y = ops.ssd_scan(xh, dt, A, bmat, cmat, chunk=chunk)
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, di)
    y = shard(y, "batch", "seq", "d_inner")

    y = _gated_rmsnorm(y, z, params["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return shard(out, "batch", "seq", "embed")


def ssm_prefill(params, x, cache, d_model: int, spec: SSMSpec):
    """Train-path + final (conv, ssm) state capture."""
    B, S, _ = x.shape
    di = spec.d_inner(d_model)
    h = spec.n_heads(d_model)
    n = spec.d_state
    p = spec.head_dim

    proj = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xs, bmat, cmat, dt = _split(proj, di, n, h)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_state = xbc[:, -(spec.d_conv - 1):, :]   # pre-activation window
    pad = jnp.pad(xbc, ((0, 0), (spec.d_conv - 1, 0), (0, 0)))
    kern = params["conv_w"]
    conv = sum(
        pad[:, i : i + S] * kern[i][None, None, :]
        for i in range(spec.d_conv)
    ) + params["conv_b"]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, bmat, cmat = conv[..., :di], conv[..., di : di + n], conv[..., di + n :]
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xs.reshape(B, S, h, p)
    chunk = min(SSD_CHUNK, S)
    y, state = ops.ssd_scan(
        xh, dt, A, bmat, cmat, chunk=chunk, return_state=True
    )
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = _gated_rmsnorm(y.reshape(B, S, di), z, params["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    cache = {"conv": conv_state, "ssm": state.astype(jnp.float32)}
    return shard(out, "batch", "seq", "embed"), cache


def ssm_prefill_at(
    params, x, cache, offsets, new_lens, d_model: int, spec: SSMSpec
):
    """Chunk prefill continuing from the cached (conv, ssm) state.

    Row ``b`` consumes ``new_lens[b] <= S`` tokens; positions past
    ``new_lens`` get ``dt = 0`` (decay ``exp(0) = 1``, zero input add), so
    the recurrent state after the scan equals the state after exactly
    ``new_lens`` real steps — rows with ``new_lens == 0`` keep both state
    tensors bit-for-bit.  The causal conv window is seeded from the cached
    pre-activation tail instead of zero padding, and the new conv state is
    the last ``d_conv - 1`` *valid* entries of the [cached ++ chunk]
    stream, gathered per row.

    A row whose ``offsets == 0`` starts from ZERO state, whatever the
    cache holds: the recurrent state is cumulative (unlike a KV slot, it
    cannot be overwritten by position), and a freed slot's state keeps
    integrating garbage from the full-batch decode dispatches it idles
    through — re-admission must not inherit that.
    """
    B, S, _ = x.shape
    di = spec.d_inner(d_model)
    h = spec.n_heads(d_model)
    n = spec.d_state
    p = spec.head_dim
    new_lens = new_lens.astype(jnp.int32)
    fresh = offsets.astype(jnp.int32) == 0                     # (B,)
    conv_in = jnp.where(
        fresh[:, None, None], jnp.zeros_like(cache["conv"]), cache["conv"]
    )
    ssm_in = jnp.where(
        fresh[:, None, None, None],
        jnp.zeros_like(cache["ssm"]), cache["ssm"],
    )

    proj = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xs, bmat, cmat, dt = _split(proj, di, n, h)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    full = jnp.concatenate(
        [conv_in.astype(xbc.dtype), xbc], axis=1
    )                                              # (B, d_conv-1+S, conv_dim)
    idx = new_lens[:, None] + jnp.arange(spec.d_conv - 1)[None, :]
    conv_state = jnp.take_along_axis(full, idx[:, :, None], axis=1)
    kern = params["conv_w"]
    conv = sum(
        full[:, i : i + S] * kern[i][None, None, :]
        for i in range(spec.d_conv)
    ) + params["conv_b"]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, bmat, cmat = conv[..., :di], conv[..., di : di + n], conv[..., di + n :]

    dtf = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    live = jnp.arange(S, dtype=jnp.int32)[None, :] < new_lens[:, None]
    dtf = jnp.where(live[:, :, None], dtf, 0.0)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xs.reshape(B, S, h, p)
    chunk = min(SSD_CHUNK, S)
    if S % chunk:
        chunk = S
    y, state = ops.ssd_scan(
        xh, dtf, A, bmat, cmat, chunk=chunk,
        init_state=ssm_in, return_state=True,
    )
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = _gated_rmsnorm(y.reshape(B, S, di), z, params["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    cache = {
        "conv": conv_state.astype(cache["conv"].dtype),
        "ssm": state.astype(jnp.float32),
    }
    return shard(out, "batch", "seq", "embed"), cache


def ssm_decode(params, x, cache, d_model: int, spec: SSMSpec):
    """One-token step; x (B,1,d). Returns (out, cache)."""
    B = x.shape[0]
    di = spec.d_inner(d_model)
    h = spec.n_heads(d_model)
    n = spec.d_state
    p = spec.head_dim

    proj = jnp.einsum("bsd,de->bse", x[:, 0:1], params["w_in"])[:, 0]
    z, xs, bmat, cmat, dt = _split(proj, di, n, h)

    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)      # (B, conv_dim)
    window = jnp.concatenate(
        [cache["conv"], xbc[:, None].astype(cache["conv"].dtype)], axis=1
    )
    kern = params["conv_w"]
    conv = jnp.einsum("bkc,kc->bc", window, kern) + params["conv_b"]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, bmat, cmat = conv[..., :di], conv[..., di : di + n], conv[..., di + n :]

    dtf = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xs.reshape(B, h, p)
    y, new_state = ops.ssd_decode_step(xh, dtf, A, bmat, cmat, cache["ssm"])
    y = y + params["d_skip"].astype(y.dtype)[None, :, None] * xh
    y = _gated_rmsnorm(y.reshape(B, di), z, params["norm_scale"])
    out = jnp.einsum("be,ed->bd", y, params["w_out"])[:, None]
    cache = {"conv": window[:, 1:], "ssm": new_state}
    return shard(out, "batch", "seq", "embed"), cache
