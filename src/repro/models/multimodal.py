"""Modality frontends — STUBS per the task assignment.

``[audio]``/``[vlm]`` architectures specify the transformer BACKBONE only;
``input_specs()`` provides precomputed frame/patch embeddings.  The defs
here describe those stub inputs so the dry-run and smoke tests are
shape-exact; a real InternViT / w2v-BERT frontend would produce arrays of
exactly these shapes and plug in without touching the backbone.
"""

from __future__ import annotations

from repro.configs import ArchConfig
from repro.models.sharding import Param


def frontend_input_defs(cfg: ArchConfig, batch: int) -> dict:
    """Stub embedding inputs for a batch (empty for text-only archs)."""
    if cfg.frontend == "none" or cfg.frontend_tokens == 0:
        return {}
    name = {"vision_stub": "patch_embeds", "audio_stub": "frame_embeds"}[
        cfg.frontend
    ]
    return {
        name: Param(
            (batch, cfg.frontend_tokens, cfg.d_model),
            ("batch", "seq", "embed"),
        )
    }


def frontend_embeds(batch_inputs: dict):
    """Extract the stub embedding array from a batch dict (or None)."""
    for key in ("patch_embeds", "frame_embeds"):
        if key in batch_inputs:
            return batch_inputs[key]
    return None
