"""Encoder-decoder transformer (SeamlessM4T backbone).

The modality frontend is a STUB per the task: the encoder consumes
precomputed frame embeddings from ``input_specs``.  Decode caches both the
decoder self-attention KV and the *precomputed cross-attention KV* (the
encoder memory is projected once at prefill — the read-mostly buffer whose
placement bench_llm_inference studies).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.kernels import ops
from repro.models import attention as attn
from repro.models.layers import (
    apply_embed,
    apply_head,
    apply_mlp,
    apply_norm,
    embed_defs,
    head_defs,
    mlp_defs,
    norm_defs,
)
from repro.models.sharding import Param, shard, stack_defs


def _enc_layer_defs(cfg: ArchConfig) -> dict:
    return {
        "attn_norm": norm_defs(cfg.d_model, cfg.norm),
        "attn": attn.attention_defs(cfg.d_model, cfg.attention),
        "mlp_norm": norm_defs(cfg.d_model, cfg.norm),
        "mlp": mlp_defs(cfg.d_model, cfg.d_ff),
    }


def _dec_layer_defs(cfg: ArchConfig) -> dict:
    return {
        "self_norm": norm_defs(cfg.d_model, cfg.norm),
        "self_attn": attn.attention_defs(cfg.d_model, cfg.attention),
        "cross_norm": norm_defs(cfg.d_model, cfg.norm),
        "cross_attn": attn.attention_defs(cfg.d_model, cfg.attention),
        "mlp_norm": norm_defs(cfg.d_model, cfg.norm),
        "mlp": mlp_defs(cfg.d_model, cfg.d_ff),
    }


def encdec_defs(cfg: ArchConfig) -> dict:
    return {
        "embed": embed_defs(cfg.vocab, cfg.d_model),
        "head": head_defs(cfg.vocab, cfg.d_model, cfg.tie_embeddings),
        "enc_final_norm": norm_defs(cfg.d_model, cfg.norm),
        "dec_final_norm": norm_defs(cfg.d_model, cfg.norm),
        "encoder": stack_defs(_enc_layer_defs(cfg), cfg.n_encoder_layers),
        "decoder": stack_defs(_dec_layer_defs(cfg), cfg.n_layers),
    }


def encdec_cache_defs(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    a = cfg.attention
    cross = {
        "k": Param(
            (batch, a.n_kv_heads, cfg.frontend_tokens, a.d_head),
            ("batch", "kv_heads", None, "head_dim"), init="zeros",
        ),
        "v": Param(
            (batch, a.n_kv_heads, cfg.frontend_tokens, a.d_head),
            ("batch", "kv_heads", None, "head_dim"), init="zeros",
        ),
    }
    layer = {
        "self": attn.cache_defs(batch, max_len, a, "F"),
        "cross": cross,
    }
    return {"decoder": stack_defs(layer, cfg.n_layers)}


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings -> encoder memory."""
    x = shard(frames, "batch", "seq", "embed")

    def body(x, lp):
        h = apply_norm(lp["attn_norm"], x, cfg.norm)
        x = x + attn.gqa_train(lp["attn"], h, cfg.attention, "X")
        h = apply_norm(lp["mlp_norm"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, cfg.act)
        return shard(x, "batch", "seq", "embed"), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return apply_norm(params["enc_final_norm"], x, cfg.norm)


def _cross_kv(lp, memory):
    k = jnp.einsum("bsd,dhk->bhsk", memory, lp["w_k"])
    v = jnp.einsum("bsd,dhk->bhsk", memory, lp["w_v"])
    return k, v


def _cross_attend(lp, x, k, v):
    q = jnp.einsum("bsd,dhk->bhsk", x, lp["w_q"])
    o = ops.attention(q, k, v, kind="bidirectional")
    return jnp.einsum("bhsk,hkd->bsd", o, lp["w_o"])


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def decode_train(params, tokens, memory, cfg: ArchConfig):
    """Teacher-forced decoder -> logits (B, S_dec, vocab)."""
    x = apply_embed(params["embed"], tokens)

    def body(x, lp):
        h = apply_norm(lp["self_norm"], x, cfg.norm)
        x = x + attn.gqa_train(lp["self_attn"], h, cfg.attention, "F")
        h = apply_norm(lp["cross_norm"], x, cfg.norm)
        k, v = _cross_kv(lp["cross_attn"], memory)
        x = x + _cross_attend(lp["cross_attn"], h, k, v)
        h = apply_norm(lp["mlp_norm"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, cfg.act)
        return shard(x, "batch", "seq", "embed"), None

    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = apply_norm(params["dec_final_norm"], x, cfg.norm)
    return apply_head(params["head"], params["embed"], x)


def encdec_train_loss(params, frames, tokens, labels, cfg: ArchConfig):
    from repro.models.layers import cross_entropy

    memory = encode(params, frames, cfg)
    logits = decode_train(params, tokens, memory, cfg)
    loss = cross_entropy(logits, labels)
    return loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}


def encdec_prefill(params, frames, tokens, caches, cfg: ArchConfig):
    """Encode + teacher-forced prompt + cache fill."""
    memory = encode(params, frames, cfg)
    x = apply_embed(params["embed"], tokens)

    def body(x, slices):
        lp, cache = slices
        h = apply_norm(lp["self_norm"], x, cfg.norm)
        out, self_c = attn.gqa_prefill(
            lp["self_attn"], h, cache["self"], cfg.attention, "F"
        )
        x = x + out
        k, v = _cross_kv(lp["cross_attn"], memory)
        h = apply_norm(lp["cross_norm"], x, cfg.norm)
        x = x + _cross_attend(lp["cross_attn"], h, k, v)
        h = apply_norm(lp["mlp_norm"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, cfg.act)
        x = shard(x, "batch", "seq", "embed")
        return x, {"self": self_c, "cross": {"k": k, "v": v}}

    x, new_cache = jax.lax.scan(
        body, x, (params["decoder"], caches["decoder"])
    )
    x = apply_norm(params["dec_final_norm"], x[:, -1:], cfg.norm)
    logits = apply_head(params["head"], params["embed"], x)
    return logits[:, 0], {"decoder": new_cache}


def encdec_prefill_at(params, tokens, caches, offsets, new_lens, cfg):
    """Offset-aware chunked decoder prefill against self+cross caches.

    Serving counterpart of :func:`repro.models.transformer.lm_prefill_at`
    for encoder-decoder bundles: row ``b`` appends ``new_lens[b] <= S``
    prompt tokens at self-cache positions ``offsets[b]..`` in a single
    dispatch instead of replaying them one-by-one through
    :func:`encdec_decode_step`.  Self-attention goes through
    :func:`~repro.models.attention.gqa_prefill_at` (decode-replay
    semantics by construction); cross-attention is *bidirectional
    per-query over a fixed memory*, so attending a whole chunk at once is
    bit-identical to attending its tokens one at a time — the cross KV is
    read, never written, and rides through unchanged (in serving it holds
    whatever the admission path projected, zeros for token-only prompts,
    exactly as the decode-step replay would see).  Rows with
    ``new_lens == 0`` keep their caches untouched.
    """
    x = apply_embed(params["embed"], tokens)
    offsets = offsets.astype(jnp.int32)
    new_lens = new_lens.astype(jnp.int32)

    def body(x, slices):
        lp, cache = slices
        h = apply_norm(lp["self_norm"], x, cfg.norm)
        out, self_c = attn.gqa_prefill_at(
            lp["self_attn"], h, cache["self"], offsets, new_lens,
            cfg.attention, "F",
        )
        x = x + out
        h = apply_norm(lp["cross_norm"], x, cfg.norm)
        x = x + _cross_attend(
            lp["cross_attn"], h, cache["cross"]["k"], cache["cross"]["v"]
        )
        h = apply_norm(lp["mlp_norm"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, cfg.act)
        x = shard(x, "batch", "seq", "embed")
        return x, {"self": self_c, "cross": cache["cross"]}

    x, new_cache = jax.lax.scan(
        body, x, (params["decoder"], caches["decoder"])
    )
    last = jnp.clip(new_lens - 1, 0, tokens.shape[1] - 1)
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)
    x = apply_norm(params["dec_final_norm"], x, cfg.norm)
    logits = apply_head(params["head"], params["embed"], x)
    return logits[:, 0], {"decoder": new_cache}


def encdec_decode_step(params, tokens, caches, lengths, cfg: ArchConfig):
    """One decoder step against self+cross caches; tokens (B,1)."""
    x = apply_embed(params["embed"], tokens)

    def body(x, slices):
        lp, cache = slices
        h = apply_norm(lp["self_norm"], x, cfg.norm)
        out, self_c = attn.gqa_decode(
            lp["self_attn"], h, cache["self"], lengths, cfg.attention, "F"
        )
        x = x + out
        h = apply_norm(lp["cross_norm"], x, cfg.norm)
        x = x + _cross_attend(
            lp["cross_attn"], h, cache["cross"]["k"], cache["cross"]["v"]
        )
        h = apply_norm(lp["mlp_norm"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, cfg.act)
        x = shard(x, "batch", "seq", "embed")
        return x, {"self": self_c, "cross": cache["cross"]}

    x, new_cache = jax.lax.scan(
        body, x, (params["decoder"], caches["decoder"])
    )
    x = apply_norm(params["dec_final_norm"], x, cfg.norm)
    logits = apply_head(params["head"], params["embed"], x)
    return logits[:, 0], {"decoder": new_cache}
