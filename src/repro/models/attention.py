"""Attention blocks: GQA (+sliding/chunked/global variants) and MLA.

Three execution paths per block, matching the assigned shapes:

* ``train``    — full-sequence causal/windowed attention, differentiable;
* ``prefill``  — same math, also materializes the KV cache;
* ``decode``   — one token against the cache (flash-decode datapath).

MLA (DeepSeek-V2) caches the 512-dim latent + shared rope key and uses the
**absorbed** decode formulation (q absorbed through W_uk, output through
W_uv) so decode reads scale with kv_lora, not heads — the architecture-level
version of the paper's "shrink what you must stream" lesson.

Sliding-window layers keep a **ring-buffer cache of size window** (order
does not matter to softmax; masking handles validity) — ``long_500k``
memory for gemma3 local layers is O(window), not O(seq).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import AttentionSpec
from repro.kernels import ops
from repro.models.layers import rope
from repro.models.sharding import Param, shard


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------

def attention_defs(d_model: int, spec: AttentionSpec) -> dict:
    if spec.kind == "mla":
        qk_head = spec.nope_head_dim + spec.rope_head_dim
        defs = {
            "w_kv_a": Param((d_model, spec.kv_lora), ("embed", "lora")),
            "w_k_rope": Param((d_model, spec.rope_head_dim), ("embed", None)),
            "w_k_b": Param(
                (spec.kv_lora, spec.n_heads, spec.nope_head_dim),
                ("lora", "heads", "head_dim"),
            ),
            "w_v_b": Param(
                (spec.kv_lora, spec.n_heads, spec.v_head_dim),
                ("lora", "heads", "head_dim"),
            ),
            "w_o": Param(
                (spec.n_heads, spec.v_head_dim, d_model),
                ("heads", "head_dim", "embed"),
            ),
        }
        if spec.q_lora:
            defs["w_q_a"] = Param((d_model, spec.q_lora), ("embed", "lora"))
            defs["w_q_b"] = Param(
                (spec.q_lora, spec.n_heads, qk_head),
                ("lora", "heads", "head_dim"),
            )
        else:
            defs["w_q"] = Param(
                (d_model, spec.n_heads, qk_head),
                ("embed", "heads", "head_dim"),
            )
        return defs

    defs = {
        "w_q": Param(
            (d_model, spec.n_heads, spec.d_head),
            ("embed", "heads", "head_dim"),
        ),
        "w_k": Param(
            (d_model, spec.n_kv_heads, spec.d_head),
            ("embed", "kv_heads", "head_dim"),
        ),
        "w_v": Param(
            (d_model, spec.n_kv_heads, spec.d_head),
            ("embed", "kv_heads", "head_dim"),
        ),
        "w_o": Param(
            (spec.n_heads, spec.d_head, d_model),
            ("heads", "head_dim", "embed"),
        ),
    }
    if spec.qk_norm:
        defs["q_norm"] = Param((spec.d_head,), (None,), init="ones")
        defs["k_norm"] = Param((spec.d_head,), (None,), init="ones")
    return defs


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _mask_kind(code: str) -> str:
    return {"F": "causal", "G": "causal", "L": "sliding", "C": "chunked",
            "X": "bidirectional"}[code]


def _theta(spec: AttentionSpec, code: str) -> float:
    if code == "G" and spec.rope_theta_global:
        return spec.rope_theta_global
    return spec.rope_theta


# ---------------------------------------------------------------------------
# Cache defs
# ---------------------------------------------------------------------------

def cache_defs(
    batch: int, max_len: int, spec: AttentionSpec, code: str = "F"
) -> dict:
    """Per-layer decode-cache defs (Param reused as a shaped placeholder)."""
    if spec.kind == "mla":
        return {
            "ckv": Param(
                (batch, max_len, spec.kv_lora),
                ("batch", "kv_seq", "lora"), init="zeros",
            ),
            "krope": Param(
                (batch, max_len, spec.rope_head_dim),
                ("batch", "kv_seq", None), init="zeros",
            ),
        }
    size = min(max_len, spec.window) if code == "L" and spec.window else max_len
    if code == "C" and spec.chunk:
        size = min(max_len, 2 * spec.chunk)  # ring over current+prev chunk
    return {
        "k": Param(
            (batch, spec.n_kv_heads, size, spec.d_head),
            ("batch", "kv_heads", "kv_seq", "head_dim"), init="zeros",
        ),
        "v": Param(
            (batch, spec.n_kv_heads, size, spec.d_head),
            ("batch", "kv_heads", "kv_seq", "head_dim"), init="zeros",
        ),
    }


# ---------------------------------------------------------------------------
# Apply: GQA
# ---------------------------------------------------------------------------

def _gqa_project(params, x, spec, positions, code):
    q = jnp.einsum("bsd,dhk->bhsk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bhsk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bhsk", x, params["w_v"])
    if spec.qk_norm:
        q = _rms(q, params["q_norm"])
        k = _rms(k, params["k_norm"])
    th = _theta(spec, code)
    q = rope(q, positions, th)
    k = rope(k, positions, th)
    # NOTE: deliberately not "seq"-sharded here.  Under sequence-parallel
    # rules x is seq-sharded at layer boundaries; attention needs the full
    # sequence per head, so q/k/v carry head sharding only — the implied
    # reshard is the Megatron-SP all-gather.  Seq-sharding KV when
    # kv_heads < TP degree trips XLA involuntary full rematerialization
    # against the q-chunked attention loop (observed on yi-6b: 16 GiB
    # replication copies in the backward).
    q = shard(q, "batch", "heads", None, "head_dim")
    k = shard(k, "batch", "kv_heads", None, "head_dim")
    v = shard(v, "batch", "kv_heads", None, "head_dim")
    return q, k, v


def gqa_train(params, x, spec: AttentionSpec, code: str):
    """Full-sequence attention; x (B,S,D)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _gqa_project(params, x, spec, positions, code)
    o = ops.attention(
        q, k, v,
        kind=_mask_kind(code), window=spec.window, chunk=spec.chunk,
    )
    out = jnp.einsum("bhsk,hkd->bsd", o, params["w_o"])
    return shard(out, "batch", "seq", "embed")


def _ring_positions(offsets: jax.Array, size: int) -> jax.Array:
    """Absolute position held by each ring slot *before* a chunk append.

    ``offsets`` (B,) is each row's cache fill.  Slot ``r`` holds the
    largest position ``p ≡ r (mod size)`` with ``p < offsets``; a negative
    result marks a hole (never-written slot).  For non-ring caches
    (``size >= max_len``) this degenerates to ``p = r`` for ``r < offsets``.
    """
    r = jnp.arange(size, dtype=jnp.int32)[None, :]
    return r + size * jnp.floor_divide(offsets[:, None] - 1 - r, size)


def _append_kv(cache, k_new, v_new, offsets, new_lens):
    """Offset-aware KV append: scatter chunk keys into each row's ring.

    Row ``b`` writes positions ``[offsets[b], offsets[b] + new_lens[b])``
    at ring slots ``pos % size``.  Chunk entries past ``new_lens`` — and,
    when the chunk is longer than the ring, entries the chunk itself would
    immediately overwrite — are routed to an out-of-bounds slot and
    dropped, so rows with ``new_lens == 0`` keep their cache bit-for-bit.
    """
    size = cache["k"].shape[2]
    B, _, S, _ = k_new.shape
    j = jnp.arange(S, dtype=jnp.int32)[None, :]
    keep = (j < new_lens[:, None]) & (j >= new_lens[:, None] - size)
    slot = jnp.where(keep, (offsets[:, None] + j) % size, size)
    bidx = jnp.arange(B)[:, None]
    return {
        "k": cache["k"].at[bidx, :, slot].set(
            k_new.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
            mode="drop",
        ),
        "v": cache["v"].at[bidx, :, slot].set(
            v_new.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
            mode="drop",
        ),
    }


def gqa_prefill(params, x, cache, spec: AttentionSpec, code: str):
    """Train-path attention + cache fill. Returns (out, cache)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _gqa_project(params, x, spec, positions, code)
    o = ops.attention(
        q, k, v,
        kind=_mask_kind(code), window=spec.window, chunk=spec.chunk,
    )
    zeros = jnp.zeros((B,), jnp.int32)
    cache = _append_kv(cache, k, v, zeros, zeros + S)
    out = jnp.einsum("bhsk,hkd->bsd", o, params["w_o"])
    return shard(out, "batch", "seq", "embed"), cache


def gqa_prefill_at(
    params, x, cache, offsets, new_lens, spec: AttentionSpec, code: str
):
    """Offset-aware chunk prefill: continue each row's cache in one pass.

    ``x`` (B, S, D) holds one prefill chunk; row ``b`` appends
    ``new_lens[b] <= S`` tokens at positions ``offsets[b]..``.  Queries
    attend causally within the chunk and fully (windowed / chunk-locally,
    by absolute position) against the prior cache — token-by-token decode
    replay semantics in a single dispatch, reading the prior cache once
    per chunk instead of once per token.  Rows with ``new_lens == 0`` are
    untouched.  Keys are compared in the cache's storage dtype so logits
    and cache match the decode replay exactly.
    """
    B, S, _ = x.shape
    offsets = offsets.astype(jnp.int32)
    new_lens = new_lens.astype(jnp.int32)
    positions = offsets[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = _gqa_project(params, x, spec, positions[:, None, :], code)

    size = cache["k"].shape[2]
    j = jnp.arange(S, dtype=jnp.int32)[None, :]
    kpos_new = jnp.where(j < new_lens[:, None], positions, -1)
    kpos = jnp.concatenate([_ring_positions(offsets, size), kpos_new], axis=1)
    kcat = jnp.concatenate(
        [cache["k"], k.astype(cache["k"].dtype)], axis=2
    )
    vcat = jnp.concatenate(
        [cache["v"], v.astype(cache["v"].dtype)], axis=2
    )
    o = ops.prefill_attention(
        q, kcat, vcat, positions, kpos,
        kind=_mask_kind(code), window=spec.window, chunk=spec.chunk,
    )
    cache = _append_kv(cache, k, v, offsets, new_lens)
    out = jnp.einsum("bhsk,hkd->bsd", o, params["w_o"])
    return shard(out, "batch", "seq", "embed"), cache


def gqa_decode(params, x, cache, lengths, spec: AttentionSpec, code: str):
    """One-token decode; x (B,1,D); lengths (B,) tokens already cached."""
    B = x.shape[0]
    positions = lengths[:, None, None]           # (B,1,1) for (B,H,1,dh)
    q = jnp.einsum("bsd,dhk->bhsk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bhsk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bhsk", x, params["w_v"])
    if spec.qk_norm:
        q = _rms(q, params["q_norm"])
        k = _rms(k, params["k_norm"])
    th = _theta(spec, code)
    q = rope(q, positions, th)[:, :, 0]          # (B,H,D)
    k = rope(k, positions, th)[:, :, 0]          # (B,Hkv,D)
    v = v[:, :, 0]

    size = cache["k"].shape[2]
    slot = (lengths % size).astype(jnp.int32)    # ring index
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, :, slot].set(k.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, :, slot].set(v.astype(cache["v"].dtype))

    if code == "L" and spec.window:
        valid = jnp.minimum(lengths + 1, size)
    elif code == "C" and spec.chunk:
        # entries in the current chunk (ring holds 2 chunks; mask the rest)
        valid = (lengths % spec.chunk) + 1
        # ring layout: we mask by recency -> approximate with ring validity
        valid = jnp.minimum(valid, size)
    else:
        valid = jnp.minimum(lengths + 1, size)
    o = ops.decode_attention(q, k_cache, v_cache, valid)
    out = jnp.einsum("bhk,hkd->bd", o, params["w_o"])[:, None]
    return shard(out, "batch", "seq", "embed"), {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Apply: MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_q(params, x, spec, positions):
    if "w_q_a" in params:
        qa = jnp.einsum("bsd,dr->bsr", x, params["w_q_a"])
        q = jnp.einsum("bsr,rhk->bhsk", qa, params["w_q_b"])
    else:
        q = jnp.einsum("bsd,dhk->bhsk", x, params["w_q"])
    qn = q[..., : spec.nope_head_dim]
    qr = rope(q[..., spec.nope_head_dim:], positions, spec.rope_theta)
    return qn, qr


def mla_train(params, x, spec: AttentionSpec, code: str = "F"):
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    qn, qr = _mla_q(params, x, spec, positions)
    ckv = jnp.einsum("bsd,dr->bsr", x, params["w_kv_a"])
    kr = rope(
        jnp.einsum("bsd,dk->bsk", x, params["w_k_rope"])[:, None],
        positions, spec.rope_theta,
    )                                             # (B,1,S,rope)
    kn = jnp.einsum("bsr,rhk->bhsk", ckv, params["w_k_b"])
    v = jnp.einsum("bsr,rhk->bhsk", ckv, params["w_v_b"])
    q = jnp.concatenate([qn, qr], -1)
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(kr, (*kn.shape[:-1], spec.rope_head_dim))], -1
    )
    q = shard(q, "batch", "heads", "seq", "head_dim")
    k = shard(k, "batch", "heads", "seq", "head_dim")
    v = shard(v, "batch", "heads", "seq", "head_dim")
    o = ops.attention(q, k, v, kind="causal")
    out = jnp.einsum("bhsk,hkd->bsd", o, params["w_o"])
    return shard(out, "batch", "seq", "embed")


def _append_latent(cache, ckv_new, kr_new, offsets, new_lens):
    """Offset-aware MLA latent append (non-ring: slot == position).

    Row ``b`` writes ``new_lens[b]`` latents at slots ``offsets[b]..``;
    entries past ``new_lens`` go to an out-of-bounds slot and are dropped.
    """
    Smax = cache["ckv"].shape[1]
    B, S, _ = ckv_new.shape
    j = jnp.arange(S, dtype=jnp.int32)[None, :]
    idx = jnp.where(
        j < new_lens[:, None],
        jnp.minimum(offsets[:, None] + j, Smax - 1),
        Smax,
    )
    bidx = jnp.arange(B)[:, None]
    return {
        "ckv": cache["ckv"].at[bidx, idx].set(
            ckv_new.astype(cache["ckv"].dtype), mode="drop"),
        "krope": cache["krope"].at[bidx, idx].set(
            kr_new.astype(cache["krope"].dtype), mode="drop"),
    }


def mla_prefill(params, x, cache, spec: AttentionSpec, code: str = "F"):
    B, S, _ = x.shape
    out = mla_train(params, x, spec, code)
    positions = jnp.arange(S)[None, :]
    ckv = jnp.einsum("bsd,dr->bsr", x, params["w_kv_a"])
    kr = rope(
        jnp.einsum("bsd,dk->bsk", x, params["w_k_rope"]),
        positions, spec.rope_theta,
    )
    zeros = jnp.zeros((B,), jnp.int32)
    cache = _append_latent(cache, ckv, kr, zeros, zeros + S)
    return out, cache


def mla_prefill_at(
    params, x, cache, offsets, new_lens, spec: AttentionSpec, code: str = "F"
):
    """Offset-aware absorbed-MLA chunk prefill (decode-replay semantics).

    Latents are scattered first (the cache is non-ring, slot == position),
    then the chunk's queries run the absorbed decode formulation against
    the updated cache with a causal mask on absolute positions — the same
    score layout, dtype path, and summation order as ``mla_decode``, so a
    chunked prefill reproduces the token-by-token replay exactly.
    """
    B, S, _ = x.shape
    offsets = offsets.astype(jnp.int32)
    new_lens = new_lens.astype(jnp.int32)
    positions = offsets[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    qn, qr = _mla_q(params, x, spec, positions[:, None, :])   # (B,H,S,*)

    ckv_new = jnp.einsum("bsd,dr->bsr", x, params["w_kv_a"])
    kr_new = rope(
        jnp.einsum("bsd,dk->bsk", x, params["w_k_rope"]),
        positions, spec.rope_theta,
    )
    cache = _append_latent(cache, ckv_new, kr_new, offsets, new_lens)
    ckv, kr = cache["ckv"], cache["krope"]
    Smax = ckv.shape[1]

    # absorbed scores, storage dtype through the einsums (see mla_decode)
    q_abs = jnp.einsum("bhsk,rhk->bhsr", qn, params["w_k_b"]).astype(ckv.dtype)
    scores = (
        jnp.einsum("bhsr,btr->bhst", q_abs, ckv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhsk,btk->bhst", qr.astype(kr.dtype), kr,
                     preferred_element_type=jnp.float32)
    ) * ((spec.nope_head_dim + spec.rope_head_dim) ** -0.5)
    kpos = jnp.arange(Smax, dtype=jnp.int32)[None, None, :]
    valid = kpos < jnp.minimum(positions + 1, Smax)[:, :, None]  # (B,S,Smax)
    scores = jnp.where(valid[:, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    ctx = jnp.einsum("bhst,btr->bhsr", p, ckv,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    o = jnp.einsum("bhsr,rhk->bhsk", ctx, params["w_v_b"])
    out = jnp.einsum("bhsk,hkd->bsd", o, params["w_o"])
    return shard(out, "batch", "seq", "embed"), cache


def mla_decode(params, x, cache, lengths, spec: AttentionSpec, code: str = "F"):
    """Absorbed MLA decode: reads scale with kv_lora, not n_heads*d_head."""
    B = x.shape[0]
    pos4 = lengths[:, None, None]                   # for (B,H,1,dh)
    qn, qr = _mla_q(params, x, spec, pos4)          # (B,H,1,*)
    qn, qr = qn[:, :, 0], qr[:, :, 0]               # (B,H,nope/rope)

    ckv_new = jnp.einsum("bsd,dr->bsr", x, params["w_kv_a"])[:, 0]
    kr_new = rope(
        jnp.einsum("bsd,dk->bsk", x, params["w_k_rope"]),
        lengths[:, None], spec.rope_theta,
    )[:, 0]

    bidx = jnp.arange(B)
    Smax = cache["ckv"].shape[1]
    slot = jnp.minimum(lengths, Smax - 1)
    ckv = cache["ckv"].at[bidx, slot].set(ckv_new.astype(cache["ckv"].dtype))
    kr = cache["krope"].at[bidx, slot].set(kr_new.astype(cache["krope"].dtype))

    # absorb: q_eff[b,h,r] = sum_k qn[b,h,k] * w_k_b[r,h,k]
    # NOTE: the streamed buffer (ckv/kr, the per-token read of the whole
    # cache) stays in its STORAGE dtype through the einsums; upcasting the
    # operand would make XLA hoist an f32 convert of the entire stacked
    # cache out of the decode loop (observed: 3 GB/device buffers + 2x
    # cache HBM traffic).  f32 accumulation via preferred_element_type.
    q_abs = jnp.einsum("bhk,rhk->bhr", qn, params["w_k_b"]).astype(ckv.dtype)
    scores = (
        jnp.einsum("bhr,bsr->bhs", q_abs, ckv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhk,bsk->bhs", qr.astype(kr.dtype), kr,
                     preferred_element_type=jnp.float32)
    ) * ((spec.nope_head_dim + spec.rope_head_dim) ** -0.5)
    valid = jnp.arange(Smax)[None, :] < jnp.minimum(lengths + 1, Smax)[:, None]
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", p, ckv,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    o = jnp.einsum("bhr,rhk->bhk", ctx, params["w_v_b"])
    out = jnp.einsum("bhk,hkd->bd", o, params["w_o"])[:, None]
    return shard(out, "batch", "seq", "embed"), {"ckv": ckv, "krope": kr}


# ---------------------------------------------------------------------------
# Unified dispatch
# ---------------------------------------------------------------------------

def attn_train(params, x, spec, code):
    if spec.kind == "mla":
        return mla_train(params, x, spec, code)
    return gqa_train(params, x, spec, code)


def attn_prefill(params, x, cache, spec, code):
    if spec.kind == "mla":
        return mla_prefill(params, x, cache, spec, code)
    return gqa_prefill(params, x, cache, spec, code)


def attn_prefill_at(params, x, cache, offsets, new_lens, spec, code):
    if spec.kind == "mla":
        return mla_prefill_at(params, x, cache, offsets, new_lens, spec, code)
    return gqa_prefill_at(params, x, cache, offsets, new_lens, spec, code)


def attn_decode(params, x, cache, lengths, spec, code):
    if spec.kind == "mla":
        return mla_decode(params, x, cache, lengths, spec, code)
    return gqa_decode(params, x, cache, lengths, spec, code)
