"""Replay validation: predicted-vs-measured times, per calibrated term.

The paper's figures are all of one shape — a theoretical bound next to an
achieved measurement, per datapath (Figs. 3, 5-9).  This module is that
shape as infrastructure: every dispatch we can both *predict* (from the
:mod:`repro.core.datapath` bounds under the active system) and *measure*
(serve Executor step timings, benchmark sweeps) is recorded as a
:class:`ReplayRecord`, grouped by the hardware term that dominates its
prediction, and summarized as per-term relative error with the limiting
link attached.

The summary drives a CI drift gate (:meth:`ReplayLog.gate`): when the
cost model's prediction for a term diverges from what the machine
actually does by more than a configurable threshold, CI fails loudly
instead of letting the planner keep pricing placements off a stale
model.  Thresholds are necessarily loose on CPU-emulated CI (host
devices share one memory system, so "ICI" collectives run at DRAM
speed); see docs/calibration.md for the tight values intended for real
hardware.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Mapping

__all__ = [
    "ReplayRecord",
    "TermError",
    "ReplayLog",
]

#: records kept verbatim per term; aggregates keep counting past the cap
_MAX_RECORDS_PER_TERM = 256

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class ReplayRecord:
    """One predicted-vs-measured observation.

    ``term`` names the calibrated constant the prediction leans on
    (e.g. ``hbm_bandwidth``, ``ici_link_bandwidth``, ``decode_step``);
    ``limiting_link`` is the datapath segment the bound said would
    dominate; ``source`` says which harness produced the measurement
    (``executor``, ``bench_membw``, ``calibrate``...).
    """

    term: str
    name: str
    predicted_s: float
    measured_s: float
    nbytes: int = 0
    limiting_link: str = ""
    source: str = ""

    @property
    def rel_error(self) -> float:
        """|predicted - measured| / measured (symmetric enough for a
        gate; guarded against zero-length measurements)."""
        return abs(self.predicted_s - self.measured_s) / max(
            self.measured_s, _EPS
        )

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj: Mapping) -> "ReplayRecord":
        return cls(**{f.name: obj[f.name] for f in dataclasses.fields(cls)
                      if f.name in obj})


@dataclasses.dataclass
class TermError:
    """Running per-term aggregate over every record ever seen."""

    term: str
    count: int = 0
    mean_rel_error: float = 0.0
    max_rel_error: float = 0.0
    worst_name: str = ""
    limiting_link: str = ""

    def update(self, rec: ReplayRecord) -> None:
        err = rec.rel_error
        self.count += 1
        self.mean_rel_error += (err - self.mean_rel_error) / self.count
        if err >= self.max_rel_error:
            self.max_rel_error = err
            self.worst_name = rec.name
            self.limiting_link = rec.limiting_link or self.limiting_link

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class ReplayLog:
    """Accumulates :class:`ReplayRecord` s and answers the gate question.

    Verbatim records are capped per term (aggregates are exact over the
    full stream) so a long serve soak cannot grow the log unboundedly.
    """

    def __init__(self) -> None:
        self._records: dict[str, list[ReplayRecord]] = {}
        self._errors: dict[str, TermError] = {}

    # -- recording --------------------------------------------------------
    def record(
        self,
        term: str,
        name: str,
        predicted_s: float,
        measured_s: float,
        *,
        nbytes: int = 0,
        limiting_link: str = "",
        source: str = "",
    ) -> ReplayRecord:
        rec = ReplayRecord(
            term=term,
            name=name,
            predicted_s=float(predicted_s),
            measured_s=float(measured_s),
            nbytes=int(nbytes),
            limiting_link=str(limiting_link),
            source=source,
        )
        self.add(rec)
        return rec

    def add(self, rec: ReplayRecord) -> None:
        if rec.measured_s <= 0.0:
            return  # clock glitch / unmeasured: nothing to validate
        bucket = self._records.setdefault(rec.term, [])
        if len(bucket) < _MAX_RECORDS_PER_TERM:
            bucket.append(rec)
        self._errors.setdefault(rec.term, TermError(rec.term)).update(rec)

    def extend(self, recs: Iterable[ReplayRecord]) -> None:
        for rec in recs:
            self.add(rec)

    # -- reporting --------------------------------------------------------
    def __len__(self) -> int:
        return sum(e.count for e in self._errors.values())

    @property
    def terms(self) -> tuple[str, ...]:
        return tuple(sorted(self._errors))

    def records(self, term: str | None = None) -> list[ReplayRecord]:
        if term is not None:
            return list(self._records.get(term, ()))
        return [r for t in sorted(self._records) for r in self._records[t]]

    def per_term_error(self) -> dict[str, TermError]:
        return {t: self._errors[t] for t in sorted(self._errors)}

    def report(self) -> str:
        """Human-readable per-term table (the CI artifact's text form)."""
        lines = [
            f"{'term':<22} {'n':>5} {'mean err':>9} {'max err':>9} "
            f"{'link':<8} worst"
        ]
        for term, err in self.per_term_error().items():
            lines.append(
                f"{term:<22} {err.count:>5d} {err.mean_rel_error:>8.1%} "
                f"{err.max_rel_error:>8.1%} {err.limiting_link:<8} "
                f"{err.worst_name}"
            )
        if len(lines) == 1:
            lines.append("(no replay records)")
        return "\n".join(lines)

    def gate(
        self,
        default_threshold: float,
        per_term: Mapping[str, float] | None = None,
    ) -> list[str]:
        """Drift-gate violations: terms whose *mean* relative error
        exceeds their threshold.  Empty list == gate passes."""
        per_term = dict(per_term or {})
        violations = []
        for term, err in self.per_term_error().items():
            threshold = per_term.get(term, default_threshold)
            if err.mean_rel_error > threshold:
                violations.append(
                    f"{term}: mean rel error {err.mean_rel_error:.1%} > "
                    f"gate {threshold:.1%} (n={err.count}, worst "
                    f"{err.worst_name} at {err.max_rel_error:.1%})"
                )
        return violations

    # -- persistence ------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "errors": {t: e.to_json() for t, e in self._errors.items()},
            "records": [r.to_json() for r in self.records()],
        }

    @classmethod
    def from_json(cls, obj: Mapping) -> "ReplayLog":
        log = cls()
        log.extend(ReplayRecord.from_json(r) for r in obj.get("records", ()))
        # aggregates rebuilt from records may undercount a capped stream;
        # prefer the persisted exact aggregates when present
        for term, e in obj.get("errors", {}).items():
            fields = {f.name for f in dataclasses.fields(TermError)}
            log._errors[term] = TermError(
                **{k: v for k, v in {**e, "term": term}.items()
                   if k in fields}
            )
        return log

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.to_json(), indent=2) + "\n")
        return path

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "ReplayLog":
        return cls.from_json(json.loads(pathlib.Path(path).read_text()))
