"""Placement planner: pick the placement policy the datapath model favors.

This automates the paper's §IV decision: given a workload's per-step byte
traffic per tensor role and the capacity of each memory pool, predict the
step time of every placement policy **from the datapath bounds** and choose
the fastest one that *fits*.  (The paper does this by hand across
Figs. 15-17; here it is a planner the launchers consult.)

v2 unification: every bandwidth/latency term is derived from
:mod:`repro.core.datapath` ``Bound`` objects — ``read_bound`` for in-place
accesses, ``copy_bound`` for streamed migrations (inheriting the
twice-traversed-link halving rule and per-segment latencies), and
``collective_bound`` for collective terms — never from raw ``chip.*``
bandwidth arithmetic.  The planner covers the full
:class:`~repro.core.hardware.MemoryTier` axis (local HBM/DRAM, peer
HBM/DRAM over ICI, remote HBM over DCN — the paper's HBM/DDR/HBM-p/DDR-p
columns) and accounts capacity per *pool*: local HBM (including the
double-buffered staging window a streamed tensor occupies), local host
DRAM, and the peer/remote donor pools.

Peer and remote pools model a *memory-donor* chip (the paper's peer-access
experiments: the donor's memory is idle while the accessor works), so their
capacity is one donor's full pool.

Peer/remote policies are executable, not analysis-only: the runtime
realizes them by sharding the role's tensors across a donor mesh axis
(:data:`repro.core.placement.DONOR_AXIS` over ICI,
:data:`~repro.core.placement.REMOTE_DONOR_AXIS` over DCN).  Callers derive
the ``allow_peer``/``allow_remote`` gates from the active mesh via
:func:`repro.core.placement.donor_allow_flags` — the auto-pick may select
a peer/remote tier exactly when the mesh has the donor axis that realizes
it.  When nothing fits, :func:`plan` either degrades to the smallest-HBM
policy (default) or, with ``require_fit=True``, raises
:class:`PlacementOOMError` reporting the overflow of every memory pool
per policy.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping

from repro.core.datapath import (
    Bound,
    collective_bound,
    copy_bound,
    read_bound,
)
from repro.core.hardware import Link, MemoryTier, SystemSpec, get_active_system
from repro.core.placement import (
    HOST_TIERS,
    PlacementPolicy,
    Role,
    Strategy,
    registered_policies,
)

#: capacity pool each tier's bytes are charged to
_TIER_POOL: dict[MemoryTier, str] = {
    MemoryTier.HBM: "hbm",
    MemoryTier.HOST: "host",
    MemoryTier.PEER_HBM: "peer_hbm",
    MemoryTier.PEER_HOST: "peer_host",
    MemoryTier.REMOTE_HBM: "remote_hbm",
}

#: which serialized-transfer bucket a bound's limiting link belongs to.
#: (HBM_BUS-limited transfers fold into the hbm term: they contend with
#: the compute pass for the same bus.)
_LINK_BUCKET: dict[Link, str] = {
    Link.PCIE: "pcie",
    Link.ICI: "ici",
    Link.DCN: "dcn",
    Link.HBM_BUS: "hbm",
    Link.VMEM_BUS: "hbm",
}


def pool_capacities(system: SystemSpec | None = None) -> dict[str, float]:
    """Capacity of every memory pool the planner accounts, in bytes."""
    system = system if system is not None else get_active_system()
    chip = system.chip
    return {
        "hbm": chip.hbm_capacity,
        "host": chip.host_dram_capacity,
        "peer_hbm": chip.hbm_capacity,          # one donor chip's HBM
        "peer_host": chip.host_dram_capacity,   # one donor host's DRAM
        "remote_hbm": chip.hbm_capacity,        # one remote chip's HBM
    }


@dataclasses.dataclass(frozen=True)
class CollectiveTerm:
    """One collective the step must run, timed via ``collective_bound``."""

    kind: str            # 'all_reduce' | 'all_gather' | ... (datapath kinds)
    link: Link           # the mesh axis's physical link (ICI or DCN)
    axis_size: int
    payload_bytes: float  # per-chip payload as collective_bound defines it

    def seconds(self, system: SystemSpec | None = None) -> float:
        bw = collective_bound(self.axis_size, self.link, self.kind, system)
        return self.payload_bytes / bw if bw != float("inf") else 0.0


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Per-chip, per-step workload description.

    ``bytes_per_role``: resident size of each role's tensors (per chip).
    ``touches_per_role``: how many times the role's bytes move through the
    compute datapath per step (params: 1 fwd read (+1 bwd read under remat);
    opt state: 1 read + 1 write; KV: 1 read per decoded token; ...).
    ``stream_chunks``: granularity of a streamed tensor's migration (layer
    count for layer-wise streaming) — sets both the per-touch latency count
    and the HBM staging-buffer footprint (double-buffered chunks).
    ``collectives``: collective terms timed via ``collective_bound``;
    ``collective_s`` adds pre-computed seconds (e.g. from a measured trace).
    """

    name: str
    flops: float
    bytes_per_role: Mapping[Role, float]
    touches_per_role: Mapping[Role, float]
    collective_s: float = 0.0
    collectives: tuple[CollectiveTerm, ...] = ()
    overlap_streams: bool = True   # host DMA overlaps compute (LHS scheduler)
    stream_chunks: int = 8


@dataclasses.dataclass
class PolicyPrediction:
    """Predicted step time + pool residency of one policy.

    Every ``*_s`` term except ``compute_s`` is datapath-bound-derived;
    ``limiting`` names the argmax term (the paper's bottleneck attribution).
    """

    policy: str
    fits: bool
    hbm_bytes: float               # local HBM pool, staging included
    host_bytes: float              # local host-DRAM pool
    bytes_by_pool: dict[str, float]
    overflow_pools: tuple[str, ...]
    compute_s: float
    hbm_s: float                   # local HBM-bus seconds
    pcie_s: float                  # PCIe-limited transfer seconds
    ici_s: float                   # ICI-limited transfer seconds
    dcn_s: float                   # DCN-limited transfer seconds
    collective_s: float
    step_s: float
    limiting: str

    def explain(self) -> str:
        pools = " ".join(
            f"{k} {v/2**30:.2f}GiB"
            for k, v in sorted(self.bytes_by_pool.items())
            if v > 0
        )
        return (
            f"{self.policy}: step={self.step_s*1e3:.3f} ms "
            f"[compute {self.compute_s*1e3:.3f} | hbm {self.hbm_s*1e3:.3f} "
            f"| pcie {self.pcie_s*1e3:.3f} | ici {self.ici_s*1e3:.3f} "
            f"| dcn {self.dcn_s*1e3:.3f} | coll {self.collective_s*1e3:.3f}] "
            f"limited by {self.limiting}; {pools}"
            + (
                ""
                if self.fits
                else f"  ** DOES NOT FIT: {', '.join(self.overflow_pools)} **"
            )
        )


def _touch_seconds(bound: Bound, nbytes: float, transfers: float) -> float:
    """Seconds for one touch moving ``nbytes`` in ``transfers`` pieces."""
    return nbytes / bound.bandwidth + transfers * bound.latency


def predict(
    profile: WorkloadProfile,
    policy: PlacementPolicy,
    system: SystemSpec | None = None,
) -> PolicyPrediction:
    """Predict ``policy``'s step time for ``profile`` from datapath bounds.

    Per role: HBM-resident bytes pay ``touches`` passes over the HBM read
    bound; streamed bytes pay ``touches`` migrations through
    ``copy_bound(tier, HBM)`` (halving rule + latency per chunk) *plus* the
    HBM pass, and occupy a double-buffered staging window in local HBM;
    far-tier-resident bytes pay ``touches`` in-place passes over the tier's
    ``read_bound``.  Transfer seconds are bucketed by each bound's limiting
    link; collective terms come from ``collective_bound``.
    """
    system = system if system is not None else get_active_system()
    chip = system.chip
    compute_s = profile.flops / chip.peak_bf16_flops

    hbm_read = read_bound(MemoryTier.HBM, system)
    chunks = max(int(profile.stream_chunks), 1)

    pools: dict[str, float] = {k: 0.0 for k in pool_capacities(system)}
    buckets = {"hbm": 0.0, "pcie": 0.0, "ici": 0.0, "dcn": 0.0}

    for role, nbytes in profile.bytes_per_role.items():
        touches = profile.touches_per_role.get(role, 1.0)
        pl = policy.placement(role)
        pool = _TIER_POOL[pl.tier]

        if pl.tier == MemoryTier.HBM:
            pools["hbm"] += nbytes
            buckets["hbm"] += touches * _touch_seconds(hbm_read, nbytes, 1)
        elif pl.strategy == Strategy.STREAM:
            # lives in the far tier; each touch is one chunked bulk
            # migration over the copy datapath plus one HBM compute pass,
            # through a double-buffered staging window in local HBM.
            pools[pool] += nbytes
            pools["hbm"] += 2.0 * nbytes / chunks
            cb = copy_bound(pl.tier, MemoryTier.HBM, system)
            buckets[_LINK_BUCKET[cb.limiting_link]] += (
                touches * _touch_seconds(cb, nbytes, chunks)
            )
            buckets["hbm"] += touches * _touch_seconds(hbm_read, nbytes, 1)
        else:
            # resident in a far tier, accessed in place — every touch
            # crosses the tier's full read datapath.
            pools[pool] += nbytes
            rb = read_bound(pl.tier, system)
            buckets[_LINK_BUCKET[rb.limiting_link]] += (
                touches * _touch_seconds(rb, nbytes, 1)
            )

    coll_s = profile.collective_s + sum(
        term.seconds(system) for term in profile.collectives
    )

    terms = {
        "compute": compute_s,
        "hbm": buckets["hbm"],
        "pcie": buckets["pcie"],
        "ici": buckets["ici"],
        "dcn": buckets["dcn"],
        "collective": coll_s,
    }
    if profile.overlap_streams:
        step_s = max(terms.values())
    else:
        step_s = (
            max(compute_s, buckets["hbm"])
            + buckets["pcie"] + buckets["ici"] + buckets["dcn"] + coll_s
        )
    limiting = max(terms, key=terms.get)

    caps = pool_capacities(system)
    overflow = tuple(
        k for k, v in pools.items() if v > caps[k]
    )

    return PolicyPrediction(
        policy=policy.name,
        fits=not overflow,
        hbm_bytes=pools["hbm"],
        host_bytes=pools["host"],
        bytes_by_pool=dict(pools),
        overflow_pools=overflow,
        compute_s=compute_s,
        hbm_s=buckets["hbm"],
        pcie_s=buckets["pcie"],
        ici_s=buckets["ici"],
        dcn_s=buckets["dcn"],
        collective_s=coll_s,
        step_s=step_s,
        limiting=limiting,
    )


def eligible_policies(
    policies: Iterable[PlacementPolicy] | None = None,
    *,
    allow_host: bool = True,
    allow_peer: bool = True,
    allow_remote: bool = True,
) -> list[PlacementPolicy]:
    """Filter policies to tiers the runtime can actually reach.

    ``allow_host=False`` when the backend exposes no host memory space
    (:func:`repro.core.placement.host_available`); ``allow_peer``/
    ``allow_remote`` track whether the mesh has the donor axis that
    realizes those tiers (``donor`` on ICI / ``donor_pod`` on DCN) —
    :func:`repro.core.placement.donor_allow_flags` derives all three from
    the active mesh.
    """
    out = []
    # note: an explicitly empty candidate list must stay empty (-> the
    # 'no eligible placement policies' error), not widen to the registry
    candidates = (
        registered_policies().values() if policies is None else policies
    )
    for p in candidates:
        tiers = p.tiers()
        if not allow_host and tiers & HOST_TIERS:
            continue
        if not allow_peer and tiers & {
            MemoryTier.PEER_HBM, MemoryTier.PEER_HOST
        }:
            continue
        if not allow_remote and MemoryTier.REMOTE_HBM in tiers:
            continue
        out.append(p)
    return out


class PlacementOOMError(RuntimeError):
    """No eligible policy fits; carries the per-pool overflow report."""

    def __init__(self, preds: list[PolicyPrediction],
                 system: SystemSpec | None = None):
        self.predictions = preds
        caps = pool_capacities(system)
        lines = []
        for p in preds:
            over = ", ".join(
                f"{pool} {p.bytes_by_pool[pool]/2**30:.2f}GiB "
                f"> cap {caps[pool]/2**30:.2f}GiB"
                for pool in p.overflow_pools
            )
            lines.append(f"  {p.policy}: {over}")
        super().__init__(
            "no placement policy fits every memory pool:\n" + "\n".join(lines)
        )


def plan(
    profile: WorkloadProfile,
    policies: Iterable[PlacementPolicy] | None = None,
    system: SystemSpec | None = None,
    *,
    allow_host: bool = True,
    allow_peer: bool = True,
    allow_remote: bool = True,
    require_fit: bool = False,
) -> tuple[PolicyPrediction, list[PolicyPrediction]]:
    """Evaluate eligible policies; return (best-feasible, all-predictions).

    Best = min step time among policies whose every pool fits; if none fit,
    the one with the smallest local-HBM residency (degraded but runnable) —
    mirroring the paper's observation that a slower placement that *runs*
    beats an OOM.  ``require_fit=True`` turns that fallback into a
    :class:`PlacementOOMError` whose message reports, per policy, every
    pool that overflows and by how much.
    """
    preds = [
        predict(profile, p, system)
        for p in eligible_policies(
            policies,
            allow_host=allow_host,
            allow_peer=allow_peer,
            allow_remote=allow_remote,
        )
    ]
    if not preds:
        raise ValueError("no eligible placement policies")
    feasible = [p for p in preds if p.fits]
    if feasible:
        best = min(feasible, key=lambda p: p.step_s)
    elif require_fit:
        raise PlacementOOMError(preds, system)
    else:
        best = min(preds, key=lambda p: p.hbm_bytes)
    return best, preds


# ---------------------------------------------------------------------------
# Profile builders for the framework's own workloads
# ---------------------------------------------------------------------------

def train_profile(
    *,
    name: str,
    param_bytes: float,
    step_flops: float,
    activation_bytes: float,
    collective_s: float = 0.0,
    num_chips: int = 1,
    remat: bool = True,
    n_layers: int = 8,
    data_axis_size: int = 1,
    pod_axis_size: int = 1,
) -> WorkloadProfile:
    """Per-chip training-step profile from global model numbers.

    Adam: master (4B/param as f32 vs 2B resident bf16 params -> x2 params
    bytes), moments 2 x 4B/param; grads 2B/param.  When the mesh has a
    data (ICI) or pod (DCN) axis, the per-step gradient all-reduce is added
    as a ``CollectiveTerm`` so ``collective_bound`` prices it.
    """
    p = param_bytes / num_chips
    act = activation_bytes / num_chips
    collectives = []
    # The gradient buffer is sharded over the model axis only and
    # replicated over data AND pod (that replication is what the data/pod
    # all-reduces resolve), so the per-chip payload of BOTH reductions is
    # param_bytes / model_size = p * data_axis_size * pod_axis_size.
    grad_payload = p * data_axis_size * pod_axis_size
    if data_axis_size > 1:
        collectives.append(
            CollectiveTerm("all_reduce", Link.ICI, data_axis_size, grad_payload)
        )
    if pod_axis_size > 1:
        collectives.append(
            CollectiveTerm("all_reduce", Link.DCN, pod_axis_size, grad_payload)
        )
    return WorkloadProfile(
        name=name,
        flops=step_flops / num_chips,
        bytes_per_role={
            Role.PARAMS: p,
            Role.MASTER: 2.0 * p,
            Role.OPT_STATE: 4.0 * p,
            Role.GRADS: p,
            Role.ACTIVATIONS: act,
        },
        touches_per_role={
            Role.PARAMS: 3.0 if remat else 2.0,  # fwd + bwd (+ remat fwd)
            Role.MASTER: 2.0,                    # read + write
            Role.OPT_STATE: 2.0,
            Role.GRADS: 2.0,
            Role.ACTIVATIONS: 2.0,
        },
        collective_s=collective_s,
        collectives=tuple(collectives),
        stream_chunks=max(int(n_layers), 1),
    )


def decode_profile(
    *,
    name: str,
    param_bytes: float,
    kv_bytes: float,
    step_flops: float,
    collective_s: float = 0.0,
    num_chips: int = 1,
    n_layers: int = 8,
) -> WorkloadProfile:
    """Per-chip single-token decode profile (paper Fig. 17 regime):
    reads all params + all KV once per token."""
    return WorkloadProfile(
        name=name,
        flops=step_flops / num_chips,
        bytes_per_role={
            Role.PARAMS: param_bytes / num_chips,
            Role.KV_CACHE: kv_bytes / num_chips,
        },
        touches_per_role={Role.PARAMS: 1.0, Role.KV_CACHE: 1.0},
        collective_s=collective_s,
        stream_chunks=max(int(n_layers), 1),
    )


def prefill_profile(
    *,
    name: str,
    param_bytes: float,
    kv_bytes: float,
    chunk_flops: float,
    activation_bytes: float = 0.0,
    collective_s: float = 0.0,
    num_chips: int = 1,
    n_layers: int = 8,
) -> WorkloadProfile:
    """Per-chip chunked-prefill profile (one batched admission dispatch).

    The serve engine writes whole prompt chunks per dispatch instead of
    replaying tokens through decode steps, so per chunk the params move
    through the datapath once, and the KV role is touched ~once: the chunk
    appends its keys (a write of ``chunk/max_len`` of the cache) and reads
    the prior cache, which over a full prompt averages half the final
    cache per chunk — together one cache-sized pass through whatever
    datapath (HBM bus, PCIe stream, donor link) the policy places the
    cache behind.  Capacity-wise prefill peaks *above* decode by the
    chunk's activations, so a policy must fit this profile too before the
    engine adopts it.
    """
    return WorkloadProfile(
        name=name,
        flops=chunk_flops / num_chips,
        bytes_per_role={
            Role.PARAMS: param_bytes / num_chips,
            Role.KV_CACHE: kv_bytes / num_chips,
            Role.ACTIVATIONS: activation_bytes / num_chips,
        },
        touches_per_role={
            Role.PARAMS: 1.0,
            Role.KV_CACHE: 1.0,
            Role.ACTIVATIONS: 2.0,   # written by the chunk, read back
        },
        collective_s=collective_s,
        stream_chunks=max(int(n_layers), 1),
    )


# ---------------------------------------------------------------------------
# Disaggregated-serve pool split (prefill pool vs decode pool)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PoolSplitPrediction:
    """One candidate prefill/decode device split, priced per pool.

    ``prefill_tps`` is prompt-token ingest rate of the prefill pool (one
    chunked dispatch ingests ``batch_slots * prefill_chunk`` tokens);
    ``decode_tps`` is the decode pool's generation rate (one step yields
    ``batch_slots`` tokens).  In steady state the cluster moves at the
    slower pool — the bottleneck rate — so the planner maximizes
    ``min(prefill_tps, decode_tps)``, i.e. minimizes the tok/s imbalance
    between the pools subject to both phases fitting their pool's
    capacity.
    """

    prefill_devices: int
    decode_devices: int
    prefill_tps: float
    decode_tps: float
    prefill: PolicyPrediction
    decode: PolicyPrediction

    @property
    def fits(self) -> bool:
        return self.prefill.fits and self.decode.fits

    @property
    def bottleneck_tps(self) -> float:
        return min(self.prefill_tps, self.decode_tps)

    @property
    def imbalance(self) -> float:
        """max/min tok/s ratio across the pools (1.0 = balanced)."""
        lo = max(self.bottleneck_tps, 1e-30)
        return max(self.prefill_tps, self.decode_tps) / lo


def plan_pool_split(
    bundle,
    num_devices: int,
    *,
    batch_slots: int,
    max_len: int,
    prefill_chunk: int,
    policies: Iterable[PlacementPolicy] | None = None,
    system: SystemSpec | None = None,
    allow_host: bool = True,
    allow_peer: bool = False,
    allow_remote: bool = False,
) -> tuple[PoolSplitPrediction, list[PoolSplitPrediction]]:
    """Choose the prefill/decode device split for a disaggregated cluster.

    For every split ``(p, d)`` with ``p + d == num_devices`` and at least
    one device per pool, price the prefill pool on the bundle's
    :func:`prefill_profile` over ``p`` chips and the decode pool on its
    :func:`decode_profile` over ``d`` chips (each pool picks its own best
    eligible policy via :func:`plan`), then take the split with the
    highest **bottleneck** token rate — equivalently the smallest
    prefill-vs-decode tok/s imbalance that still fits both pools'
    capacities.  Splits where either phase overflows are only used when
    *no* split fits (degraded, like :func:`plan`'s fallback).

    The per-pool ``allow_*`` flags default to local-tiers-only: each pool
    is a plain compute mesh (the donor_pod axis exists only on the bridge
    mesh the handoff uses), so peer/remote placements are not realizable
    *inside* a pool unless the caller built pool-local donor axes.

    Returns ``(best, all_candidates)``; an explicit
    :class:`repro.core.placement.PoolSplit` override skips this planner
    entirely (see ``repro.serve.disagg.Cluster``).
    """
    from repro.configs import ShapeSpec

    if num_devices < 2:
        raise ValueError(
            f"a disaggregated cluster needs >= 2 devices, got {num_devices}"
        )
    shape = ShapeSpec("serve", max_len, batch_slots, "decode")
    allow = dict(
        allow_host=allow_host, allow_peer=allow_peer,
        allow_remote=allow_remote,
    )
    cands: list[PoolSplitPrediction] = []
    for p in range(1, num_devices):
        d = num_devices - p
        pre_prof = bundle.prefill_workload(
            shape, chunk_tokens=prefill_chunk, num_chips=p
        )
        dec_prof = bundle.decode_workload(shape, num_chips=d)
        pre_best, _ = plan(pre_prof, policies, system, **allow)
        dec_best, _ = plan(dec_prof, policies, system, **allow)
        cands.append(PoolSplitPrediction(
            prefill_devices=p,
            decode_devices=d,
            prefill_tps=(
                batch_slots * max(prefill_chunk, 1) / pre_best.step_s
                if pre_best.step_s > 0 else float("inf")
            ),
            decode_tps=(
                batch_slots / dec_best.step_s
                if dec_best.step_s > 0 else float("inf")
            ),
            prefill=pre_best,
            decode=dec_best,
        ))
    feasible = [c for c in cands if c.fits]
    pool = feasible or cands
    best = max(pool, key=lambda c: c.bottleneck_tps)
    return best, cands
