"""Placement planner: pick the placement policy the datapath model favors.

This automates the paper's §IV decision: given a workload's per-step byte
traffic per tensor role and the capacity of each memory pool, predict the
step time of every placement policy from the datapath bounds and choose the
fastest one that *fits*.  (The paper does this by hand across Figs. 15-17;
here it is a planner the launcher consults.)
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping

from repro.core.datapath import copy_bound, read_bound
from repro.core.hardware import DEFAULT_SYSTEM, MemoryTier, SystemSpec
from repro.core.placement import (
    POLICIES,
    PlacementPolicy,
    Role,
    Strategy,
)


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Per-chip, per-step workload description.

    ``bytes_per_role``: resident size of each role's tensors (per chip).
    ``touches_per_role``: how many times the role's bytes move through the
    compute datapath per step (params: 1 fwd read (+1 bwd read under remat);
    opt state: 1 read + 1 write; KV: 1 read per decoded token; ...).
    """

    name: str
    flops: float
    bytes_per_role: Mapping[Role, float]
    touches_per_role: Mapping[Role, float]
    collective_s: float = 0.0
    overlap_streams: bool = True   # host DMA overlaps compute (LHS scheduler)


@dataclasses.dataclass
class PolicyPrediction:
    policy: str
    fits: bool
    hbm_bytes: float
    host_bytes: float
    compute_s: float
    hbm_s: float
    pcie_s: float
    collective_s: float
    step_s: float
    limiting: str

    def explain(self) -> str:
        return (
            f"{self.policy}: step={self.step_s*1e3:.3f} ms "
            f"[compute {self.compute_s*1e3:.3f} | hbm {self.hbm_s*1e3:.3f} "
            f"| pcie {self.pcie_s*1e3:.3f} | coll {self.collective_s*1e3:.3f}] "
            f"limited by {self.limiting}; "
            f"hbm {self.hbm_bytes/2**30:.2f} GiB"
            + ("" if self.fits else "  ** DOES NOT FIT **")
        )


def predict(
    profile: WorkloadProfile,
    policy: PlacementPolicy,
    system: SystemSpec = DEFAULT_SYSTEM,
) -> PolicyPrediction:
    chip = system.chip
    compute_s = profile.flops / chip.peak_bf16_flops

    hbm_resident = 0.0
    host_resident = 0.0
    hbm_traffic = 0.0
    pcie_traffic = 0.0

    for role, nbytes in profile.bytes_per_role.items():
        touches = profile.touches_per_role.get(role, 1.0)
        pl = policy.placement(role)
        if pl.tier == MemoryTier.HBM:
            hbm_resident += nbytes
            hbm_traffic += nbytes * touches
        elif pl.strategy == Strategy.STREAM:
            # lives on host; each use = one PCIe bulk move + HBM pass
            host_resident += nbytes
            pcie_traffic += nbytes * touches
            hbm_traffic += nbytes * touches
            # streamed working set also occupies a small HBM staging buffer,
            # assumed layer-granular (<= 2 layers) and ignored for capacity.
        else:
            # resident on host, accessed in place — per-touch PCIe traffic
            host_resident += nbytes
            pcie_traffic += nbytes * touches

    hbm_s = hbm_traffic / chip.hbm_bandwidth
    pcie_s = pcie_traffic / chip.pcie_bandwidth
    coll_s = profile.collective_s

    if profile.overlap_streams:
        step_s = max(compute_s, hbm_s, pcie_s, coll_s)
    else:
        step_s = max(compute_s, hbm_s) + pcie_s + coll_s

    terms = {
        "compute": compute_s,
        "hbm": hbm_s,
        "pcie": pcie_s,
        "collective": coll_s,
    }
    limiting = max(terms, key=terms.get)
    fits = hbm_resident <= chip.hbm_capacity

    return PolicyPrediction(
        policy=policy.name,
        fits=fits,
        hbm_bytes=hbm_resident,
        host_bytes=host_resident,
        compute_s=compute_s,
        hbm_s=hbm_s,
        pcie_s=pcie_s,
        collective_s=coll_s,
        step_s=step_s,
        limiting=limiting,
    )


def plan(
    profile: WorkloadProfile,
    policies: Iterable[PlacementPolicy] | None = None,
    system: SystemSpec = DEFAULT_SYSTEM,
) -> tuple[PolicyPrediction, list[PolicyPrediction]]:
    """Evaluate all policies; return (best-feasible, all-predictions).

    Best = min step time among policies that fit HBM; if none fit, the one
    with the smallest HBM residency (degraded but runnable) — mirroring the
    paper's observation that a slower placement that *runs* beats an OOM.
    """
    preds = [
        predict(profile, p, system)
        for p in (policies or POLICIES.values())
    ]
    feasible = [p for p in preds if p.fits]
    if feasible:
        best = min(feasible, key=lambda p: p.step_s)
    else:
        best = min(preds, key=lambda p: p.hbm_bytes)
    return best, preds


# ---------------------------------------------------------------------------
# Profile builders for the framework's own workloads
# ---------------------------------------------------------------------------

def train_profile(
    *,
    name: str,
    param_bytes: float,
    step_flops: float,
    activation_bytes: float,
    collective_s: float = 0.0,
    num_chips: int = 1,
    remat: bool = True,
) -> WorkloadProfile:
    """Per-chip training-step profile from global model numbers.

    Adam: master (4B/param as f32 vs 2B resident bf16 params -> x2 params
    bytes), moments 2 x 4B/param; grads 2B/param.
    """
    p = param_bytes / num_chips
    act = activation_bytes / num_chips
    return WorkloadProfile(
        name=name,
        flops=step_flops / num_chips,
        bytes_per_role={
            Role.PARAMS: p,
            Role.MASTER: 2.0 * p,
            Role.OPT_STATE: 4.0 * p,
            Role.GRADS: p,
            Role.ACTIVATIONS: act,
        },
        touches_per_role={
            Role.PARAMS: 3.0 if remat else 2.0,  # fwd + bwd (+ remat fwd)
            Role.MASTER: 2.0,                    # read + write
            Role.OPT_STATE: 2.0,
            Role.GRADS: 2.0,
            Role.ACTIVATIONS: 2.0,
        },
        collective_s=collective_s,
    )


def decode_profile(
    *,
    name: str,
    param_bytes: float,
    kv_bytes: float,
    step_flops: float,
    collective_s: float = 0.0,
    num_chips: int = 1,
) -> WorkloadProfile:
    """Per-chip single-token decode profile (paper Fig. 17 regime):
    reads all params + all KV once per token."""
    return WorkloadProfile(
        name=name,
        flops=step_flops / num_chips,
        bytes_per_role={
            Role.PARAMS: param_bytes / num_chips,
            Role.KV_CACHE: kv_bytes / num_chips,
        },
        touches_per_role={Role.PARAMS: 1.0, Role.KV_CACHE: 1.0},
        collective_s=collective_s,
    )
