"""Placement policies: where every tensor role physically lives.

The paper's application studies (§IV) show that the *physical placement* of
each buffer — not just its sharding — decides performance, and that the
decision is per-role: GEMM source matrices care (reads dominate), the
destination does not; KV-type read-mostly buffers benefit from the big slow
pool only when the fast pool is full.

A :class:`PlacementPolicy` maps tensor roles to placements over the **full**
:class:`repro.core.hardware.MemoryTier` axis — local HBM, local host DRAM,
a peer chip's HBM / host DRAM over ICI, and a remote pod's HBM over DCN —
mirroring the paper's {HBM, DDR, HBM-p, DDR-p} columns (Figs. 5/7/9 and the
§IV application tables).  The planner (:mod:`repro.core.planner`) predicts
each policy's step time from the datapath bounds and picks the best that
fits every memory pool; the train/serve steps consume the chosen policy.

Physical realization on the runtime: JAX exposes ``NamedSharding(mesh,
spec, memory_kind=...)`` with kinds ``device`` (HBM), ``pinned_host`` and
``unpinned_host`` — the TPU analogue of the paper's Table II allocation
APIs (``numa_alloc_onnode`` ≈ explicit memory_kind; first-touch ≈ default
``device``).  Peer/remote tiers are realized as *device* memory on a donor
mesh axis (the bytes live in HBM, just a hop away — exactly the paper's
HBM-p case), so their memory kind is ``device``.  Not every backend exposes
every kind (the CPU backend of older jax exposes only ``unpinned_host``),
so every kind the policy requests is passed through
:func:`resolve_memory_kind`, which degrades gracefully to what the backend
actually has.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.hardware import MemoryTier


class Role(str, enum.Enum):
    PARAMS = "params"            # model weights (read every step)
    MASTER = "master"            # f32 master copy of params (optimizer)
    OPT_STATE = "opt_state"      # Adam moments
    GRADS = "grads"              # gradient buffers
    ACTIVATIONS = "activations"  # step-local
    KV_CACHE = "kv_cache"        # decode-state, read-mostly, grows with seq
    INPUTS = "inputs"            # token batches


class Strategy(str, enum.Enum):
    RESIDENT = "resident"   # lives in its tier; computed on in place
    STREAM = "stream"       # lives in a far tier; bulk-moved each use
                            # (paper: "managed"-like — pay the migration,
                            #  then access at HBM speed)


#: memory_kind strings understood by jax shardings, per tier.  Peer and
#: remote HBM are device memory reached over ICI/DCN (donor-axis sharding);
#: peer host DRAM is pinned host memory on the donor's host.
_TIER_TO_KIND = {
    MemoryTier.HBM: "device",
    MemoryTier.HOST: "pinned_host",
    MemoryTier.PEER_HBM: "device",
    MemoryTier.PEER_HOST: "pinned_host",
    MemoryTier.REMOTE_HBM: "device",
}

#: tiers whose bytes live in a host DRAM pool (vs an HBM pool).
HOST_TIERS = frozenset({MemoryTier.HOST, MemoryTier.PEER_HOST})


# ---------------------------------------------------------------------------
# Backend memory-kind capability (API-drift + hardware-capability shim)
# ---------------------------------------------------------------------------

# Successful probes are memoized; failures are NOT (a query racing backend
# init — e.g. before jax.distributed.initialize — must not pin the
# "no memory kinds" fallback for the process lifetime).
_KINDS_CACHE: frozenset[str] | None = None
_DEFAULT_KIND_CACHE: str | None = None


def available_memory_kinds() -> frozenset[str]:
    """Memory kinds the default backend's device 0 can address."""
    global _KINDS_CACHE
    if _KINDS_CACHE is None:
        try:
            _KINDS_CACHE = frozenset(
                m.kind for m in jax.devices()[0].addressable_memories()
            )
        except Exception:
            return frozenset()
    return _KINDS_CACHE


def default_memory_kind() -> str | None:
    """The backend's default memory kind (``device`` on TPU)."""
    global _DEFAULT_KIND_CACHE
    if _DEFAULT_KIND_CACHE is None:
        try:
            _DEFAULT_KIND_CACHE = jax.devices()[0].default_memory().kind
        except Exception:
            return None
    return _DEFAULT_KIND_CACHE


def resolve_memory_kind(kind: str | None) -> str | None:
    """Map a requested memory kind onto what the backend exposes.

    ``None`` means "backend default" and always works.  Unavailable kinds
    degrade: ``pinned_host`` falls back to ``unpinned_host`` when only that
    is exposed, and anything else falls back to the backend default — the
    graceful path for CPU backends where host DRAM *is* device memory.
    """
    if kind is None:
        return None
    kinds = available_memory_kinds()
    if kind in kinds:
        return kind
    if kind == "pinned_host" and "unpinned_host" in kinds:
        if default_memory_kind() != "unpinned_host":
            return "unpinned_host"
    return None


def host_available() -> bool:
    """Does this backend expose a host memory space distinct from device
    memory?  False on CPU backends (host DRAM *is* the default memory), in
    which case offload policies are placement no-ops and the planner should
    not prefer them."""
    kinds = available_memory_kinds()
    default = default_memory_kind()
    return any(
        k.endswith("host") and k != default for k in kinds
    ) and default is not None and not default.endswith("host")


@dataclasses.dataclass(frozen=True)
class Placement:
    tier: MemoryTier = MemoryTier.HBM
    strategy: Strategy = Strategy.RESIDENT

    @property
    def raw_memory_kind(self) -> str:
        """The memory kind this tier wants, ignoring backend capability."""
        return _TIER_TO_KIND.get(self.tier, "device")

    @property
    def memory_kind(self) -> str | None:
        """The memory kind to actually hand to jax on this backend."""
        return resolve_memory_kind(self.raw_memory_kind)

    @property
    def on_host(self) -> bool:
        return self.tier in HOST_TIERS


@dataclasses.dataclass(frozen=True)
class PlacementPolicy:
    """Named per-role placement map (the paper's 'allocation policy')."""

    name: str
    placements: Mapping[Role, Placement]
    description: str = ""

    def placement(self, role: Role) -> Placement:
        return self.placements.get(role, Placement())

    def memory_kind(self, role: Role) -> str | None:
        return self.placement(role).memory_kind

    def raw_memory_kind(self, role: Role) -> str:
        return self.placement(role).raw_memory_kind

    def tiers(self) -> frozenset[MemoryTier]:
        """Every tier this policy places at least one role in."""
        return frozenset(
            {MemoryTier.HBM} | {p.tier for p in self.placements.values()}
        )

    @property
    def uses_host(self) -> bool:
        return any(p.on_host for p in self.placements.values())

    def sharding(
        self, mesh: Mesh, spec: PartitionSpec, role: Role
    ) -> NamedSharding:
        return NamedSharding(mesh, spec, memory_kind=self.memory_kind(role))

    def with_placement(self, role: Role, placement: Placement) -> "PlacementPolicy":
        p = dict(self.placements)
        p[role] = placement
        return PlacementPolicy(self.name, p, self.description)


def _policy(name: str, desc: str, **roles: Placement) -> PlacementPolicy:
    return PlacementPolicy(
        name,
        {Role[k.upper()]: v for k, v in roles.items()},
        desc,
    )


HBM = Placement(MemoryTier.HBM, Strategy.RESIDENT)
HOST = Placement(MemoryTier.HOST, Strategy.RESIDENT)
HOST_STREAM = Placement(MemoryTier.HOST, Strategy.STREAM)
PEER_HBM = Placement(MemoryTier.PEER_HBM, Strategy.RESIDENT)
PEER_HBM_STREAM = Placement(MemoryTier.PEER_HBM, Strategy.STREAM)
PEER_HOST_STREAM = Placement(MemoryTier.PEER_HOST, Strategy.STREAM)
REMOTE_HBM = Placement(MemoryTier.REMOTE_HBM, Strategy.RESIDENT)


#: Paper-faithful default: everything in fast memory ("local HBM" column of
#: every paper figure — the best-performing placement when it fits).
HBM_RESIDENT = _policy(
    "hbm_resident",
    "all tensors in device HBM (paper's local-HBM baseline)",
)

#: Optimizer-state offload: master weights + moments live in host DRAM and
#: are streamed through once per step (ZeRO-Offload-style).  Trades PCIe
#: bandwidth for ~12 bytes/param of HBM.
OPT_HOST = _policy(
    "opt_host",
    "Adam moments + f32 master in host DRAM, streamed once per step",
    master=HOST_STREAM,
    opt_state=HOST_STREAM,
)

#: KV cache on host, streamed per decode step (long-context serving when the
#: cache exceeds HBM; paper Fig. 17's DDR rows).
KV_HOST = _policy(
    "kv_host",
    "KV cache in host DRAM, streamed per decode step",
    kv_cache=HOST_STREAM,
)

#: Layer-wise weight streaming (serving models bigger than aggregate HBM;
#: paper Fig. 17 'weights on DDR').
WEIGHTS_STREAM = _policy(
    "weights_stream",
    "weights resident in host DRAM, streamed layer-by-layer",
    params=HOST_STREAM,
)

#: KV cache in a peer chip's HBM, read in place over ICI — the paper's
#: HBM-p column (peer HBM beats local DDR whenever the chip-to-chip link
#: outruns the host link, which it does on both GH200 and TPU).
KV_PEER_HBM = _policy(
    "kv_peer_hbm",
    "KV cache resident in a peer chip's HBM, read in place over ICI",
    kv_cache=PEER_HBM,
)

#: Weights streamed from a peer chip's HBM (Figs. 15-16: GEMM sources in
#: HBM-p) — the serving regime where a memory-donor chip holds the cold
#: layers and ships them over ICI ahead of use.
WEIGHTS_PEER_HBM = _policy(
    "weights_peer_hbm",
    "weights resident in peer HBM, streamed layer-by-layer over ICI",
    params=PEER_HBM_STREAM,
)

#: Optimizer state spilled to a *peer's* host DRAM (DDR-p column): the
#: escape hatch when local host DRAM is full — pays ICI+PCIe per step.
OPT_PEER_HOST = _policy(
    "opt_peer_host",
    "Adam moments + f32 master in a peer's host DRAM (spill-to-peer-host)",
    master=PEER_HOST_STREAM,
    opt_state=PEER_HOST_STREAM,
)

#: KV cache in a remote pod's HBM over DCN — the inter-node tier the paper
#: reaches once a node's four-superchip pool is exhausted.
KV_REMOTE_HBM = _policy(
    "kv_remote_hbm",
    "KV cache resident in a remote pod's HBM, read in place over DCN",
    kv_cache=REMOTE_HBM,
)

POLICIES: dict[str, PlacementPolicy] = {
    p.name: p
    for p in (
        HBM_RESIDENT,
        OPT_HOST,
        KV_HOST,
        WEIGHTS_STREAM,
        KV_PEER_HBM,
        WEIGHTS_PEER_HBM,
        OPT_PEER_HOST,
        KV_REMOTE_HBM,
    )
}


def put_like(tree, mesh: Mesh, specs, role: Role, policy: PlacementPolicy):
    """device_put a pytree under the policy's placement for ``role``.

    ``specs`` is a matching pytree of PartitionSpecs (or a single spec).
    """
    def _put(x, spec):
        return jax.device_put(x, policy.sharding(mesh, spec, role))

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _put(x, specs), tree)
    return jax.tree.map(_put, tree, specs)


def to_device(tree, mesh: Mesh, specs):
    """Move a (possibly host-placed) pytree into HBM inside a jit region.

    This is the 'migration' step of a STREAM placement: under jit, XLA turns
    it into a host->device DMA that the latency-hiding scheduler can overlap
    with compute (the TPU analogue of managed-memory prefetch).
    """
    kind = resolve_memory_kind("device")

    def _mv(x, spec):
        return jax.device_put(
            x, NamedSharding(mesh, spec, memory_kind=kind)
        )

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _mv(x, specs), tree)
    return jax.tree.map(_mv, tree, specs)


def to_host(tree, mesh: Mesh, specs):
    """Move a pytree to (pinned) host memory inside a jit region."""
    kind = resolve_memory_kind("pinned_host")

    def _mv(x, spec):
        return jax.device_put(
            x, NamedSharding(mesh, spec, memory_kind=kind)
        )

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _mv(x, specs), tree)
    return jax.tree.map(_mv, tree, specs)
