"""Placement policies: where every tensor role physically lives.

The paper's application studies (§IV) show that the *physical placement* of
each buffer — not just its sharding — decides performance, and that the
decision is per-role: GEMM source matrices care (reads dominate), the
destination does not; KV-type read-mostly buffers benefit from the big slow
pool only when the fast pool is full.

A :class:`PlacementPolicy` maps tensor roles to placements over the **full**
:class:`repro.core.hardware.MemoryTier` axis — local HBM, local host DRAM,
a peer chip's HBM / host DRAM over ICI, and a remote pod's HBM over DCN —
mirroring the paper's {HBM, DDR, HBM-p, DDR-p} columns (Figs. 5/7/9 and the
§IV application tables).  The planner (:mod:`repro.core.planner`) predicts
each policy's step time from the datapath bounds and picks the best that
fits every memory pool; the train/serve steps consume the chosen policy.

Physical realization on the runtime: JAX exposes ``NamedSharding(mesh,
spec, memory_kind=...)`` with kinds ``device`` (HBM), ``pinned_host`` and
``unpinned_host`` — the TPU analogue of the paper's Table II allocation
APIs (``numa_alloc_onnode`` ≈ explicit memory_kind; first-touch ≈ default
``device``).  Not every backend exposes every kind (the CPU backend of
older jax exposes only ``unpinned_host``), so every kind the policy
requests is passed through :func:`resolve_memory_kind`, which degrades
gracefully to what the backend actually has.

Peer and remote tiers are **executable**, not analysis-only: they are
realized on a *donor mesh axis* (see :mod:`repro.launch.mesh`).  A mesh
axis named :data:`DONOR_AXIS` (``"donor"``, an ICI axis) marks a group of
chips whose memory is donated to the computation — far-tier tensors are
sharded across that axis (each donor slice holds ``1/axis_size`` of the
bytes in its own pool, a hop away over the link, exactly the paper's HBM-p
placement), while every local-tier tensor ignores the axis and is
replicated over it.  :data:`REMOTE_DONOR_AXIS` (``"donor_pod"``) is the
same convention one interconnect further out: a donor group reached over
DCN, realizing :attr:`MemoryTier.REMOTE_HBM`.  ``PEER_HBM``/``REMOTE_HBM``
keep memory kind ``device`` (the bytes live in a peer's HBM);
``PEER_HOST`` pins to the donor's host DRAM.  :func:`put_like` and
:func:`repro.models.sharding.policy_specs` emit donor-extended specs;
:func:`validate_policy_for_mesh` refuses to realize a peer/remote policy
on a mesh without the required axis — a placement must never silently
degrade to ``hbm_resident`` (and then OOM where the planner predicted a
fit).  :class:`DonorStream` is the ``Strategy.STREAM`` datapath: per-layer
windows fetched from the donor slices into a double-buffered local staging
slot, overlapping the fetch of window ``i+1`` with the use of ``i``.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.hardware import MemoryTier


class Role(str, enum.Enum):
    PARAMS = "params"            # model weights (read every step)
    MASTER = "master"            # f32 master copy of params (optimizer)
    OPT_STATE = "opt_state"      # Adam moments
    GRADS = "grads"              # gradient buffers
    ACTIVATIONS = "activations"  # step-local
    KV_CACHE = "kv_cache"        # decode-state, read-mostly, grows with seq
    INPUTS = "inputs"            # token batches


class Strategy(str, enum.Enum):
    RESIDENT = "resident"   # lives in its tier; computed on in place
    STREAM = "stream"       # lives in a far tier; bulk-moved each use
                            # (paper: "managed"-like — pay the migration,
                            #  then access at HBM speed)


#: memory_kind strings understood by jax shardings, per tier.  Peer and
#: remote HBM are device memory reached over ICI/DCN (donor-axis sharding);
#: peer host DRAM is pinned host memory on the donor's host.
_TIER_TO_KIND = {
    MemoryTier.HBM: "device",
    MemoryTier.HOST: "pinned_host",
    MemoryTier.PEER_HBM: "device",
    MemoryTier.PEER_HOST: "pinned_host",
    MemoryTier.REMOTE_HBM: "device",
}

#: tiers whose bytes live in a host DRAM pool (vs an HBM pool).
HOST_TIERS = frozenset({MemoryTier.HOST, MemoryTier.PEER_HOST})

#: tiers that live on another chip/host and need a donor mesh axis.
PEER_TIERS = frozenset({MemoryTier.PEER_HBM, MemoryTier.PEER_HOST})
REMOTE_TIERS = frozenset({MemoryTier.REMOTE_HBM})

#: donor mesh-axis convention (see module docstring + repro.launch.mesh):
#: an axis with this name groups the local slice with the memory-donor
#: slices; peer/remote-tier tensors are sharded across it.
DONOR_AXIS = "donor"
REMOTE_DONOR_AXIS = "donor_pod"

#: which donor axis realizes each far tier (ICI donors vs DCN donors).
TIER_DONOR_AXIS: dict[MemoryTier, str] = {
    MemoryTier.PEER_HBM: DONOR_AXIS,
    MemoryTier.PEER_HOST: DONOR_AXIS,
    MemoryTier.REMOTE_HBM: REMOTE_DONOR_AXIS,
}


class DonorAxisError(ValueError):
    """A placement needs a donor mesh axis the active mesh does not have."""


def _mesh_axes(mesh) -> dict[str, int]:
    return dict(mesh.shape) if mesh is not None else {}


def donor_axes_for(mesh, tier: MemoryTier) -> tuple[str, ...]:
    """Mesh axes that realize ``tier``'s donor placement (empty for local
    tiers).  Raises :class:`DonorAxisError` when ``tier`` needs a donor
    axis and ``mesh`` has none of (usable) size >= 2."""
    axis = TIER_DONOR_AXIS.get(tier)
    if axis is None:
        return ()
    if _mesh_axes(mesh).get(axis, 1) < 2:
        raise DonorAxisError(
            f"tier {tier} needs a {axis!r} mesh axis of size >= 2 to be "
            f"realized; mesh axes are {_mesh_axes(mesh) or None} (see "
            "repro.launch.mesh.make_donor_mesh)"
        )
    return (axis,)


def donor_allow_flags(mesh) -> dict[str, bool]:
    """``allow_*`` kwargs for :func:`repro.core.planner.plan`, derived
    from what this runtime can realize: host tiers need a distinct host
    memory space, peer tiers a :data:`DONOR_AXIS`, remote tiers a
    :data:`REMOTE_DONOR_AXIS`.  With ``mesh=None`` nothing non-local is
    realizable."""
    axes = _mesh_axes(mesh)
    return {
        "allow_host": host_available(),
        "allow_peer": axes.get(DONOR_AXIS, 1) > 1,
        "allow_remote": axes.get(REMOTE_DONOR_AXIS, 1) > 1,
    }


def validate_policy_for_mesh(policy: "PlacementPolicy", mesh) -> None:
    """Raise :class:`DonorAxisError` if ``policy`` places any role in a
    peer/remote tier the mesh cannot realize.  Realizers call this before
    ``device_put`` so a donor placement never silently lands in local
    memory."""
    for role, pl in policy.placements.items():
        try:
            donor_axes_for(mesh, pl.tier)
        except DonorAxisError as e:
            raise DonorAxisError(
                f"policy {policy.name!r} places {role.value} in {pl.tier}: {e}"
            ) from None


# ---------------------------------------------------------------------------
# Backend memory-kind capability (API-drift + hardware-capability shim)
# ---------------------------------------------------------------------------

# Successful probes are memoized; failures are NOT (a query racing backend
# init — e.g. before jax.distributed.initialize — must not pin the
# "no memory kinds" fallback for the process lifetime).
_KINDS_CACHE: frozenset[str] | None = None
_DEFAULT_KIND_CACHE: str | None = None


def available_memory_kinds() -> frozenset[str]:
    """Memory kinds the default backend's device 0 can address."""
    global _KINDS_CACHE
    if _KINDS_CACHE is None:
        try:
            _KINDS_CACHE = frozenset(
                m.kind for m in jax.devices()[0].addressable_memories()
            )
        except Exception:
            return frozenset()
    return _KINDS_CACHE


def default_memory_kind() -> str | None:
    """The backend's default memory kind (``device`` on TPU)."""
    global _DEFAULT_KIND_CACHE
    if _DEFAULT_KIND_CACHE is None:
        try:
            _DEFAULT_KIND_CACHE = jax.devices()[0].default_memory().kind
        except Exception:
            return None
    return _DEFAULT_KIND_CACHE


def resolve_memory_kind(kind: str | None) -> str | None:
    """Map a requested memory kind onto what the backend exposes.

    ``None`` means "backend default" and always works.  Unavailable kinds
    degrade: ``pinned_host`` falls back to ``unpinned_host`` when only that
    is exposed, and anything else falls back to the backend default — the
    graceful path for CPU backends where host DRAM *is* device memory.
    """
    if kind is None:
        return None
    kinds = available_memory_kinds()
    if kind in kinds:
        return kind
    if kind == "pinned_host" and "unpinned_host" in kinds:
        if default_memory_kind() != "unpinned_host":
            return "unpinned_host"
    return None


def host_available() -> bool:
    """Does this backend expose a host memory space distinct from device
    memory?  False on CPU backends (host DRAM *is* the default memory), in
    which case offload policies are placement no-ops and the planner should
    not prefer them."""
    kinds = available_memory_kinds()
    default = default_memory_kind()
    return any(
        k.endswith("host") and k != default for k in kinds
    ) and default is not None and not default.endswith("host")


@dataclasses.dataclass(frozen=True)
class Placement:
    tier: MemoryTier = MemoryTier.HBM
    strategy: Strategy = Strategy.RESIDENT

    @property
    def raw_memory_kind(self) -> str:
        """The memory kind this tier wants, ignoring backend capability."""
        return _TIER_TO_KIND.get(self.tier, "device")

    @property
    def memory_kind(self) -> str | None:
        """The memory kind to actually hand to jax on this backend."""
        return resolve_memory_kind(self.raw_memory_kind)

    @property
    def on_host(self) -> bool:
        return self.tier in HOST_TIERS


@dataclasses.dataclass(frozen=True)
class PlacementPolicy:
    """Named per-role placement map (the paper's 'allocation policy')."""

    name: str
    placements: Mapping[Role, Placement]
    description: str = ""

    def placement(self, role: Role) -> Placement:
        return self.placements.get(role, Placement())

    def memory_kind(self, role: Role) -> str | None:
        return self.placement(role).memory_kind

    def raw_memory_kind(self, role: Role) -> str:
        return self.placement(role).raw_memory_kind

    def tiers(self) -> frozenset[MemoryTier]:
        """Every tier this policy places at least one role in."""
        return frozenset(
            {MemoryTier.HBM} | {p.tier for p in self.placements.values()}
        )

    @property
    def uses_host(self) -> bool:
        return any(p.on_host for p in self.placements.values())

    def sharding(
        self, mesh: Mesh, spec: PartitionSpec, role: Role
    ) -> NamedSharding:
        return NamedSharding(mesh, spec, memory_kind=self.memory_kind(role))

    def with_placement(self, role: Role, placement: Placement) -> "PlacementPolicy":
        p = dict(self.placements)
        p[role] = placement
        return PlacementPolicy(self.name, p, self.description)


def _policy(name: str, desc: str, **roles: Placement) -> PlacementPolicy:
    return PlacementPolicy(
        name,
        {Role[k.upper()]: v for k, v in roles.items()},
        desc,
    )


HBM = Placement(MemoryTier.HBM, Strategy.RESIDENT)
HOST = Placement(MemoryTier.HOST, Strategy.RESIDENT)
HOST_STREAM = Placement(MemoryTier.HOST, Strategy.STREAM)
PEER_HBM = Placement(MemoryTier.PEER_HBM, Strategy.RESIDENT)
PEER_HBM_STREAM = Placement(MemoryTier.PEER_HBM, Strategy.STREAM)
PEER_HOST_STREAM = Placement(MemoryTier.PEER_HOST, Strategy.STREAM)
REMOTE_HBM = Placement(MemoryTier.REMOTE_HBM, Strategy.RESIDENT)


#: Paper-faithful default: everything in fast memory ("local HBM" column of
#: every paper figure — the best-performing placement when it fits).
HBM_RESIDENT = _policy(
    "hbm_resident",
    "all tensors in device HBM (paper's local-HBM baseline)",
)

#: Optimizer-state offload: master weights + moments live in host DRAM and
#: are streamed through once per step (ZeRO-Offload-style).  Trades PCIe
#: bandwidth for ~12 bytes/param of HBM.
OPT_HOST = _policy(
    "opt_host",
    "Adam moments + f32 master in host DRAM, streamed once per step",
    master=HOST_STREAM,
    opt_state=HOST_STREAM,
)

#: KV cache on host, streamed per decode step (long-context serving when the
#: cache exceeds HBM; paper Fig. 17's DDR rows).
KV_HOST = _policy(
    "kv_host",
    "KV cache in host DRAM, streamed per decode step",
    kv_cache=HOST_STREAM,
)

#: Layer-wise weight streaming (serving models bigger than aggregate HBM;
#: paper Fig. 17 'weights on DDR').
WEIGHTS_STREAM = _policy(
    "weights_stream",
    "weights resident in host DRAM, streamed layer-by-layer",
    params=HOST_STREAM,
)

#: KV cache in a peer chip's HBM, read in place over ICI — the paper's
#: HBM-p column (peer HBM beats local DDR whenever the chip-to-chip link
#: outruns the host link, which it does on both GH200 and TPU).
KV_PEER_HBM = _policy(
    "kv_peer_hbm",
    "KV cache resident in a peer chip's HBM, read in place over ICI",
    kv_cache=PEER_HBM,
)

#: Weights streamed from a peer chip's HBM (Figs. 15-16: GEMM sources in
#: HBM-p) — the serving regime where a memory-donor chip holds the cold
#: layers and ships them over ICI ahead of use.
WEIGHTS_PEER_HBM = _policy(
    "weights_peer_hbm",
    "weights resident in peer HBM, streamed layer-by-layer over ICI",
    params=PEER_HBM_STREAM,
)

#: Optimizer state spilled to a *peer's* host DRAM (DDR-p column): the
#: escape hatch when local host DRAM is full — pays ICI+PCIe per step.
OPT_PEER_HOST = _policy(
    "opt_peer_host",
    "Adam moments + f32 master in a peer's host DRAM (spill-to-peer-host)",
    master=PEER_HOST_STREAM,
    opt_state=PEER_HOST_STREAM,
)

#: KV cache in a remote pod's HBM over DCN — the inter-node tier the paper
#: reaches once a node's four-superchip pool is exhausted.
KV_REMOTE_HBM = _policy(
    "kv_remote_hbm",
    "KV cache resident in a remote pod's HBM, read in place over DCN",
    kv_cache=REMOTE_HBM,
)

POLICIES: dict[str, PlacementPolicy] = {
    p.name: p
    for p in (
        HBM_RESIDENT,
        OPT_HOST,
        KV_HOST,
        WEIGHTS_STREAM,
        KV_PEER_HBM,
        WEIGHTS_PEER_HBM,
        OPT_PEER_HOST,
        KV_REMOTE_HBM,
    )
}


def put_like(tree, mesh: Mesh, specs, role: Role, policy: PlacementPolicy):
    """device_put a pytree under the policy's placement for ``role``.

    ``specs`` is a matching pytree of PartitionSpecs (or a single spec).
    For peer/remote placements the spec of every leaf is extended over the
    tier's donor axis (validated first — a missing donor axis raises
    :class:`DonorAxisError` rather than silently landing locally).

    This is the array-level twin of
    :func:`repro.models.sharding.policy_specs` for trees without Param
    defs.  Lacking logical axis names, a STREAM placement targets the
    first divisible free dim — dim 0 of a stacked tree, i.e. the stack
    dim — where ``policy_specs`` targets the dim *labelled* ``layers``.
    """
    pl = policy.placement(role)
    donor = donor_axes_for(mesh, pl.tier)

    def _put(x, spec):
        if donor:
            from repro.models.sharding import donor_extend

            spec = donor_extend(
                spec, x.shape, mesh, donor,
                prefer_stack=pl.strategy is Strategy.STREAM,
            )
        return jax.device_put(
            x, NamedSharding(mesh, spec, memory_kind=policy.memory_kind(role))
        )

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _put(x, specs), tree)
    return jax.tree.map(_put, tree, specs)


def to_device(tree, mesh: Mesh, specs):
    """Move a (possibly host-placed) pytree into HBM inside a jit region.

    This is the 'migration' step of a STREAM placement: under jit, XLA turns
    it into a host->device DMA that the latency-hiding scheduler can overlap
    with compute (the TPU analogue of managed-memory prefetch).
    """
    kind = resolve_memory_kind("device")

    def _mv(x, spec):
        return jax.device_put(
            x, NamedSharding(mesh, spec, memory_kind=kind)
        )

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _mv(x, specs), tree)
    return jax.tree.map(_mv, tree, specs)


def to_host(tree, mesh: Mesh, specs):
    """Move a pytree to (pinned) host memory inside a jit region."""
    kind = resolve_memory_kind("pinned_host")

    def _mv(x, spec):
        return jax.device_put(
            x, NamedSharding(mesh, spec, memory_kind=kind)
        )

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _mv(x, specs), tree)
    return jax.tree.map(_mv, tree, specs)


class DonorStream:
    """Double-buffered per-window streaming from a donor-resident stack.

    The executable form of ``Strategy.STREAM`` over a donor axis (the
    planner's ``copy_bound(PEER_*/REMOTE_*, HBM)`` datapath): ``tree``'s
    leaves are stacked along dim 0 into ``n_windows`` windows (layer-wise
    weight streaming stacks per-layer params) and live sharded across the
    donor slices; :meth:`window` returns window ``i`` device_put into the
    **local** sharding and immediately issues the (asynchronous) fetch of
    window ``i+1`` into the second staging slot, so the next fetch crosses
    the ICI/DCN path while the caller computes on window ``i``.  At most
    ``depth`` windows are held locally — the double-buffered staging
    footprint the planner charges against local HBM (``2 * bytes /
    stream_chunks``).
    """

    def __init__(self, tree, mesh: Mesh, specs, n_windows: int,
                 depth: int = 2):
        self._tree = tree
        self._mesh = mesh
        self._specs = specs
        self.n_windows = int(n_windows)
        self.depth = max(int(depth), 2)
        self._buf: dict[int, object] = {}
        self._kind = resolve_memory_kind("device")

    def _fetch(self, i: int):
        def mv(x, spec):
            return jax.device_put(
                x[i], NamedSharding(self._mesh, spec, memory_kind=self._kind)
            )

        if isinstance(self._specs, PartitionSpec):
            return jax.tree.map(lambda x: mv(x, self._specs), self._tree)
        return jax.tree.map(mv, self._tree, self._specs)

    def window(self, i: int):
        """Window ``i`` in local memory; prefetches the next ``depth - 1``
        windows behind it (``depth=2`` = classic double buffering)."""
        if not 0 <= i < self.n_windows:
            raise IndexError(f"window {i} of {self.n_windows}")
        keep = range(i, min(i + self.depth, self.n_windows))
        for j in keep:           # j == i first: the caller's window, then
            if j not in self._buf:     # the async prefetches behind it
                self._buf[j] = self._fetch(j)
        for k in [k for k in self._buf if k not in keep]:
            del self._buf[k]  # bound staging residency to `depth` windows
        return self._buf[i]

    def __iter__(self):
        for i in range(self.n_windows):
            yield self.window(i)
