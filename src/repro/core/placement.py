"""Placement policies: where every tensor role physically lives.

The paper's application studies (§IV) show that the *physical placement* of
each buffer — not just its sharding — decides performance, and that the
decision is per-role: GEMM source matrices care (reads dominate), the
destination does not; KV-type read-mostly buffers benefit from the big slow
pool only when the fast pool is full.

JAX exposes exactly the needed control: ``NamedSharding(mesh, spec,
memory_kind=...)`` with kinds ``device`` (HBM), ``pinned_host`` and
``unpinned_host`` — the TPU analogue of the paper's Table II allocation
APIs (``numa_alloc_onnode`` ≈ explicit memory_kind; first-touch ≈ default
``device``).  A :class:`PlacementPolicy` maps tensor roles to placements;
the train/serve steps consume it; the planner (:mod:`repro.core.planner`)
predicts its step time from the datapath model and picks the best that fits.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.hardware import MemoryTier


class Role(str, enum.Enum):
    PARAMS = "params"            # model weights (read every step)
    MASTER = "master"            # f32 master copy of params (optimizer)
    OPT_STATE = "opt_state"      # Adam moments
    GRADS = "grads"              # gradient buffers
    ACTIVATIONS = "activations"  # step-local
    KV_CACHE = "kv_cache"        # decode-state, read-mostly, grows with seq
    INPUTS = "inputs"            # token batches


class Strategy(str, enum.Enum):
    RESIDENT = "resident"   # lives in its tier; computed on in place (HBM)
    STREAM = "stream"       # lives in a far tier; bulk-moved each use
                            # (paper: "managed"-like — pay the migration,
                            #  then access at HBM speed)


#: memory_kind strings understood by jax shardings, per tier.
_TIER_TO_KIND = {
    MemoryTier.HBM: "device",
    MemoryTier.HOST: "pinned_host",
}


@dataclasses.dataclass(frozen=True)
class Placement:
    tier: MemoryTier = MemoryTier.HBM
    strategy: Strategy = Strategy.RESIDENT

    @property
    def memory_kind(self) -> str:
        return _TIER_TO_KIND.get(self.tier, "device")

    @property
    def on_host(self) -> bool:
        return self.tier == MemoryTier.HOST


@dataclasses.dataclass(frozen=True)
class PlacementPolicy:
    """Named per-role placement map (the paper's 'allocation policy')."""

    name: str
    placements: Mapping[Role, Placement]
    description: str = ""

    def placement(self, role: Role) -> Placement:
        return self.placements.get(role, Placement())

    def memory_kind(self, role: Role) -> str:
        return self.placement(role).memory_kind

    def sharding(
        self, mesh: Mesh, spec: PartitionSpec, role: Role
    ) -> NamedSharding:
        return NamedSharding(mesh, spec, memory_kind=self.memory_kind(role))

    def with_placement(self, role: Role, placement: Placement) -> "PlacementPolicy":
        p = dict(self.placements)
        p[role] = placement
        return PlacementPolicy(self.name, p, self.description)


def _policy(name: str, desc: str, **roles: Placement) -> PlacementPolicy:
    return PlacementPolicy(
        name,
        {Role[k.upper()]: v for k, v in roles.items()},
        desc,
    )


HOST = Placement(MemoryTier.HOST, Strategy.RESIDENT)
HOST_STREAM = Placement(MemoryTier.HOST, Strategy.STREAM)
HBM = Placement(MemoryTier.HBM, Strategy.RESIDENT)


#: Paper-faithful default: everything in fast memory ("local HBM" column of
#: every paper figure — the best-performing placement when it fits).
HBM_RESIDENT = _policy(
    "hbm_resident",
    "all tensors in device HBM (paper's local-HBM baseline)",
)

#: Optimizer-state offload: master weights + moments live in host DRAM and
#: are streamed through once per step (ZeRO-Offload-style).  Trades PCIe
#: bandwidth for ~12 bytes/param of HBM.
OPT_HOST = _policy(
    "opt_host",
    "Adam moments + f32 master in host DRAM, streamed once per step",
    master=HOST_STREAM,
    opt_state=HOST_STREAM,
)

#: KV cache on host, streamed per decode step (long-context serving when the
#: cache exceeds HBM; paper Fig. 17's DDR rows).
KV_HOST = _policy(
    "kv_host",
    "KV cache in host DRAM, streamed per decode step",
    kv_cache=HOST_STREAM,
)

#: Layer-wise weight streaming (serving models bigger than aggregate HBM;
#: paper Fig. 17 'weights on DDR').
WEIGHTS_STREAM = _policy(
    "weights_stream",
    "weights resident in host DRAM, streamed layer-by-layer",
    params=HOST_STREAM,
)

POLICIES: dict[str, PlacementPolicy] = {
    p.name: p for p in (HBM_RESIDENT, OPT_HOST, KV_HOST, WEIGHTS_STREAM)
}


def host_available() -> bool:
    """Does this backend expose a pinned_host memory space?"""
    try:
        kinds = {m.kind for m in jax.devices()[0].addressable_memories()}
    except Exception:
        return False
    return "pinned_host" in kinds


def put_like(tree, mesh: Mesh, specs, role: Role, policy: PlacementPolicy):
    """device_put a pytree under the policy's placement for ``role``.

    ``specs`` is a matching pytree of PartitionSpecs (or a single spec).
    """
    def _put(x, spec):
        return jax.device_put(x, policy.sharding(mesh, spec, role))

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _put(x, specs), tree)
    return jax.tree.map(_put, tree, specs)


def to_device(tree, mesh: Mesh, specs):
    """Move a (possibly host-placed) pytree into HBM inside a jit region.

    This is the 'migration' step of a STREAM placement: under jit, XLA turns
    it into a host->device DMA that the latency-hiding scheduler can overlap
    with compute (the TPU analogue of managed-memory prefetch).
    """
    def _mv(x, spec):
        return jax.device_put(
            x, NamedSharding(mesh, spec, memory_kind="device")
        )

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _mv(x, specs), tree)
    return jax.tree.map(_mv, tree, specs)


def to_host(tree, mesh: Mesh, specs):
    """Move a pytree to pinned host memory inside a jit region."""
    def _mv(x, spec):
        return jax.device_put(
            x, NamedSharding(mesh, spec, memory_kind="pinned_host")
        )

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _mv(x, specs), tree)
    return jax.tree.map(_mv, tree, specs)
