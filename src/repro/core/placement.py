"""Placement policies: where every tensor role physically lives.

The paper's application studies (§IV) show that the *physical placement* of
each buffer — not just its sharding — decides performance, and that the
decision is per-role: GEMM source matrices care (reads dominate), the
destination does not; KV-type read-mostly buffers benefit from the big slow
pool only when the fast pool is full.

A :class:`PlacementPolicy` maps tensor roles to placements over the **full**
:class:`repro.core.hardware.MemoryTier` axis — local HBM, local host DRAM,
a peer chip's HBM / host DRAM over ICI, and a remote pod's HBM over DCN —
mirroring the paper's {HBM, DDR, HBM-p, DDR-p} columns (Figs. 5/7/9 and the
§IV application tables).  The planner (:mod:`repro.core.planner`) predicts
each policy's step time from the datapath bounds and picks the best that
fits every memory pool; the train/serve steps consume the chosen policy.

Physical realization on the runtime: JAX exposes ``NamedSharding(mesh,
spec, memory_kind=...)`` with kinds ``device`` (HBM), ``pinned_host`` and
``unpinned_host`` — the TPU analogue of the paper's Table II allocation
APIs (``numa_alloc_onnode`` ≈ explicit memory_kind; first-touch ≈ default
``device``).  Not every backend exposes every kind (the CPU backend of
older jax exposes only ``unpinned_host``), so every kind the policy
requests is passed through :func:`resolve_memory_kind`, which degrades
gracefully to what the backend actually has.

Peer and remote tiers are **executable**, not analysis-only: they are
realized on a *donor mesh axis* (see :mod:`repro.launch.mesh`).  A mesh
axis named :data:`DONOR_AXIS` (``"donor"``, an ICI axis) marks a group of
chips whose memory is donated to the computation — far-tier tensors are
sharded across that axis (each donor slice holds ``1/axis_size`` of the
bytes in its own pool, a hop away over the link, exactly the paper's HBM-p
placement), while every local-tier tensor ignores the axis and is
replicated over it.  :data:`REMOTE_DONOR_AXIS` (``"donor_pod"``) is the
same convention one interconnect further out: a donor group reached over
DCN, realizing :attr:`MemoryTier.REMOTE_HBM`.  ``PEER_HBM``/``REMOTE_HBM``
keep memory kind ``device`` (the bytes live in a peer's HBM);
``PEER_HOST`` pins to the donor's host DRAM.  :func:`put_like` and
:func:`repro.models.sharding.policy_specs` emit donor-extended specs;
:func:`validate_policy_for_mesh` refuses to realize a peer/remote policy
on a mesh without the required axis — a placement must never silently
degrade to ``hbm_resident`` (and then OOM where the planner predicted a
fit).  :class:`DonorStream` is the ``Strategy.STREAM`` datapath: per-layer
windows fetched from the donor slices into a double-buffered local staging
slot, overlapping the fetch of window ``i+1`` with the use of ``i``.
"""

from __future__ import annotations

import dataclasses
import enum
import json
import re
from typing import Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.hardware import MemoryTier


class Role(str, enum.Enum):
    PARAMS = "params"            # model weights (read every step)
    MASTER = "master"            # f32 master copy of params (optimizer)
    OPT_STATE = "opt_state"      # Adam moments
    GRADS = "grads"              # gradient buffers
    ACTIVATIONS = "activations"  # step-local
    KV_CACHE = "kv_cache"        # decode-state, read-mostly, grows with seq
    INPUTS = "inputs"            # token batches


class Strategy(str, enum.Enum):
    RESIDENT = "resident"   # lives in its tier; computed on in place
    STREAM = "stream"       # lives in a far tier; bulk-moved each use
                            # (paper: "managed"-like — pay the migration,
                            #  then access at HBM speed)


#: memory_kind strings understood by jax shardings, per tier.  Peer and
#: remote HBM are device memory reached over ICI/DCN (donor-axis sharding);
#: peer host DRAM is pinned host memory on the donor's host.
_TIER_TO_KIND = {
    MemoryTier.HBM: "device",
    MemoryTier.HOST: "pinned_host",
    MemoryTier.PEER_HBM: "device",
    MemoryTier.PEER_HOST: "pinned_host",
    MemoryTier.REMOTE_HBM: "device",
}

#: canonical tier spellings for the placement string grammar (the names
#: configs/CLI use: ``--policy kv=host:stream,params=peer_hbm``), plus the
#: aliases accepted on input (the MemoryTier enum values and a few
#: paper-flavored spellings).
TIER_NAMES: dict[MemoryTier, str] = {
    MemoryTier.HBM: "hbm",
    MemoryTier.HOST: "host",
    MemoryTier.PEER_HBM: "peer_hbm",
    MemoryTier.PEER_HOST: "peer_host",
    MemoryTier.REMOTE_HBM: "remote_hbm",
}
_TIER_ALIASES: dict[str, MemoryTier] = {
    **{v: k for k, v in TIER_NAMES.items()},
    **{t.value: t for t in TIER_NAMES},   # enum values: hbm_p, host_p, ...
    "device": MemoryTier.HBM,
    "ddr": MemoryTier.HOST,
    "ddr_p": MemoryTier.PEER_HOST,
}

#: role spellings for the grammar: enum values plus short aliases.
_ROLE_ALIASES: dict[str, Role] = {
    **{r.value: r for r in Role},
    "kv": Role.KV_CACHE,
    "weights": Role.PARAMS,
    "opt": Role.OPT_STATE,
    "act": Role.ACTIVATIONS,
}


def parse_role(name: str | Role) -> Role:
    """Role from a grammar spelling (``kv``/``kv_cache``/``params``/...)."""
    if isinstance(name, Role):
        return name
    try:
        return _ROLE_ALIASES[name.strip().lower()]
    except KeyError:
        raise ValueError(
            f"unknown tensor role {name!r}; one of "
            f"{sorted(_ROLE_ALIASES)}"
        ) from None


def parse_tier(name: str | MemoryTier) -> MemoryTier:
    """MemoryTier from a grammar spelling (``hbm``/``peer_hbm``/...)."""
    if isinstance(name, MemoryTier):
        return name
    try:
        return _TIER_ALIASES[name.strip().lower()]
    except KeyError:
        raise ValueError(
            f"unknown memory tier {name!r}; one of "
            f"{sorted(set(_TIER_ALIASES))}"
        ) from None

#: tiers whose bytes live in a host DRAM pool (vs an HBM pool).
HOST_TIERS = frozenset({MemoryTier.HOST, MemoryTier.PEER_HOST})

#: tiers that live on another chip/host and need a donor mesh axis.
PEER_TIERS = frozenset({MemoryTier.PEER_HBM, MemoryTier.PEER_HOST})
REMOTE_TIERS = frozenset({MemoryTier.REMOTE_HBM})

#: donor mesh-axis convention (see module docstring + repro.launch.mesh):
#: an axis with this name groups the local slice with the memory-donor
#: slices; peer/remote-tier tensors are sharded across it.
DONOR_AXIS = "donor"
REMOTE_DONOR_AXIS = "donor_pod"

#: which donor axis realizes each far tier (ICI donors vs DCN donors).
TIER_DONOR_AXIS: dict[MemoryTier, str] = {
    MemoryTier.PEER_HBM: DONOR_AXIS,
    MemoryTier.PEER_HOST: DONOR_AXIS,
    MemoryTier.REMOTE_HBM: REMOTE_DONOR_AXIS,
}


class DonorAxisError(ValueError):
    """A placement needs a donor mesh axis the active mesh does not have."""


def _mesh_axes(mesh) -> dict[str, int]:
    return dict(mesh.shape) if mesh is not None else {}


def donor_axes_for(mesh, tier: MemoryTier) -> tuple[str, ...]:
    """Mesh axes that realize ``tier``'s donor placement (empty for local
    tiers).  Raises :class:`DonorAxisError` when ``tier`` needs a donor
    axis and ``mesh`` has none of (usable) size >= 2."""
    axis = TIER_DONOR_AXIS.get(tier)
    if axis is None:
        return ()
    if _mesh_axes(mesh).get(axis, 1) < 2:
        raise DonorAxisError(
            f"tier {tier} needs a {axis!r} mesh axis of size >= 2 to be "
            f"realized; mesh axes are {_mesh_axes(mesh) or None} (see "
            "repro.launch.mesh.make_donor_mesh)"
        )
    return (axis,)


def donor_allow_flags(mesh) -> dict[str, bool]:
    """``allow_*`` kwargs for :func:`repro.core.planner.plan`, derived
    from what this runtime can realize: host tiers need a distinct host
    memory space, peer tiers a :data:`DONOR_AXIS`, remote tiers a
    :data:`REMOTE_DONOR_AXIS`.  With ``mesh=None`` nothing non-local is
    realizable."""
    axes = _mesh_axes(mesh)
    return {
        "allow_host": host_available(),
        "allow_peer": axes.get(DONOR_AXIS, 1) > 1,
        "allow_remote": axes.get(REMOTE_DONOR_AXIS, 1) > 1,
    }


def validate_policy_for_mesh(policy: "PlacementPolicy", mesh) -> None:
    """Raise :class:`DonorAxisError` if ``policy`` places any role in a
    peer/remote tier the mesh cannot realize.  Realizers call this before
    ``device_put`` so a donor placement never silently lands in local
    memory."""
    for role, pl in policy.placements.items():
        try:
            donor_axes_for(mesh, pl.tier)
        except DonorAxisError as e:
            raise DonorAxisError(
                f"policy {policy.name!r} places {role.value} in {pl.tier}: {e}"
            ) from None


# ---------------------------------------------------------------------------
# Backend memory-kind capability (API-drift + hardware-capability shim)
# ---------------------------------------------------------------------------

# Successful probes are memoized; failures are NOT (a query racing backend
# init — e.g. before jax.distributed.initialize — must not pin the
# "no memory kinds" fallback for the process lifetime).
_KINDS_CACHE: frozenset[str] | None = None
_DEFAULT_KIND_CACHE: str | None = None


def available_memory_kinds() -> frozenset[str]:
    """Memory kinds the default backend's device 0 can address."""
    global _KINDS_CACHE
    if _KINDS_CACHE is None:
        try:
            _KINDS_CACHE = frozenset(
                m.kind for m in jax.devices()[0].addressable_memories()
            )
        except Exception:
            return frozenset()
    return _KINDS_CACHE


def default_memory_kind() -> str | None:
    """The backend's default memory kind (``device`` on TPU)."""
    global _DEFAULT_KIND_CACHE
    if _DEFAULT_KIND_CACHE is None:
        try:
            _DEFAULT_KIND_CACHE = jax.devices()[0].default_memory().kind
        except Exception:
            return None
    return _DEFAULT_KIND_CACHE


def resolve_memory_kind(kind: str | None) -> str | None:
    """Map a requested memory kind onto what the backend exposes.

    ``None`` means "backend default" and always works.  Unavailable kinds
    degrade: ``pinned_host`` falls back to ``unpinned_host`` when only that
    is exposed, and anything else falls back to the backend default — the
    graceful path for CPU backends where host DRAM *is* device memory.
    """
    if kind is None:
        return None
    kinds = available_memory_kinds()
    if kind in kinds:
        return kind
    if kind == "pinned_host" and "unpinned_host" in kinds:
        if default_memory_kind() != "unpinned_host":
            return "unpinned_host"
    return None


def host_available() -> bool:
    """Does this backend expose a host memory space distinct from device
    memory?  False on CPU backends (host DRAM *is* the default memory), in
    which case offload policies are placement no-ops and the planner should
    not prefer them."""
    kinds = available_memory_kinds()
    default = default_memory_kind()
    return any(
        k.endswith("host") and k != default for k in kinds
    ) and default is not None and not default.endswith("host")


@dataclasses.dataclass(frozen=True)
class Placement:
    tier: MemoryTier = MemoryTier.HBM
    strategy: Strategy = Strategy.RESIDENT

    @property
    def raw_memory_kind(self) -> str:
        """The memory kind this tier wants, ignoring backend capability."""
        return _TIER_TO_KIND.get(self.tier, "device")

    @property
    def memory_kind(self) -> str | None:
        """The memory kind to actually hand to jax on this backend."""
        return resolve_memory_kind(self.raw_memory_kind)

    @property
    def on_host(self) -> bool:
        return self.tier in HOST_TIERS

    def to_str(self) -> str:
        """Grammar form: ``tier[:strategy]`` (``:resident`` is implied)."""
        tier = TIER_NAMES[self.tier]
        if self.strategy is Strategy.RESIDENT:
            return tier
        return f"{tier}:{self.strategy.value}"

    @classmethod
    def parse(cls, text: "str | Placement") -> "Placement":
        """Placement from ``tier[:strategy]`` (``host:stream``, ``peer_hbm``)."""
        if isinstance(text, Placement):
            return text
        tier_s, _, strat_s = text.partition(":")
        tier = parse_tier(tier_s)
        if not strat_s:
            return cls(tier, Strategy.RESIDENT)
        try:
            strategy = Strategy(strat_s.strip().lower())
        except ValueError:
            raise ValueError(
                f"unknown placement strategy {strat_s!r} in {text!r}; one "
                f"of {[s.value for s in Strategy]}"
            ) from None
        return cls(tier, strategy)


@dataclasses.dataclass(frozen=True)
class PlacementPolicy:
    """Named per-role placement map (the paper's 'allocation policy')."""

    name: str
    placements: Mapping[Role, Placement]
    description: str = ""

    def placement(self, role: Role) -> Placement:
        return self.placements.get(role, Placement())

    def memory_kind(self, role: Role) -> str | None:
        return self.placement(role).memory_kind

    def raw_memory_kind(self, role: Role) -> str:
        return self.placement(role).raw_memory_kind

    def tiers(self) -> frozenset[MemoryTier]:
        """Every tier this policy places at least one role in."""
        return frozenset(
            {MemoryTier.HBM} | {p.tier for p in self.placements.values()}
        )

    @property
    def uses_host(self) -> bool:
        return any(p.on_host for p in self.placements.values())

    def sharding(
        self, mesh: Mesh, spec: PartitionSpec, role: Role
    ) -> NamedSharding:
        return NamedSharding(mesh, spec, memory_kind=self.memory_kind(role))

    def with_placement(self, role: Role, placement: Placement) -> "PlacementPolicy":
        p = dict(self.placements)
        p[role] = placement
        return PlacementPolicy(self.name, p, self.description)

    def renamed(self, name: str, description: str | None = None) -> "PlacementPolicy":
        return PlacementPolicy(
            name, dict(self.placements),
            self.description if description is None else description,
        )

    # -- serialization ----------------------------------------------------
    def to_spec(self) -> str:
        """Compact grammar form: ``role=tier[:strategy],...`` (sorted,
        ``hbm``-resident roles omitted — they are the default)."""
        return ",".join(
            f"{role.value}={pl.to_str()}"
            for role, pl in sorted(
                self.placements.items(), key=lambda kv: kv[0].value
            )
            if pl != Placement()
        )

    def to_json(self, *, indent: int | None = None) -> str:
        """JSON form; :meth:`from_json` round-trips it exactly."""
        return json.dumps(
            {
                "name": self.name,
                "description": self.description,
                "placements": {
                    role.value: pl.to_str()
                    for role, pl in sorted(
                        self.placements.items(), key=lambda kv: kv[0].value
                    )
                },
            },
            indent=indent,
        )

    @classmethod
    def from_json(cls, data: "str | Mapping") -> "PlacementPolicy":
        """Inverse of :meth:`to_json`; also accepts the already-parsed
        dict form (configs embed it without re-stringifying)."""
        if isinstance(data, (str, bytes)):
            data = json.loads(data)
        if not isinstance(data, Mapping):
            raise ValueError(
                f"policy JSON must decode to an object, got {type(data)}"
            )
        placements = {
            parse_role(role): Placement.parse(pl)
            for role, pl in dict(data.get("placements", {})).items()
        }
        name = data.get("name") or _spec_name(placements)
        return cls(name, placements, data.get("description", ""))


def _spec_name(placements: Mapping[Role, Placement]) -> str:
    """Canonical derived name for an anonymous policy (stable across
    round-trips: sorted compact-grammar body)."""
    body = ",".join(
        f"{role.value}={pl.to_str()}"
        for role, pl in sorted(placements.items(), key=lambda kv: kv[0].value)
    )
    return f"custom({body or 'hbm_resident'})"


def policy(
    name: str | None = None,
    description: str = "",
    **role_placements: "str | Placement",
) -> PlacementPolicy:
    """Compositional policy constructor: placements as values, not names.

    Keyword names are role spellings (``kv``/``kv_cache``, ``params``,
    ``opt``/``opt_state``, ...), values are :class:`Placement` objects or
    grammar strings (``"host:stream"``, ``"peer_hbm"``)::

        policy(kv="host:stream", params="peer_hbm")

    Unnamed policies get a stable derived name so they serialize, log and
    register cleanly.
    """
    placements = {
        parse_role(role): Placement.parse(pl)
        for role, pl in role_placements.items()
    }
    return PlacementPolicy(name or _spec_name(placements), placements,
                           description)


class PolicyBuilder:
    """Incremental form of :func:`policy` for programmatic construction::

        p = (PolicyBuilder("serve_spill")
             .place("kv_cache", "host:stream")
             .place(Role.PARAMS, Placement(MemoryTier.PEER_HBM))
             .describe("KV spilled to host, params on the donor")
             .build())

    ``build(register=True)`` also publishes it to the registry.
    """

    def __init__(self, name: str | None = None):
        self._name = name
        self._description = ""
        self._placements: dict[Role, Placement] = {}

    def place(self, role: "str | Role", placement: "str | Placement") -> "PolicyBuilder":
        self._placements[parse_role(role)] = Placement.parse(placement)
        return self

    def describe(self, description: str) -> "PolicyBuilder":
        self._description = description
        return self

    def build(self, *, register: bool = False) -> PlacementPolicy:
        out = PlacementPolicy(
            self._name or _spec_name(self._placements),
            dict(self._placements),
            self._description,
        )
        if register:
            register_policy(out)
        return out


def parse_policy(text: "str | Mapping | PlacementPolicy") -> PlacementPolicy:
    """One entry point for every external policy spelling.

    Accepts, in order: a :class:`PlacementPolicy` (pass-through), a
    registered policy name (``"kv_host"``), a JSON object/string
    (:meth:`PlacementPolicy.from_json`), or the compact grammar
    (``"kv=host:stream,params=peer_hbm"``).  This is what ``--policy``
    flags and config files feed.
    """
    if isinstance(text, PlacementPolicy):
        return text
    if isinstance(text, Mapping):
        return PlacementPolicy.from_json(text)
    text = text.strip()
    if text in _REGISTRY:
        return _REGISTRY[text]
    if text.startswith("{"):
        return PlacementPolicy.from_json(text)
    if "=" not in text:
        raise ValueError(
            f"unknown policy {text!r}: not a registered name "
            f"({sorted(_REGISTRY)}), not JSON, and not the "
            "role=tier[:strategy][,...] grammar"
        )
    placements: dict[Role, Placement] = {}
    for part in text.split(","):
        if not part.strip():
            continue
        role_s, eq, pl_s = part.partition("=")
        if not eq:
            raise ValueError(
                f"bad policy fragment {part!r} in {text!r} "
                "(expected role=tier[:strategy])"
            )
        if role_s.strip().lower() == "pools":
            raise ValueError(
                f"policy spec {text!r} carries a 'pools=' directive; "
                "strip it with extract_pool_split() before parse_policy "
                "(only the disaggregated-serve entry points accept it)"
            )
        placements[parse_role(role_s)] = Placement.parse(pl_s)
    return PlacementPolicy(_spec_name(placements), placements,
                           "parsed from policy spec string")


# ---------------------------------------------------------------------------
# Pool-split grammar (disaggregated prefill/decode serving)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PoolSplit:
    """An explicit prefill/decode device split for a disaggregated
    cluster (``repro.serve.disagg``): ``prefill`` devices fill KV and
    publish handoff tickets, ``decode`` devices generate.  Parsed from
    the ``pools=prefill:N,decode:M`` grammar extension; ``None`` (no
    directive) means the planner's :func:`repro.core.planner.
    plan_pool_split` chooses the split."""

    prefill: int
    decode: int

    def __post_init__(self):
        if self.prefill < 1 or self.decode < 1:
            raise ValueError(
                f"pool split needs >= 1 device per pool, got "
                f"prefill:{self.prefill},decode:{self.decode}"
            )

    @property
    def total(self) -> int:
        return self.prefill + self.decode

    def to_str(self) -> str:
        return f"pools=prefill:{self.prefill},decode:{self.decode}"

    @classmethod
    def parse(cls, text: "str | PoolSplit") -> "PoolSplit":
        """PoolSplit from ``prefill:N,decode:M`` (either order; the
        ``pools=`` prefix is accepted and stripped)."""
        if isinstance(text, PoolSplit):
            return text
        body = text.strip()
        if body.lower().startswith("pools="):
            body = body[len("pools="):]
        counts: dict[str, int] = {}
        for frag in body.split(","):
            m = _POOL_FRAGMENT.match(frag)
            if not m:
                raise ValueError(
                    f"bad pool fragment {frag!r} in {text!r} "
                    "(expected pools=prefill:N,decode:M)"
                )
            pool, n = m.group(1), int(m.group(2))
            if pool in counts:
                raise ValueError(f"duplicate pool {pool!r} in {text!r}")
            counts[pool] = n
        if set(counts) != {"prefill", "decode"}:
            raise ValueError(
                f"pool split {text!r} must name both pools "
                "(pools=prefill:N,decode:M)"
            )
        return cls(counts["prefill"], counts["decode"])


_POOL_FRAGMENT = re.compile(r"^\s*(prefill|decode)\s*:\s*(\d+)\s*$")


def extract_pool_split(
    text: "str | Mapping | PlacementPolicy | None",
) -> "tuple[PoolSplit | None, str | Mapping | PlacementPolicy | None]":
    """Split a ``pools=prefill:N,decode:M`` directive out of a policy spec.

    The pools directive rides inside the same ``--policy`` string as the
    role grammar (``"kv=remote_hbm,pools=prefill:1,decode:3"``) but its
    *value* contains commas, so it must be carved out before
    :func:`parse_policy` splits on them.  Returns ``(split, remainder)``
    where ``remainder`` is the spec with the directive removed (``None``
    if nothing else remains) — non-string specs pass through untouched
    with ``split=None``.
    """
    if not isinstance(text, str) or "pools" not in text:
        return None, text
    parts = [p for p in text.split(",") if p.strip()]
    for i, part in enumerate(parts):
        role_s, eq, val = part.partition("=")
        if not (eq and role_s.strip().lower() == "pools"):
            continue
        frags = [val]
        j = i + 1
        while j < len(parts) and _POOL_FRAGMENT.match(parts[j]):
            frags.append(parts[j])
            j += 1
        split = PoolSplit.parse(",".join(frags))
        rest = ",".join(parts[:i] + parts[j:])
        return split, (rest if rest else None)
    return None, text


# ---------------------------------------------------------------------------
# Policy registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, PlacementPolicy] = {}


def register_policy(
    policy: PlacementPolicy, *, overwrite: bool = False
) -> PlacementPolicy:
    """Publish ``policy`` under its name.

    Registered policies show up everywhere the registry is enumerated:
    planner candidate sets, the placement sweep, the benchmark policy
    table, and every ``--policy <name>`` flag.  Re-registering a name is
    an error unless ``overwrite=True`` (a silent replacement would change
    what existing configs mean).
    """
    if not policy.name:
        raise ValueError("cannot register an unnamed policy")
    if policy.name in _REGISTRY and not overwrite:
        raise ValueError(
            f"policy {policy.name!r} is already registered; pass "
            "overwrite=True to replace it"
        )
    _REGISTRY[policy.name] = policy
    return policy


def get_policy(name: str) -> PlacementPolicy:
    """Registered policy by exact name (KeyError lists what exists)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no registered placement policy {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


def registered_policies() -> dict[str, PlacementPolicy]:
    """Snapshot of the registry (insertion-ordered name -> policy)."""
    return dict(_REGISTRY)


def _policy(name: str, desc: str, **roles: Placement) -> PlacementPolicy:
    return register_policy(PlacementPolicy(
        name,
        {Role[k.upper()]: v for k, v in roles.items()},
        desc,
    ))


HBM = Placement(MemoryTier.HBM, Strategy.RESIDENT)
HOST = Placement(MemoryTier.HOST, Strategy.RESIDENT)
HOST_STREAM = Placement(MemoryTier.HOST, Strategy.STREAM)
PEER_HBM = Placement(MemoryTier.PEER_HBM, Strategy.RESIDENT)
PEER_HBM_STREAM = Placement(MemoryTier.PEER_HBM, Strategy.STREAM)
PEER_HOST_STREAM = Placement(MemoryTier.PEER_HOST, Strategy.STREAM)
REMOTE_HBM = Placement(MemoryTier.REMOTE_HBM, Strategy.RESIDENT)


#: Paper-faithful default: everything in fast memory ("local HBM" column of
#: every paper figure — the best-performing placement when it fits).
HBM_RESIDENT = _policy(
    "hbm_resident",
    "all tensors in device HBM (paper's local-HBM baseline)",
)

#: Optimizer-state offload: master weights + moments live in host DRAM and
#: are streamed through once per step (ZeRO-Offload-style).  Trades PCIe
#: bandwidth for ~12 bytes/param of HBM.
OPT_HOST = _policy(
    "opt_host",
    "Adam moments + f32 master in host DRAM, streamed once per step",
    master=HOST_STREAM,
    opt_state=HOST_STREAM,
)

#: KV cache on host, streamed per decode step (long-context serving when the
#: cache exceeds HBM; paper Fig. 17's DDR rows).
KV_HOST = _policy(
    "kv_host",
    "KV cache in host DRAM, streamed per decode step",
    kv_cache=HOST_STREAM,
)

#: Layer-wise weight streaming (serving models bigger than aggregate HBM;
#: paper Fig. 17 'weights on DDR').
WEIGHTS_STREAM = _policy(
    "weights_stream",
    "weights resident in host DRAM, streamed layer-by-layer",
    params=HOST_STREAM,
)

#: KV cache in a peer chip's HBM, read in place over ICI — the paper's
#: HBM-p column (peer HBM beats local DDR whenever the chip-to-chip link
#: outruns the host link, which it does on both GH200 and TPU).
KV_PEER_HBM = _policy(
    "kv_peer_hbm",
    "KV cache resident in a peer chip's HBM, read in place over ICI",
    kv_cache=PEER_HBM,
)

#: Weights streamed from a peer chip's HBM (Figs. 15-16: GEMM sources in
#: HBM-p) — the serving regime where a memory-donor chip holds the cold
#: layers and ships them over ICI ahead of use.
WEIGHTS_PEER_HBM = _policy(
    "weights_peer_hbm",
    "weights resident in peer HBM, streamed layer-by-layer over ICI",
    params=PEER_HBM_STREAM,
)

#: Optimizer state spilled to a *peer's* host DRAM (DDR-p column): the
#: escape hatch when local host DRAM is full — pays ICI+PCIe per step.
OPT_PEER_HOST = _policy(
    "opt_peer_host",
    "Adam moments + f32 master in a peer's host DRAM (spill-to-peer-host)",
    master=PEER_HOST_STREAM,
    opt_state=PEER_HOST_STREAM,
)

#: KV cache in a remote pod's HBM over DCN — the inter-node tier the paper
#: reaches once a node's four-superchip pool is exhausted.
KV_REMOTE_HBM = _policy(
    "kv_remote_hbm",
    "KV cache resident in a remote pod's HBM, read in place over DCN",
    kv_cache=REMOTE_HBM,
)

class _PoliciesView(Mapping):
    """Deprecated read-only live view of the policy registry.

    The old closed ``POLICIES`` dict, kept importable: reads forward to
    the registry (so policies registered later appear), writes raise.
    Every access path warns once per process, pointing at the
    replacement surface.
    """

    def _reg(self):
        _warn_deprecated(
            "POLICIES",
            "repro.core.placement.POLICIES is a deprecated read-only "
            "view; use registered_policies()/get_policy()/parse_policy() "
            "or the repro.api.Runtime facade",
        )
        return _REGISTRY

    def __getitem__(self, name):
        return self._reg()[name]

    def __iter__(self):
        return iter(self._reg())

    def __len__(self):
        return len(self._reg())

    def __contains__(self, name):
        return name in self._reg()

    def __setitem__(self, name, value):  # pragma: no cover - guard rail
        raise TypeError(
            "POLICIES is a read-only view; use register_policy() instead"
        )

    def __repr__(self):
        return f"POLICIES(deprecated view of {sorted(_REGISTRY)})"


_POLICIES_VIEW = _PoliciesView()


def _warn_deprecated(key: str, message: str) -> None:
    from repro.analysis.warnings_registry import warn_once

    warn_once(f"deprecated:{key}", message, DeprecationWarning, stacklevel=4)


def __getattr__(name: str):
    # PEP 562 deprecation shims: the names still resolve (external code
    # keeps working) but emit a single DeprecationWarning per process.
    if name == "POLICIES":
        return _POLICIES_VIEW  # the view warns on first *use*
    if name == "put_like":
        _warn_deprecated(
            "put_like",
            "repro.core.placement.put_like is deprecated; use "
            "repro.api.Runtime.realize (or Runtime.specs) instead",
        )
        return _put_like
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _put_like(tree, mesh: Mesh, specs, role: Role, policy: PlacementPolicy,
              *, donate: bool = False):
    """device_put a pytree under the policy's placement for ``role``.

    ``specs`` is a matching pytree of PartitionSpecs (or a single spec).
    For peer/remote placements the spec of every leaf is extended over the
    tier's donor axis (validated first — a missing donor axis raises
    :class:`DonorAxisError` rather than silently landing locally).
    ``donate=True`` hands each source leaf to the transfer (the
    migration path: the old tier's buffer is freed as the copy lands).

    This is the array-level twin of the def-based realizer
    (``repro.models.sharding``) for trees without Param defs.  Lacking
    logical axis names, a STREAM placement targets the first divisible
    free dim — dim 0 of a stacked tree, i.e. the stack dim — where the
    def-based form targets the dim *labelled* ``layers``.
    """
    pl = policy.placement(role)
    donor = donor_axes_for(mesh, pl.tier)

    def _put(x, spec):
        if donor:
            from repro.models.sharding import donor_extend

            spec = donor_extend(
                spec, x.shape, mesh, donor,
                prefer_stack=pl.strategy is Strategy.STREAM,
            )
        return jax.device_put(
            x,
            NamedSharding(mesh, spec, memory_kind=policy.memory_kind(role)),
            donate=donate,
        )

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _put(x, specs), tree)
    return jax.tree.map(_put, tree, specs)


def to_device(tree, mesh: Mesh, specs):
    """Move a (possibly host-placed) pytree into HBM inside a jit region.

    This is the 'migration' step of a STREAM placement: under jit, XLA turns
    it into a host->device DMA that the latency-hiding scheduler can overlap
    with compute (the TPU analogue of managed-memory prefetch).
    """
    kind = resolve_memory_kind("device")

    def _mv(x, spec):
        return jax.device_put(
            x, NamedSharding(mesh, spec, memory_kind=kind)
        )

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _mv(x, specs), tree)
    return jax.tree.map(_mv, tree, specs)


def to_host(tree, mesh: Mesh, specs):
    """Move a pytree to (pinned) host memory inside a jit region."""
    kind = resolve_memory_kind("pinned_host")

    def _mv(x, spec):
        return jax.device_put(
            x, NamedSharding(mesh, spec, memory_kind=kind)
        )

    if isinstance(specs, PartitionSpec):
        return jax.tree.map(lambda x: _mv(x, specs), tree)
    return jax.tree.map(_mv, tree, specs)


class DonorStream:
    """Double-buffered per-window streaming from a donor-resident stack.

    The executable form of ``Strategy.STREAM`` over a donor axis (the
    planner's ``copy_bound(PEER_*/REMOTE_*, HBM)`` datapath): ``tree``'s
    leaves are stacked along dim 0 into ``n_windows`` windows (layer-wise
    weight streaming stacks per-layer params) and live sharded across the
    donor slices; :meth:`window` returns window ``i`` device_put into the
    **local** sharding and immediately issues the (asynchronous) fetch of
    window ``i+1`` into the second staging slot, so the next fetch crosses
    the ICI/DCN path while the caller computes on window ``i``.  At most
    ``depth`` windows are held locally — the double-buffered staging
    footprint the planner charges against local HBM (``2 * bytes /
    stream_chunks``).
    """

    def __init__(self, tree, mesh: Mesh, specs, n_windows: int,
                 depth: int = 2):
        self._tree = tree
        self._mesh = mesh
        self._specs = specs
        self.n_windows = int(n_windows)
        self.depth = max(int(depth), 2)
        self._buf: dict[int, object] = {}
        self._kind = resolve_memory_kind("device")

    def _fetch(self, i: int):
        def mv(x, spec):
            return jax.device_put(
                x[i], NamedSharding(self._mesh, spec, memory_kind=self._kind)
            )

        if isinstance(self._specs, PartitionSpec):
            return jax.tree.map(lambda x: mv(x, self._specs), self._tree)
        return jax.tree.map(mv, self._tree, self._specs)

    def window(self, i: int):
        """Window ``i`` in local memory; prefetches the next ``depth - 1``
        windows behind it (``depth=2`` = classic double buffering)."""
        if not 0 <= i < self.n_windows:
            raise IndexError(f"window {i} of {self.n_windows}")
        keep = range(i, min(i + self.depth, self.n_windows))
        for j in keep:           # j == i first: the caller's window, then
            if j not in self._buf:     # the async prefetches behind it
                self._buf[j] = self._fetch(j)
        for k in [k for k in self._buf if k not in keep]:
            del self._buf[k]  # bound staging residency to `depth` windows
        return self._buf[i]

    def __iter__(self):
        for i in range(self.n_windows):
            yield self.window(i)
