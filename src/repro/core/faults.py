"""Deterministic fault injection: the harness behind the self-healing runtime.

The paper's placement results assume the links behave; its successors show
what happens when they do not — same-class links varying >2x by physical
route (Pearson, arxiv 2302.14827) and GH200 access-path faults surfacing
as order-of-magnitude *slowdowns* rather than errors (arxiv 2407.07850).
A placement-aware serve runtime therefore needs recovery paths, and
recovery paths need a way to be exercised deterministically.  This module
is that way: a :class:`FaultPlan` is a seeded, step-indexed schedule of
:class:`FaultEvent`\\ s that fire at named injection *sites* — the
dispatch and migration entry points of :class:`repro.api.Runtime` and the
serve :class:`~repro.serve.Executor` — and either raise a typed
fault, stall the caller, or hand back a data-corruption token the caller
applies to the bytes in flight.

Fault taxonomy (see ``docs/robustness.md``):

* :class:`TierLossError` — a donor tier (peer HBM/DRAM over the ``donor``
  axis, remote HBM over ``donor_pod``, or host DRAM) became unusable.
  The serve layer catches it, evacuates every affected role
  (:meth:`repro.api.Runtime.evacuate`), and continues degraded.
* :class:`MigrationFault` — a *transient* migrate/realize failure
  (retryable: :func:`repro.runtime.retry.retry_call` wraps migrations).
* ``stall`` — the dispatch takes far longer than its deadline; not an
  exception at all (the GH200 lesson: path faults often manifest as
  latency).  The :class:`repro.runtime.supervisor.Watchdog` catches it.
* :class:`SpillCorruptionError` — a preemption spill round trip returned
  different bytes than it parked (detected by checksum at promotion).
  The scheduler drops the parked rows and re-queues the request as a
  ``"fresh"`` waiter whose prompt replays everything generated so far —
  bit-identical continuation, because prefill ≡ decode replay.
* :class:`TicketLossError` — a disaggregated handoff ticket (the KV a
  prefill pool published for a decode pool to adopt, see
  ``repro.serve.handoff``) vanished on the DCN path.  The decode-side
  admission adopts nothing and replays the request as fresh through the
  prefill pool — the same ladder as a corrupted spill.

Production paths pay nothing: every site guard is
``if plan: plan.check(site)`` against the falsy :data:`NO_FAULTS`
default.  Only this module may *raise* the injected fault types — the
``injected-fault-raise`` lint rule (allowlist scoped to this file,
verified by ``tools/audit.py --selftest``) keeps the harness from
leaking into production control flow.
"""

from __future__ import annotations

import dataclasses
import enum
import json
import time
from typing import Iterable

import jax
import jax.numpy as jnp

from repro.core.placement import DonorAxisError, parse_tier

__all__ = [
    "FaultKind",
    "FaultEvent",
    "FaultPlan",
    "InjectedFault",
    "TransientFault",
    "TierLossError",
    "MigrationFault",
    "SpillCorruptionError",
    "TicketLossError",
    "NO_FAULTS",
    "checksum_tree",
    "corrupt_tree",
    "verify_spill",
]


class FaultKind(str, enum.Enum):
    """What an event does when it fires."""

    TIER_LOSS = "tier_loss"          # drop a donor/host tier mid-run
    MIGRATE_FAIL = "migrate_fail"    # fail a migrate()/realize() call
    STALL = "stall"                  # stall a dispatch past its deadline
    SPILL_CORRUPT = "spill_corrupt"  # corrupt a spill round trip
    TICKET_LOSS = "ticket_loss"      # drop a disagg handoff ticket in flight


class InjectedFault(RuntimeError):
    """Base class of every fault the harness raises."""


class TransientFault(InjectedFault):
    """A fault that may succeed on retry — what retry policies wrap."""


class TierLossError(InjectedFault):
    """A memory tier (and everything parked on it) became unusable.

    Carries the lost :class:`~repro.core.hardware.MemoryTier`; the serve
    layer's recovery path (`Server._recover_tier_loss`) marks it lost on
    the runtime, evacuates affected roles, and re-queues spilled
    sequences whose parked rows lived there.
    """

    def __init__(self, tier, message: str = ""):
        self.tier = parse_tier(tier)
        super().__init__(
            message or f"tier {self.tier.value} lost: donor axis dropped"
        )


class MigrationFault(TransientFault):
    """A transient migrate/realize failure (link hiccup surrogate)."""


class TicketLossError(InjectedFault):
    """A disaggregated handoff ticket vanished in flight.

    Carries the request id; the decode-side admission path catches it,
    adopts nothing, and re-queues the request as a ``"fresh"`` waiter
    routed back to the prefill pool — the same replay-as-fresh ladder a
    corrupted handoff transfer takes (prefill ≡ decode replay, so the
    continuation is bit-identical).
    """

    def __init__(self, rid: int, message: str = ""):
        self.rid = rid
        super().__init__(
            message or f"handoff ticket for rid {rid} lost in flight; "
            "replaying the request through the prefill pool"
        )


class SpillCorruptionError(InjectedFault):
    """A promoted spill's bytes differ from what was parked."""

    def __init__(self, rid: int, expected: float, got: float):
        self.rid = rid
        self.expected = expected
        self.got = got
        super().__init__(
            f"spilled rows for rid {rid} failed their integrity check "
            f"(checksum {got!r} != {expected!r} at spill time); dropping "
            "the parked rows and replaying the sequence"
        )


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``site`` names the injection point (``decode`` / ``prefill`` /
    ``migrate`` / ``realize`` / ``extract`` / ``spill`` /
    ``handoff`` / ``checkpoint``); ``at`` is the 0-indexed pass through that site on
    which the event fires, and ``times`` how many *consecutive* passes it
    keeps firing for (>1 models a fault that outlives one retry).
    """

    site: str
    at: int
    kind: FaultKind
    #: TIER_LOSS target, any ``parse_tier`` spelling ("peer_hbm", "host")
    tier: str | None = None
    #: STALL duration
    seconds: float = 0.0
    times: int = 1
    #: MIGRATE_FAIL flavor: "transient" raises the retryable
    #: MigrationFault; "donor" raises DonorAxisError (permanent — what a
    #: real donor-axis validation failure looks like mid-replan)
    error: str = "transient"

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["kind"] = self.kind.value
        return d


class FaultPlan:
    """A deterministic, step-indexed schedule of injected faults.

    Sites call :meth:`check` once per pass; the plan counts passes per
    site and fires the events whose ``[at, at + times)`` window covers
    the current index.  Everything is decided by construction — no
    randomness at fire time — so a seeded schedule replays exactly.

    The falsy :data:`NO_FAULTS` (an empty plan) is the production
    default; guards read ``if plan: plan.check(site)`` so the no-fault
    hot path costs one attribute truthiness test.
    """

    def __init__(self, events: Iterable[FaultEvent] = (), seed: int = 0):
        self.events = tuple(events)
        self.seed = int(seed)
        self._counts: dict[str, int] = {}
        #: every fired (site, index, event), in firing order — what the
        #: chaos soak records next to its results
        self.fired: list[tuple[str, int, FaultEvent]] = []

    def __bool__(self) -> bool:
        return bool(self.events)

    def __repr__(self) -> str:
        return (
            f"FaultPlan(seed={self.seed}, events={len(self.events)}, "
            f"fired={len(self.fired)})"
        )

    def site_count(self, site: str) -> int:
        """Passes through ``site`` so far."""
        return self._counts.get(site, 0)

    def check(self, site: str, *, rid: int = -1) -> FaultEvent | None:
        """Count one pass through ``site`` and fire any matching event.

        TIER_LOSS, MIGRATE_FAIL and TICKET_LOSS raise; STALL sleeps and
        returns the event; SPILL_CORRUPT returns the event for the
        caller to apply (the harness cannot reach the bytes being
        parked).  ``rid`` tags the request a ``handoff``-site fault hits
        (TICKET_LOSS carries it).  Returns ``None`` when nothing fires.
        """
        idx = self._counts.get(site, 0)
        self._counts[site] = idx + 1
        hit: FaultEvent | None = None
        for ev in self.events:
            if ev.site != site or not ev.at <= idx < ev.at + ev.times:
                continue
            self.fired.append((site, idx, ev))
            if ev.kind is FaultKind.STALL:
                time.sleep(ev.seconds)
                hit = ev
            elif ev.kind is FaultKind.TIER_LOSS:
                raise TierLossError(ev.tier or "peer_hbm")
            elif ev.kind is FaultKind.TICKET_LOSS:
                raise TicketLossError(rid)
            elif ev.kind is FaultKind.MIGRATE_FAIL:
                if ev.error == "donor":
                    raise DonorAxisError(
                        f"injected donor-axis failure at {site}[{idx}]"
                    )
                raise MigrationFault(
                    f"injected transient {site} failure at pass {idx}"
                )
            else:  # SPILL_CORRUPT: data fault, applied by the caller
                hit = ev
        return hit

    def to_json(self) -> dict:
        """Schedule + firing record, for the chaos soak's artifact."""
        return {
            "seed": self.seed,
            "events": [ev.to_json() for ev in self.events],
            "fired": [
                {"site": site, "index": idx, **ev.to_json()}
                for site, idx, ev in self.fired
            ],
        }

    def summary(self) -> str:
        return json.dumps(self.to_json(), indent=2, sort_keys=True)


#: the production default: no events, falsy, check() never fires.
NO_FAULTS = FaultPlan()


# ---------------------------------------------------------------------------
# Spill-integrity helpers (checksum at park time, verify at promotion)
# ---------------------------------------------------------------------------

def checksum_tree(tree) -> float:
    """Cheap order-deterministic checksum of a pytree's values.

    One f32 reduction per leaf (the sum order inside a leaf is fixed per
    compilation, and the same bytes re-summed give the same float), so a
    parked spill can be verified at promotion without holding a second
    copy.  Off the per-token path — only spill/promote lifecycle events
    pay for it, and only when spill verification is on.
    """
    total = 0.0
    for leaf in jax.tree.leaves(tree):
        total += float(jnp.sum(jnp.asarray(leaf, jnp.float32)))
    return total


def corrupt_tree(tree):
    """Perturb one element of the first leaf — the SPILL_CORRUPT payload.

    Deterministic and minimal: enough to trip :func:`checksum_tree`
    verification without masking bookkeeping bugs behind large damage.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if leaves:
        x = leaves[0]
        leaves = [x.at[(0,) * x.ndim].add(jnp.asarray(1, x.dtype))] \
            + list(leaves[1:])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def verify_spill(rows, checksum: float | None, rid: int) -> None:
    """Raise :class:`SpillCorruptionError` when ``rows`` no longer match
    the checksum taken at spill time (``checksum=None`` skips — spills
    are only checksummed when verification is enabled)."""
    if checksum is None:
        return
    got = checksum_tree(rows)
    if got != checksum:
        raise SpillCorruptionError(rid, checksum, got)
