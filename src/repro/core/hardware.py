"""TPU hardware model: the single source of truth for roofline constants.

The paper characterizes the Quad GH200 node of Alps by enumerating its
processing units, physical memories, and interconnects (paper Fig. 1) and
deriving a theoretical bound for every datapath (paper Fig. 3).  This module
is the TPU v5e analogue: a declarative description of the chip, the host
link, the ICI torus, and the inter-pod DCN, consumed by
:mod:`repro.core.datapath` and :mod:`repro.core.roofline`.

All bandwidth numbers are bytes/second, latencies in seconds, capacities in
bytes.  Values marked ``# task-spec`` are the constants prescribed for the
roofline analysis; the others are public v5e-class figures used only for
secondary analyses (latency plots, VMEM tiling checks) and clearly separable.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Mapping


class MemoryTier(str, enum.Enum):
    """Physical memory pools a tensor can live in, from the chip's view.

    Mirrors the paper's {HBM, DDR, HBM-p, DDR-p} axis (Figs. 5, 7, 9),
    adapted to the TPU memory system plus the on-chip VMEM tier.
    """

    VMEM = "vmem"            # on-chip scratch (Pallas BlockSpec target)
    HBM = "hbm"              # local device HBM
    HOST = "host"            # this chip's host DRAM (pinned_host)
    PEER_HBM = "hbm_p"       # another chip's HBM, same pod (via ICI)
    PEER_HOST = "host_p"     # another host's DRAM, same pod (PCIe+ICI+PCIe)
    REMOTE_HBM = "hbm_r"     # a chip's HBM in another pod (via DCN)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


class Link(str, enum.Enum):
    """Interconnects, the paper's 'datapath segments'."""

    HBM_BUS = "hbm_bus"      # HBM <-> chip
    VMEM_BUS = "vmem_bus"    # VMEM <-> compute units
    PCIE = "pcie"            # host DRAM <-> chip
    ICI = "ici"              # chip <-> neighbor chip, per link
    DCN = "dcn"              # pod <-> pod, per chip

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """One TPU chip."""

    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12          # task-spec: 197 TFLOP/s bf16
    hbm_bandwidth: float = 819e9             # task-spec: 819 GB/s
    hbm_capacity: float = 16 * 2**30         # 16 GiB (v5e-class)
    # Per-chip share of the host's DRAM (v5e-class hosts pair ~512 GiB of
    # DDR with 8 chips) — the planner's second capacity pool, mirroring the
    # paper's 480 GiB LPDDR per Grace (vs 96 GiB HBM per Hopper).
    host_dram_capacity: float = 64 * 2**30
    vmem_capacity: float = 128 * 2**20       # ~128 MiB VMEM (v5e-class)
    vmem_bandwidth: float = 11.4e12          # derived: keeps 8x8x128 MXU fed
    ici_link_bandwidth: float = 50e9         # task-spec: ~50 GB/s/link ICI
    ici_links_per_axis: int = 1              # links used per hop of a collective
    pcie_bandwidth: float = 32e9             # PCIe Gen4 x16-class host link
    dcn_bandwidth: float = 25e9              # per-chip inter-pod bandwidth
    # Latency terms (seconds) for the latency benchmarks (paper Figs. 11-13).
    hbm_latency: float = 700e-9
    vmem_latency: float = 30e-9
    pcie_latency: float = 2.0e-6
    ici_hop_latency: float = 1.0e-6
    dcn_latency: float = 10.0e-6
    # MXU tile: matmul dims should be multiples of this for full utilization.
    mxu_dim: int = 128
    # Peak flops by dtype (GEMM study, paper Table III analogue).
    peak_flops_by_dtype: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: {
            "bfloat16": 197e12,
            "float32": 98.5e12,   # fp32 runs at half MXU rate on v5e-class
            "int8": 394e12,
        }
    )


@dataclasses.dataclass(frozen=True)
class PodSpec:
    """A pod slice: chips arranged in a 2D ICI torus (v5e-style 16x16)."""

    chip: ChipSpec = dataclasses.field(default_factory=ChipSpec)
    mesh_shape: tuple[int, ...] = (16, 16)
    torus_wraparound: bool = True

    @property
    def num_chips(self) -> int:
        return math.prod(self.mesh_shape)

    def ici_hops(self, a: tuple[int, ...], b: tuple[int, ...]) -> int:
        """Manhattan hop distance between two chips on the (wrapped) torus."""
        hops = 0
        for ax, (i, j) in enumerate(zip(a, b)):
            d = abs(i - j)
            if self.torus_wraparound:
                d = min(d, self.mesh_shape[ax] - d)
            hops += d
        return hops

    def bisection_bandwidth(self) -> float:
        """All-links bisection bandwidth of the pod (for sanity checks)."""
        # Cut the torus along its largest axis: 2 * (other-axes product)
        # links cross the cut (x2 for wraparound).
        longest = max(self.mesh_shape)
        cross = self.num_chips // longest
        wrap = 2 if self.torus_wraparound else 1
        return cross * wrap * self.chip.ici_link_bandwidth


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """The full target: ``num_pods`` pods joined by DCN.

    The production configuration for this repo is 2 pods x 256 chips
    (the multi-pod dry-run mesh); ``num_pods`` scales to thousands of
    nodes for planner what-ifs.
    """

    pod: PodSpec = dataclasses.field(default_factory=PodSpec)
    num_pods: int = 2

    @property
    def num_chips(self) -> int:
        return self.pod.num_chips * self.num_pods

    @property
    def chip(self) -> ChipSpec:
        return self.pod.chip

    def link_bandwidth(self, link: Link) -> float:
        c = self.chip
        return {
            Link.HBM_BUS: c.hbm_bandwidth,
            Link.VMEM_BUS: c.vmem_bandwidth,
            Link.PCIE: c.pcie_bandwidth,
            Link.ICI: c.ici_link_bandwidth * c.ici_links_per_axis,
            Link.DCN: c.dcn_bandwidth,
        }[link]

    def link_latency(self, link: Link) -> float:
        c = self.chip
        return {
            Link.HBM_BUS: c.hbm_latency,
            Link.VMEM_BUS: c.vmem_latency,
            Link.PCIE: c.pcie_latency,
            Link.ICI: c.ici_hop_latency,
            Link.DCN: c.dcn_latency,
        }[link]


#: Default system used everywhere unless a config overrides it.
DEFAULT_SYSTEM = SystemSpec()

#: Mesh-axis -> link map for the production meshes (see launch/mesh.py).
#: 'model' and 'data' are intra-pod ICI axes; 'pod' crosses DCN.  This is
#: the paper's "locality beats memory type" lesson (Fig. 19) as data: the
#: axis you put a collective on decides its link, and therefore its bound.
AXIS_LINK: dict[str, Link] = {
    "model": Link.ICI,
    "data": Link.ICI,
    "pod": Link.DCN,
}


def axis_bandwidth(axis: str, system: SystemSpec = DEFAULT_SYSTEM) -> float:
    """Per-chip bandwidth available to a collective running on ``axis``."""
    return system.link_bandwidth(AXIS_LINK.get(axis, Link.ICI))
