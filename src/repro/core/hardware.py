"""TPU hardware model: the single source of truth for roofline constants.

The paper characterizes the Quad GH200 node of Alps by enumerating its
processing units, physical memories, and interconnects (paper Fig. 1) and
deriving a theoretical bound for every datapath (paper Fig. 3).  This module
is the TPU v5e analogue: a declarative description of the chip, the host
link, the ICI torus, and the inter-pod DCN, consumed by
:mod:`repro.core.datapath` and :mod:`repro.core.roofline`.

All bandwidth numbers are bytes/second, latencies in seconds, capacities in
bytes.  Values marked ``# task-spec`` are the constants prescribed for the
roofline analysis; the others are public v5e-class figures used only for
secondary analyses (latency plots, VMEM tiling checks) and clearly separable.

Provenance
----------

The paper's whole method is *measuring* each datapath and reporting the
achieved fraction of the bound — a planner priced off spec-sheet numbers
alone is exactly the "assumed placement" trap §IV warns against.  Every
calibratable term therefore carries a provenance tag:

* ``spec``     — the declarative constant below (the default);
* ``measured`` — rewritten from a microbenchmark via
  :meth:`SystemSpec.with_measurements` (see
  :mod:`repro.core.calibration`);
* ``override`` — pinned by hand via :meth:`SystemSpec.with_overrides`.

Consumers resolve their system through :func:`get_active_system` (or an
explicitly passed ``system=``); the spec-sheet baseline stays available
as the module's default system and is what every process starts with.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Mapping


class MemoryTier(str, enum.Enum):
    """Physical memory pools a tensor can live in, from the chip's view.

    Mirrors the paper's {HBM, DDR, HBM-p, DDR-p} axis (Figs. 5, 7, 9),
    adapted to the TPU memory system plus the on-chip VMEM tier.
    """

    VMEM = "vmem"            # on-chip scratch (Pallas BlockSpec target)
    HBM = "hbm"              # local device HBM
    HOST = "host"            # this chip's host DRAM (pinned_host)
    PEER_HBM = "hbm_p"       # another chip's HBM, same pod (via ICI)
    PEER_HOST = "host_p"     # another host's DRAM, same pod (PCIe+ICI+PCIe)
    REMOTE_HBM = "hbm_r"     # a chip's HBM in another pod (via DCN)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


class Link(str, enum.Enum):
    """Interconnects, the paper's 'datapath segments'."""

    HBM_BUS = "hbm_bus"      # HBM <-> chip
    VMEM_BUS = "vmem_bus"    # VMEM <-> compute units
    PCIE = "pcie"            # host DRAM <-> chip
    ICI = "ici"              # chip <-> neighbor chip, per link
    DCN = "dcn"              # pod <-> pod, per chip

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """One TPU chip."""

    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12          # task-spec: 197 TFLOP/s bf16
    hbm_bandwidth: float = 819e9             # task-spec: 819 GB/s
    hbm_capacity: float = 16 * 2**30         # 16 GiB (v5e-class)
    # Per-chip share of the host's DRAM (v5e-class hosts pair ~512 GiB of
    # DDR with 8 chips) — the planner's second capacity pool, mirroring the
    # paper's 480 GiB LPDDR per Grace (vs 96 GiB HBM per Hopper).
    host_dram_capacity: float = 64 * 2**30
    vmem_capacity: float = 128 * 2**20       # ~128 MiB VMEM (v5e-class)
    vmem_bandwidth: float = 11.4e12          # derived: keeps 8x8x128 MXU fed
    ici_link_bandwidth: float = 50e9         # task-spec: ~50 GB/s/link ICI
    ici_links_per_axis: int = 1              # links used per hop of a collective
    pcie_bandwidth: float = 32e9             # PCIe Gen4 x16-class host link
    dcn_bandwidth: float = 25e9              # per-chip inter-pod bandwidth
    # Latency terms (seconds) for the latency benchmarks (paper Figs. 11-13).
    hbm_latency: float = 700e-9
    vmem_latency: float = 30e-9
    pcie_latency: float = 2.0e-6
    ici_hop_latency: float = 1.0e-6
    dcn_latency: float = 10.0e-6
    # MXU tile: matmul dims should be multiples of this for full utilization.
    mxu_dim: int = 128
    # Peak flops by dtype (GEMM study, paper Table III analogue).
    peak_flops_by_dtype: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: {
            "bfloat16": 197e12,
            "float32": 98.5e12,   # fp32 runs at half MXU rate on v5e-class
            "int8": 394e12,
        }
    )


@dataclasses.dataclass(frozen=True)
class PodSpec:
    """A pod slice: chips arranged in a 2D ICI torus (v5e-style 16x16)."""

    chip: ChipSpec = dataclasses.field(default_factory=ChipSpec)
    mesh_shape: tuple[int, ...] = (16, 16)
    torus_wraparound: bool = True

    @property
    def num_chips(self) -> int:
        return math.prod(self.mesh_shape)

    def ici_hops(self, a: tuple[int, ...], b: tuple[int, ...]) -> int:
        """Manhattan hop distance between two chips on the (wrapped) torus."""
        hops = 0
        for ax, (i, j) in enumerate(zip(a, b)):
            d = abs(i - j)
            if self.torus_wraparound:
                d = min(d, self.mesh_shape[ax] - d)
            hops += d
        return hops

    def bisection_bandwidth(self) -> float:
        """All-links bisection bandwidth of the pod (for sanity checks)."""
        # Cut the torus along its largest axis: 2 * (other-axes product)
        # links cross the cut (x2 for wraparound).
        longest = max(self.mesh_shape)
        cross = self.num_chips // longest
        wrap = 2 if self.torus_wraparound else 1
        return cross * wrap * self.chip.ici_link_bandwidth


#: provenance values a calibratable term may carry
PROVENANCES = ("spec", "measured", "override")

#: Calibratable terms: name -> the :class:`ChipSpec` field it rewrites.
#: These are exactly the bandwidth/latency/peak constants the datapath
#: bounds are built from — the terms :mod:`repro.core.calibration`
#: measures and :mod:`repro.core.replay` validates.
CALIBRATED_TERMS: dict[str, str] = {
    "peak_bf16_flops": "peak_bf16_flops",
    "hbm_bandwidth": "hbm_bandwidth",
    "vmem_bandwidth": "vmem_bandwidth",
    "pcie_bandwidth": "pcie_bandwidth",
    "ici_link_bandwidth": "ici_link_bandwidth",
    "dcn_bandwidth": "dcn_bandwidth",
    "hbm_latency": "hbm_latency",
    "vmem_latency": "vmem_latency",
    "pcie_latency": "pcie_latency",
    "ici_hop_latency": "ici_hop_latency",
    "dcn_latency": "dcn_latency",
}


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """The full target: ``num_pods`` pods joined by DCN.

    The production configuration for this repo is 2 pods x 256 chips
    (the multi-pod dry-run mesh); ``num_pods`` scales to thousands of
    nodes for planner what-ifs.

    ``provenance`` maps each :data:`CALIBRATED_TERMS` name to
    ``spec | measured | override`` (absent -> ``spec``).  Instances are
    immutable: :meth:`with_measurements` / :meth:`with_overrides` derive
    a new spec with the terms rewritten and tagged.
    """

    pod: PodSpec = dataclasses.field(default_factory=PodSpec)
    num_pods: int = 2
    provenance: Mapping[str, str] = dataclasses.field(default_factory=dict)

    @property
    def num_chips(self) -> int:
        return self.pod.num_chips * self.num_pods

    @property
    def chip(self) -> ChipSpec:
        return self.pod.chip

    def link_bandwidth(self, link: Link) -> float:
        c = self.chip
        return {
            Link.HBM_BUS: c.hbm_bandwidth,
            Link.VMEM_BUS: c.vmem_bandwidth,
            Link.PCIE: c.pcie_bandwidth,
            Link.ICI: c.ici_link_bandwidth * c.ici_links_per_axis,
            Link.DCN: c.dcn_bandwidth,
        }[link]

    def link_latency(self, link: Link) -> float:
        c = self.chip
        return {
            Link.HBM_BUS: c.hbm_latency,
            Link.VMEM_BUS: c.vmem_latency,
            Link.PCIE: c.pcie_latency,
            Link.ICI: c.ici_hop_latency,
            Link.DCN: c.dcn_latency,
        }[link]

    # -- calibration surface ----------------------------------------------
    def term_value(self, term: str) -> float:
        """Current value of a calibratable term."""
        return getattr(self.chip, _term_field(term))

    def provenance_of(self, term: str) -> str:
        """``spec | measured | override`` for ``term`` (spec when never
        rewritten)."""
        _term_field(term)  # validate
        return self.provenance.get(term, "spec")

    def _derive(self, provenance: str, terms: Mapping[str, float]
                ) -> "SystemSpec":
        if provenance not in PROVENANCES:
            raise ValueError(
                f"unknown provenance {provenance!r}; one of {PROVENANCES}"
            )
        chip_updates = {}
        for term, value in terms.items():
            field = _term_field(term)
            value = float(value)
            if not value > 0.0:
                raise ValueError(
                    f"calibrated term {term} must be > 0, got {value!r}"
                )
            chip_updates[field] = value
        new_chip = dataclasses.replace(self.chip, **chip_updates)
        new_pod = dataclasses.replace(self.pod, chip=new_chip)
        new_prov = dict(self.provenance)
        new_prov.update({t: provenance for t in terms})
        return dataclasses.replace(self, pod=new_pod, provenance=new_prov)

    def with_measurements(self, **terms: float) -> "SystemSpec":
        """A new spec with ``terms`` rewritten from measurements and
        tagged ``measured`` — the derivation :func:`repro.core.
        calibration.calibrate` applies after running the membw/pingpong/
        collective kernels."""
        return self._derive("measured", terms)

    def with_overrides(self, **terms: float) -> "SystemSpec":
        """A new spec with ``terms`` pinned by hand (``override``)."""
        return self._derive("override", terms)

    def describe_terms(self) -> dict[str, dict]:
        """Per-term ``{value, provenance}`` — what ``calibration.json``
        records for every constant the scheduler acts on."""
        return {
            term: {
                "value": self.term_value(term),
                "provenance": self.provenance_of(term),
            }
            for term in CALIBRATED_TERMS
        }


def _term_field(term: str) -> str:
    try:
        return CALIBRATED_TERMS[term]
    except KeyError:
        raise KeyError(
            f"unknown calibratable term {term!r}; "
            f"one of {sorted(CALIBRATED_TERMS)}"
        ) from None


#: Spec-sheet baseline system (every term provenance ``spec``).
DEFAULT_SYSTEM = SystemSpec()

#: The process-wide system consumers resolve through get_active_system().
_ACTIVE_SYSTEM: SystemSpec = DEFAULT_SYSTEM


def get_active_system() -> SystemSpec:
    """The system every pricing path uses when no explicit ``system=`` is
    passed: the spec-sheet baseline until :func:`set_active_system`
    installs a calibrated one (see :meth:`repro.api.Runtime.calibrate`
    and the launchers' ``--calibration`` flag)."""
    return _ACTIVE_SYSTEM


def set_active_system(system: SystemSpec) -> SystemSpec:
    """Install ``system`` as the process-wide default; returns the
    previous one (restore it in tests)."""
    global _ACTIVE_SYSTEM
    if not isinstance(system, SystemSpec):
        raise TypeError(f"expected SystemSpec, got {type(system).__name__}")
    prev = _ACTIVE_SYSTEM
    _ACTIVE_SYSTEM = system
    return prev


#: Mesh-axis -> link map for the production meshes (see launch/mesh.py).
#: 'model' and 'data' are intra-pod ICI axes; 'pod' crosses DCN; the
#: 'donor'/'donor_pod' memory-donor axes (core/placement.py) ride ICI and
#: DCN respectively.  This is the paper's "locality beats memory type"
#: lesson (Fig. 19) as data: the axis you put a collective on decides its
#: link, and therefore its bound.
AXIS_LINK: dict[str, Link] = {
    "model": Link.ICI,
    "data": Link.ICI,
    "pod": Link.DCN,
    "donor": Link.ICI,
    "donor_pod": Link.DCN,
}

def link_for_axis(axis: str, *, strict: bool = False) -> Link:
    """The physical link a mesh axis runs over.

    Unknown axes used to default silently to ICI — which priced the
    ``donor_pod`` DCN axis at ICI bandwidth.  Now ``strict=True`` raises
    ``KeyError`` and the default warns once per axis name before falling
    back to ICI, so a mispriced collective is never silent.
    """
    try:
        return AXIS_LINK[axis]
    except KeyError:
        if strict:
            raise KeyError(
                f"mesh axis {axis!r} has no AXIS_LINK entry; known axes: "
                f"{sorted(AXIS_LINK)} — register it so collectives on it "
                "are priced at the right link"
            ) from None
        from repro.analysis.warnings_registry import warn_once

        warn_once(
            f"axis_link:{axis}",
            f"mesh axis {axis!r} has no AXIS_LINK entry; pricing its "
            "collectives at ICI bandwidth (add it to "
            "repro.core.hardware.AXIS_LINK if it crosses another link)",
        )
        return Link.ICI


def axis_bandwidth(
    axis: str, system: SystemSpec | None = None, *, strict: bool = False
) -> float:
    """Per-chip bandwidth available to a collective running on ``axis``."""
    system = system if system is not None else get_active_system()
    return system.link_bandwidth(link_for_axis(axis, strict=strict))
