"""Calibrate the hardware model from the repo's own microbenchmarks.

The paper's method is to *measure* every datapath and report the achieved
fraction of its bound; this module closes the loop by rewriting the
roofline constants themselves from those measurements.  ``calibrate()``
runs in-process versions of the ``bench_membw`` (HBM + PCIe read
sweeps), ``bench_pingpong`` (neighbor ``ppermute``) and
``bench_collectives`` (``psum``) kernels, fits ``t = latency +
nbytes/bandwidth`` per link (:func:`repro.core.membench.linear_fit`),
and derives a :class:`repro.core.hardware.SystemSpec` whose terms carry
``measured`` provenance via :meth:`SystemSpec.with_measurements`.

The result is a :class:`Calibration`: per-term spec-vs-measured values
plus a :class:`repro.core.replay.ReplayLog` that replays every sweep
point against the *calibrated* bounds — a self-consistency check whose
per-term relative error drives the CI drift gate
(:meth:`ReplayLog.gate`).  ``Calibration.save`` persists the whole thing
as ``calibration.json``; ``load_or_calibrate`` makes the file the cache.

On this CPU container every "link" is host DRAM, so measured terms land
far from the TPU spec sheet — which is the point: the planner then
prices placements for the machine it is actually on, and the divergence
itself is visible in the provenance report.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Mapping, Sequence

from repro.core.hardware import (
    CALIBRATED_TERMS,
    Link,
    MemoryTier,
    SystemSpec,
    get_active_system,
    set_active_system,
)
from repro.core.membench import Measurement, linear_fit, measure
from repro.core.replay import ReplayLog

__all__ = [
    "TermCalibration",
    "Calibration",
    "calibrate",
    "load_or_calibrate",
]

#: default buffer-size sweep (bytes): small enough for CI, spread enough
#: for the latency/bandwidth fit to separate its two terms
DEFAULT_SIZES: tuple[int, ...] = (2**18, 2**21, 2**24)

FORMAT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TermCalibration:
    """One constant's spec-vs-measured record."""

    term: str
    spec: float
    measured: float
    unit: str                 # "B/s" | "s"
    source: str               # which kernel produced it
    detail: str = ""          # free-form: fit quality, device count, ...

    @property
    def ratio(self) -> float:
        """measured / spec — how far the machine is from the sheet."""
        return self.measured / self.spec if self.spec else float("inf")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj: Mapping) -> "TermCalibration":
        return cls(**{f.name: obj[f.name] for f in dataclasses.fields(cls)
                      if f.name in obj})


@dataclasses.dataclass
class Calibration:
    """A full calibration run: measured terms + replay validation."""

    backend: str
    num_devices: int
    created: str                                   # ISO timestamp
    terms: dict[str, TermCalibration] = dataclasses.field(
        default_factory=dict
    )
    replay: ReplayLog = dataclasses.field(default_factory=ReplayLog)

    def apply(self, system: SystemSpec | None = None) -> SystemSpec:
        """Derive a system with every measured term rewritten (provenance
        ``measured``)."""
        system = system if system is not None else get_active_system()
        if not self.terms:
            return system
        return system.with_measurements(
            **{t: c.measured for t, c in self.terms.items()}
        )

    def summary(self) -> str:
        lines = [
            f"calibration: backend={self.backend} devices={self.num_devices}"
            f" created={self.created}",
            f"{'term':<22} {'spec':>12} {'measured':>12} {'ratio':>7} "
            f"source",
        ]
        for term in sorted(self.terms):
            c = self.terms[term]
            lines.append(
                f"{term:<22} {_si(c.spec, c.unit):>12} "
                f"{_si(c.measured, c.unit):>12} {c.ratio:>6.2f}x {c.source}"
            )
        uncal = sorted(set(CALIBRATED_TERMS) - set(self.terms))
        if uncal:
            lines.append(f"(spec provenance kept for: {', '.join(uncal)})")
        return "\n".join(lines)

    # -- persistence ------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "format_version": FORMAT_VERSION,
            "backend": self.backend,
            "num_devices": self.num_devices,
            "created": self.created,
            "terms": {t: c.to_json() for t, c in sorted(self.terms.items())},
            "provenance": {t: "measured" for t in sorted(self.terms)},
            "replay": self.replay.to_json(),
        }

    @classmethod
    def from_json(cls, obj: Mapping) -> "Calibration":
        version = obj.get("format_version", 0)
        if version > FORMAT_VERSION:
            raise ValueError(
                f"calibration.json format {version} is newer than this "
                f"code understands ({FORMAT_VERSION}); re-run calibrate()"
            )
        return cls(
            backend=obj.get("backend", "unknown"),
            num_devices=int(obj.get("num_devices", 0)),
            created=obj.get("created", ""),
            terms={
                t: TermCalibration.from_json(c)
                for t, c in obj.get("terms", {}).items()
            },
            replay=ReplayLog.from_json(obj.get("replay", {})),
        )

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.to_json(), indent=2) + "\n")
        return path

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "Calibration":
        return cls.from_json(json.loads(pathlib.Path(path).read_text()))


def _si(v: float, unit: str) -> str:
    if unit == "B/s":
        return f"{v / 1e9:.2f}GB/s"
    if unit == "s":
        return f"{v * 1e6:.2f}us"
    return f"{v:.3g}{unit}"


# ---------------------------------------------------------------------------
# Measurement kernels (in-process analogues of benchmarks/bench_*.py)
# ---------------------------------------------------------------------------

def _sweep_read(kind: str | None, sizes: Sequence[int], repeats: int
                ) -> list[Measurement]:
    """bench_membw's read kernel: jit sum over a buffer placed in
    ``kind`` memory (``None`` -> the backend's default memory)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import SingleDeviceSharding

    read = jax.jit(lambda x: jnp.sum(x))
    out = []
    dev = jax.devices()[0]
    sharding = (SingleDeviceSharding(dev) if kind is None
                else SingleDeviceSharding(dev, memory_kind=kind))
    kind = kind or "device"
    for nbytes in sizes:
        x = jax.device_put(jnp.ones((nbytes // 4,), jnp.float32), sharding)
        out.append(measure(
            lambda x=x: read(x), name=f"read[{kind},{nbytes}]",
            nbytes=nbytes, repeats=repeats,
        ))
        del x
    return out


def _sweep_permute(axis_name: str, mesh_shape: tuple[int, ...],
                   axis_names: tuple[str, ...], sizes: Sequence[int],
                   repeats: int) -> list[Measurement]:
    """bench_pingpong's kernel at bulk sizes: one-hop ``ppermute`` over
    ``axis_name``, measuring per-chip shard bytes through one link."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat(mesh_shape, axis_names)
    axis_size = dict(zip(axis_names, mesh_shape))[axis_name]
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    f = jax.jit(shard_map(
        lambda v: jax.lax.ppermute(v, axis_name, perm),
        mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name),
    ))
    out = []
    for nbytes in sizes:
        # per-chip shard of `nbytes` -> global buffer of axis_size * nbytes
        x = jnp.ones((axis_size * (nbytes // 4),), jnp.float32)
        out.append(measure(
            lambda x=x: f(x), name=f"ppermute[{axis_name},{nbytes}]",
            nbytes=nbytes, repeats=repeats,
        ))
        del x
    return out


def _measure_psum(mesh_shape: tuple[int, ...], axis_names: tuple[str, ...],
                  axis_name: str, nbytes: int, repeats: int) -> Measurement:
    """bench_collectives' psum kernel: replay-only observation."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat(mesh_shape, axis_names)
    f = jax.jit(shard_map(
        lambda v: jax.lax.psum(v, axis_name),
        mesh=mesh, in_specs=P(None), out_specs=P(None), check_rep=False,
    ))
    x = jnp.ones((nbytes // 4,), jnp.float32)
    return measure(
        lambda: f(x), name=f"psum[{axis_name},{nbytes}]",
        nbytes=nbytes, repeats=repeats,
    )


# ---------------------------------------------------------------------------
# calibrate(): run kernels -> fit terms -> replay against calibrated bounds
# ---------------------------------------------------------------------------

def calibrate(
    system: SystemSpec | None = None,
    *,
    sizes: Sequence[int] = DEFAULT_SIZES,
    repeats: int = 5,
    include_collectives: bool = True,
) -> Calibration:
    """Measure every reachable link and build a :class:`Calibration`.

    Kernels are gated on what the runtime exposes: PCIe terms need a
    distinct host memory space (:func:`repro.core.placement.
    host_available`), ICI terms need >= 2 devices, DCN terms >= 4 (a
    (2, n/2) ("pod", "model") mesh, the bench_collectives layout).
    Unreachable terms keep ``spec`` provenance — the report says so
    rather than inventing numbers.
    """
    import jax

    from repro.core.datapath import collective_bound, read_bound
    from repro.core.placement import host_available

    system = system if system is not None else get_active_system()
    devices = jax.devices()
    ndev = len(devices)
    cal = Calibration(
        backend=devices[0].platform,
        num_devices=ndev,
        created=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )
    sweeps: dict[str, list[Measurement]] = {}

    def fit(term_bw: str, term_lat: str, source: str,
            ms: list[Measurement], detail: str) -> None:
        latency, bandwidth = linear_fit(ms)
        spec_bw = system.term_value(term_bw)
        spec_lat = system.term_value(term_lat)
        cal.terms[term_bw] = TermCalibration(
            term=term_bw, spec=spec_bw, measured=bandwidth,
            unit="B/s", source=source, detail=detail,
        )
        # a fit intercept of ~0 (bulk-dominated sweep) would erase the
        # latency term entirely; keep spec latency unless the fit
        # resolved something above the timer floor.
        if latency > 1e-7:
            cal.terms[term_lat] = TermCalibration(
                term=term_lat, spec=spec_lat, measured=latency,
                unit="s", source=source, detail=detail,
            )

    # 1. HBM bus: default-memory read sweep ("device" on TPU; the CPU
    # backend's only memory otherwise)
    ms = _sweep_read(None, sizes, repeats)
    sweeps["hbm_bandwidth"] = ms
    fit("hbm_bandwidth", "hbm_latency", "bench_membw.read[device]", ms,
        f"sizes={list(sizes)}")

    # 2. PCIe: pinned-host read sweep, only when a real host space exists
    if host_available():
        ms = _sweep_read("pinned_host", sizes, repeats)
        sweeps["pcie_bandwidth"] = ms
        fit("pcie_bandwidth", "pcie_latency",
            "bench_membw.read[pinned_host]", ms, f"sizes={list(sizes)}")

    # 3. ICI: one-hop ppermute sweep over a flat mesh
    if ndev >= 2:
        ms = _sweep_permute("x", (ndev,), ("x",), sizes, repeats)
        sweeps["ici_link_bandwidth"] = ms
        fit("ici_link_bandwidth", "ici_hop_latency",
            "bench_pingpong.ppermute", ms, f"devices={ndev}")

    # 4. DCN: ppermute over the 'pod' axis of the bench_collectives mesh
    if ndev >= 4:
        pod_mesh = (2, ndev // 2)
        ms = _sweep_permute("pod", pod_mesh, ("pod", "model"), sizes,
                            repeats)
        sweeps["dcn_bandwidth"] = ms
        fit("dcn_bandwidth", "dcn_latency", "bench_pingpong.ppermute[pod]",
            ms, f"mesh={pod_mesh}")

    calibrated = cal.apply(system)

    # Replay: every sweep point predicted under the *calibrated* bounds.
    bound_of = {
        "hbm_bandwidth": read_bound(MemoryTier.HBM, calibrated),
        "pcie_bandwidth": read_bound(MemoryTier.HOST, calibrated),
    }
    for term, ms in sweeps.items():
        if term in bound_of:
            b = bound_of[term]
            for m in ms:
                cal.replay.record(
                    term, m.name, b.time(m.nbytes), m.mean_s,
                    nbytes=int(m.nbytes), limiting_link=str(b.limiting_link),
                    source="calibrate",
                )
        else:
            link = Link.ICI if term == "ici_link_bandwidth" else Link.DCN
            lat = calibrated.link_latency(link)
            bw = calibrated.link_bandwidth(link)
            for m in ms:
                cal.replay.record(
                    term, m.name, lat + m.nbytes / bw, m.mean_s,
                    nbytes=int(m.nbytes), limiting_link=str(link),
                    source="calibrate",
                )

    # psum observations validate the ring-collective pricing end to end
    # (replay-only: they rewrite no constant).
    if include_collectives and ndev >= 2:
        axis_names = ("x",)
        mesh_shape = (ndev,)
        m = _measure_psum(mesh_shape, axis_names, "x", max(sizes), repeats)
        bw = collective_bound(ndev, Link.ICI, "all_reduce", calibrated)
        cal.replay.record(
            "all_reduce", m.name,
            calibrated.link_latency(Link.ICI) + m.nbytes / bw, m.mean_s,
            nbytes=int(m.nbytes), limiting_link=str(Link.ICI),
            source="calibrate",
        )

    return cal


def load_or_calibrate(
    path: str | pathlib.Path | None,
    *,
    activate: bool = False,
    system: SystemSpec | None = None,
    **kwargs,
) -> Calibration:
    """Load ``calibration.json`` if it exists, else calibrate and save.

    ``path=None`` always calibrates (nothing persisted).  With
    ``activate=True`` the calibrated system is installed process-wide via
    :func:`repro.core.hardware.set_active_system` — what the launchers'
    ``--calibration`` flag does.
    """
    if path is not None and pathlib.Path(path).exists():
        cal = Calibration.load(path)
    else:
        cal = calibrate(system, **kwargs)
        if path is not None:
            cal.save(path)
    if activate:
        set_active_system(cal.apply(system))
    return cal
