"""Three-term roofline from compiled artifacts + the hardware model.

Per (architecture x shape x mesh) this module derives, from the dry-run's
compiled module (``lowered.compile()``):

* ``compute_s``    = HLO_FLOPs(per chip)            / peak_FLOP/s
* ``memory_s``     = HLO_bytes(per chip)            / HBM_bw
* ``collective_s`` = Σ_link wire_bytes(per chip, link) / link_bw

(the task's formulas divide global quantities by ``chips x peak``; the HLO
analyzer operates on the SPMD-partitioned module so its quantities are
already per-chip — identical result, with the bonus that imbalanced
shardings would be visible).

The dominant term is the bottleneck; ``roofline_fraction`` is the score
(useful model FLOPs over what the hardware could do in the achievable time).
This is the paper's "achieved/theoretical" bound-fraction metric (Fig. 7)
lifted from single memory operations to whole training/serving steps.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

from repro.core.hardware import (
    Link,
    SystemSpec,
    get_active_system,
    link_for_axis,
)
from repro.core.hlo_analysis import HloCost, analyze_hlo_text


@dataclasses.dataclass
class RooflineReport:
    """The §Roofline record for one (arch x shape x mesh) cell."""

    arch: str
    shape: str
    mesh: str
    num_chips: int
    # three terms, seconds (per step, per chip — steps are synchronous)
    compute_s: float
    memory_s: float
    collective_s: float
    # provenance
    hlo_flops: float              # per-chip
    hlo_bytes: float              # per-chip
    collective_bytes: float       # per-chip wire bytes
    collective_by_link: dict[str, float]
    collective_by_axes: dict[str, float]
    model_flops: float            # analytic 6*N*D (global, per step)
    model_bytes: float            # bytes that MUST move per step (global)
    useful_ratio: float           # model_flops / (hlo_flops * num_chips)
    dominant: str
    bound_step_s: float           # max of the three terms
    roofline_fraction: float      # ideal compute time / bound_step_s
    bw_fraction: float            # ideal memory time / bound_step_s
    notes: str = ""

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "RooflineReport":
        return RooflineReport(**d)


def _dominant(compute_s: float, memory_s: float, collective_s: float) -> str:
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    return max(terms, key=terms.get)


def report_from_cost(
    cost: HloCost,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    num_chips: int,
    model_flops: float,
    model_bytes: float = 0.0,
    system: SystemSpec | None = None,
    notes: str = "",
) -> RooflineReport:
    """Build the roofline record from an :class:`HloCost`.

    ``roofline_fraction`` scores compute-bound steps (train/prefill);
    ``bw_fraction`` scores movement-bound steps (decode: the ideal time is
    streaming the must-read bytes — active params + cache — once at full
    HBM bandwidth, the paper's bound-fraction metric verbatim).
    """
    system = system if system is not None else get_active_system()
    chip = system.chip
    compute_s = cost.flops / chip.peak_bf16_flops
    memory_s = cost.hbm_bytes / chip.hbm_bandwidth

    by_link: dict[str, float] = {}
    by_axes: dict[str, float] = {}
    collective_s = 0.0
    for axes, nbytes in cost.wire_bytes_by_axis_group().items():
        link = Link.ICI
        for ax in axes:
            # link_for_axis warns on unregistered axes instead of the old
            # silent AXIS_LINK.get(ax, ICI) — which priced any unknown DCN
            # axis (e.g. donor_pod before it was registered) at ICI speed.
            if link_for_axis(ax) == Link.DCN:
                link = Link.DCN
                break
        key = str(link)
        by_link[key] = by_link.get(key, 0.0) + nbytes
        by_axes["+".join(axes) or "replica"] = (
            by_axes.get("+".join(axes) or "replica", 0.0) + nbytes
        )
    for key, nbytes in by_link.items():
        collective_s += nbytes / system.link_bandwidth(Link(key))

    # Useful-compute ratio: analytic model flops vs compiled flops summed
    # over chips.  >1 would flag missing compute; <1 flags remat/redundancy.
    total_hlo_flops = cost.flops * num_chips
    useful = model_flops / total_hlo_flops if total_hlo_flops else 0.0

    bound = max(compute_s, memory_s, collective_s)
    # the time the step would take if only useful compute ran at peak:
    ideal_s = model_flops / (num_chips * chip.peak_bf16_flops)
    frac = ideal_s / bound if bound > 0 else 0.0
    ideal_mem_s = model_bytes / (num_chips * chip.hbm_bandwidth)
    bw_frac = ideal_mem_s / bound if bound > 0 else 0.0

    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        num_chips=num_chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        hlo_flops=cost.flops,
        hlo_bytes=cost.hbm_bytes,
        collective_bytes=cost.collective_wire_bytes,
        collective_by_link=by_link,
        collective_by_axes=by_axes,
        model_flops=model_flops,
        model_bytes=model_bytes,
        useful_ratio=useful,
        dominant=_dominant(compute_s, memory_s, collective_s),
        bound_step_s=bound,
        roofline_fraction=frac,
        bw_fraction=bw_frac,
        notes=notes,
    )


def report_from_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    mesh_axes: Mapping[str, int],
    model_flops: float,
    model_bytes: float = 0.0,
    system: SystemSpec | None = None,
    notes: str = "",
) -> RooflineReport:
    """Roofline record straight from a ``jax.stages.Compiled``."""
    import math

    cost = analyze_hlo_text(compiled.as_text(), mesh_axes)
    num_chips = math.prod(mesh_axes.values())
    return report_from_cost(
        cost,
        arch=arch,
        shape=shape,
        mesh_name=mesh_name,
        num_chips=num_chips,
        model_flops=model_flops,
        model_bytes=model_bytes,
        system=system,
        notes=notes,
    )


# ---------------------------------------------------------------------------
# Formatting for EXPERIMENTS.md
# ---------------------------------------------------------------------------

_HDR = (
    "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
    "| dominant | useful | roofline frac | what would move it |"
)
_SEP = "|---" * 10 + "|"


def markdown_table(reports: list[RooflineReport]) -> str:
    rows = [_HDR, _SEP]
    for r in reports:
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} "
            f"| {r.compute_s*1e3:.2f} | {r.memory_s*1e3:.2f} "
            f"| {r.collective_s*1e3:.2f} | {r.dominant} "
            f"| {r.useful_ratio:.2f} | {r.roofline_fraction:.1%} "
            f"| {r.notes or '-'} |"
        )
    return "\n".join(rows)


def save_reports(reports: list[RooflineReport], path: str) -> None:
    with open(path, "w") as f:
        json.dump([r.to_json() for r in reports], f, indent=1)


def load_reports(path: str) -> list[RooflineReport]:
    with open(path) as f:
        return [RooflineReport.from_json(d) for d in json.load(f)]
