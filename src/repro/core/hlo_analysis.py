"""Optimized-HLO text analyzer: FLOPs, HBM bytes, collective wire bytes.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body once
(verified experimentally — a scan of L matmuls reports 1/L of the FLOPs), and
gives no collective breakdown at all.  The roofline methodology in the task
requires collective bytes parsed from the HLO text.  This module parses
``compiled.as_text()`` (post-SPMD-partitioning, so all quantities are
**per chip**) and produces:

* ``flops``          — dot/convolution FLOPs, with every ``while`` body
                       multiplied by its ``known_trip_count``;
* ``hbm_bytes``      — Σ (operand + output bytes) over *top-level* ops;
                       fusion internals are excluded (they live in
                       registers/VMEM on the target), which is the
                       TPU-meaningful HBM-traffic model;
* ``collectives``    — every collective op with payload bytes, wire bytes
                       (ring-algorithm factors), group size, and the mesh
                       axes it runs over (decoded from ``replica_groups``
                       iota patterns / source-target pairs).

The mesh-axis attribution implements the paper's key observation that the
*identity of the traversed interconnect* (ICI vs DCN here; NVLink vs GI vs
Slingshot there) — not the op type — determines the bound.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Iterable, Mapping, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Shape parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1,
    "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "token": 0, "opaque": 0,
}

#: optional layout suffix captured so memory-space annotations survive:
#: ``f32[8]{0:S(5)}`` places the buffer in XLA memory space 5 (host).
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{([^{}]*)\})?")

#: XLA's host memory space id in layout annotations (``S(5)``); the
#: default (device) space is 0 and is usually unannotated.
HOST_MEMORY_SPACE = 5

_SPACE_RE = re.compile(r"S\((\d+)\)")


@dataclasses.dataclass(frozen=True)
class Shape:
    dtype: str
    dims: tuple[int, ...]
    #: XLA memory space from the layout annotation (0 = device/default,
    #: 5 = host) — how the paper's host↔device transfers show up in the
    #: compiled text.
    space: int = 0

    @property
    def numel(self) -> int:
        return math.prod(self.dims) if self.dims else 1

    @property
    def nbytes(self) -> int:
        return self.numel * _DTYPE_BYTES.get(self.dtype, 4)

    @property
    def on_host(self) -> bool:
        return self.space == HOST_MEMORY_SPACE


def parse_shapes(type_str: str) -> list[Shape]:
    """Parse ``bf16[4,64,128]{2,1,0}`` or tuple ``(s32[], f32[2]{0})``,
    keeping any ``S(n)`` memory-space layout annotation."""
    shapes = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        ms = _SPACE_RE.search(m.group(3)) if m.group(3) else None
        shapes.append(Shape(m.group(1), dims, int(ms.group(1)) if ms else 0))
    return shapes


def total_bytes(type_str: str) -> int:
    return sum(s.nbytes for s in parse_shapes(type_str))


# ---------------------------------------------------------------------------
# Instruction / computation parsing
# ---------------------------------------------------------------------------

_INSTR_RE = re.compile(
    # type is either a (possibly /*index=N*/-annotated) tuple or a single
    # array type.  Tuple element layouts may themselves contain parens —
    # ``(f32[8]{0:S(5)}, f32[8]{0}, u32[])`` from an async host copy — so
    # allow one level of nesting inside the tuple.
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<type>\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<opcode>[\w\-]+)\("
)

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(")

_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

# attribute extractors
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?\})\}")
_WINDOW_SIZE_RE = re.compile(r"window=\{[^}]*size=([0-9x]+)")
_FEATURE_GROUP_RE = re.compile(r"feature_group_count=(\d+)")
_DIM_LABELS_RE = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)")

def _op_key(op_name: str) -> str:
    """Collapse a jax op_name path to its meaningful tail (last 2 parts,
    loop/transpose wrappers stripped)."""
    parts = [
        p for p in op_name.split("/")
        if p not in ("while", "body", "closed_call", "checkpoint",
                     "rematted_computation", "cond", "branch_0", "branch_1")
        and not p.startswith(("jit(", "jvp(", "transpose("))
    ]
    tail = "/".join(parts[-2:]) if parts else op_name
    grad = "transpose(" in op_name
    return ("bwd:" if grad else "") + tail


COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "ragged-all-to-all",
    "collective-broadcast",
)

# ops we never charge bytes for (metadata / aliasing / layout-only)
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "reshape", "broadcast", "partition-id",
    "replica-id", "rng-get-and-update-state", "custom-call",
}


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str  # raw text after the operand list
    raw_args: str = ""  # verbatim operand-paren contents (param numbers)

    @property
    def shapes(self) -> list[Shape]:
        return parse_shapes(self.type_str)

    @property
    def out_bytes(self) -> int:
        return total_bytes(self.type_str)


@dataclasses.dataclass
class Computation:
    name: str
    instructions: dict[str, Instruction]
    order: list[str]


def _split_operands(argstr: str) -> list[str]:
    """Operand names from the call-paren contents (constants → []).

    Handles both operand spellings XLA has used: bare names (``%x, %w``)
    and typed operands (``f32[8,8]{1,0} %x, ...``), whose shape brackets
    contain commas — so split only at bracket-depth zero and keep each
    token's trailing name.
    """
    out = []
    toks, depth, cur = [], 0, []
    for ch in argstr:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            toks.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    toks.append("".join(cur))
    for tok in toks:
        tok = tok.strip()
        if not tok:
            continue
        # typed operand: the name is the last whitespace-separated word
        tok = tok.split()[-1]
        if tok.startswith("%"):
            out.append(tok[1:])
        elif re.fullmatch(r"[\w.\-]+", tok) and not tok[0].isdigit():
            out.append(tok)
    return out


def parse_hlo(text: str) -> dict[str, Computation]:
    """Parse HLO text into computations keyed by name."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if (
            line
            and not line.startswith((" ", "\t"))
            and stripped.endswith("{")
            and "->" in stripped
        ):
            mc = _COMP_RE.match(line)
            if mc:
                cur = Computation(mc.group("name"), {}, [])
                comps[cur.name] = cur
                continue
        if stripped == "}" or stripped.startswith("})"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        # balanced-paren scan for the operand list
        start = mi.end()  # index just past the '('
        depth, i = 1, start
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        argstr = line[start : i - 1]
        attrs = line[i:]
        instr = Instruction(
            name=mi.group("name"),
            type_str=mi.group("type"),
            opcode=mi.group("opcode"),
            operands=_split_operands(argstr),
            attrs=attrs,
            raw_args=argstr,
        )
        cur.instructions[instr.name] = instr
        cur.order.append(instr.name)
    return comps


def find_entry(text: str, comps: Mapping[str, Computation]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: the computation that is not called by any other
    called: set[str] = set()
    for c in comps.values():
        for ins in c.instructions.values():
            for rx in (_CALLS_RE, _TO_APPLY_RE, _BODY_RE, _COND_RE):
                mm = rx.search(ins.attrs)
                if mm:
                    called.add(mm.group(1))
    for name in comps:
        if name not in called:
            return name
    raise ValueError("cannot determine entry computation")


# ---------------------------------------------------------------------------
# Replica-group decoding -> mesh-axis attribution
# ---------------------------------------------------------------------------

def decode_replica_groups(attrs: str) -> list[list[int]] | None:
    """Decode replica_groups into explicit device-id groups."""
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        devs = np.arange(math.prod(dims)).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            devs = devs.transpose(perm)
        return devs.reshape(g, s).tolist()
    m = _GROUPS_EXPL_RE.search(attrs)
    if m:
        groups = []
        for grp in re.finditer(r"\{([0-9,\s]*)\}", m.group(0)):
            ids = [int(x) for x in grp.group(1).split(",") if x.strip()]
            if ids:
                groups.append(ids)
        return groups or None
    return None


def group_axes(
    groups: Sequence[Sequence[int]], mesh_axes: Mapping[str, int]
) -> tuple[str, ...]:
    """Which mesh axes vary within a replica group.

    ``mesh_axes`` is ordered major→minor, e.g. {"pod":2,"data":16,"model":16}
    with device id = row-major rank.  This is how the analyzer knows whether
    a collective runs over ICI or DCN — the paper's link-identity question.
    """
    if not groups or not mesh_axes:
        return ()
    names = list(mesh_axes.keys())
    sizes = list(mesh_axes.values())
    strides = [0] * len(sizes)
    acc = 1
    for i in range(len(sizes) - 1, -1, -1):
        strides[i] = acc
        acc *= sizes[i]

    def coords(dev: int) -> tuple[int, ...]:
        return tuple((dev // strides[i]) % sizes[i] for i in range(len(sizes)))

    varying: set[str] = set()
    for grp in groups:
        base = coords(grp[0])
        for dev in grp[1:]:
            c = coords(dev)
            for i, (a, b) in enumerate(zip(base, c)):
                if a != b:
                    varying.add(names[i])
    return tuple(n for n in names if n in varying)


def decode_permute_pairs(attrs: str) -> list[tuple[int, int]]:
    m = _PAIRS_RE.search(attrs)
    if not m:
        return []
    return [
        (int(a), int(b))
        for a, b in re.findall(r"\{(\d+),\s*(\d+)\}", m.group(0))
    ]


# ---------------------------------------------------------------------------
# Cost walking
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TransferStat:
    """One ``copy``/``copy-start`` in the compiled module — the raw data-
    movement fact the auditor diffs against the planner's byte plan.

    An async pair is recorded once, at its ``copy-start``; the matching
    ``copy-done`` is a handle resolution that moves no bytes.
    """

    opcode: str               # "copy" or "copy-start"
    name: str                 # HLO instruction name
    nbytes: float             # bytes moved, x trip count
    src_space: int            # XLA memory space of the source buffer
    dst_space: int            # XLA memory space of the destination
    count: float              # dynamic execution count (x trip counts)
    op_name: str = ""         # jax op_name tail (attribution)

    @property
    def crosses_host(self) -> bool:
        """True when exactly one endpoint is in host memory — the
        host↔device PCIe/C2C traffic the paper's Fig. 17 datapath budgets
        per token."""
        return (self.src_space == HOST_MEMORY_SPACE) != (
            self.dst_space == HOST_MEMORY_SPACE
        )


@dataclasses.dataclass
class CollectiveStat:
    opcode: str
    payload_bytes: float      # per-chip HLO payload, x trip count
    wire_bytes: float         # per-chip ring wire bytes, x trip count
    group_size: int
    axes: tuple[str, ...]
    count: float              # dynamic execution count (x trip counts)
    name: str = ""
    op_name: str = ""         # jax op_name tail (attribution)


@dataclasses.dataclass
class HloCost:
    """Per-chip cost summary of a compiled (partitioned) module."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: list[CollectiveStat] = dataclasses.field(default_factory=list)
    #: every copy/copy-start, with source/destination memory spaces — the
    #: input to the transfer audit
    transfers: list[TransferStat] = dataclasses.field(default_factory=list)
    instruction_count: float = 0.0
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    #: bytes attributed to the originating op_name prefix (profile for the
    #: §Perf hypothesis loop: 'where do the HBM bytes come from?')
    bytes_by_op: dict = dataclasses.field(default_factory=dict)

    @property
    def collective_wire_bytes(self) -> float:
        return sum(c.wire_bytes for c in self.collectives)

    def wire_bytes_by_axis_group(self) -> dict[tuple[str, ...], float]:
        out: dict[tuple[str, ...], float] = defaultdict(float)
        for c in self.collectives:
            out[c.axes] += c.wire_bytes
        return dict(out)

    def wire_bytes_over(self, axis: str) -> float:
        return sum(c.wire_bytes for c in self.collectives if axis in c.axes)

    @property
    def host_transfer_bytes(self) -> float:
        """Total bytes crossing the host↔device boundary."""
        return sum(t.nbytes for t in self.transfers if t.crosses_host)


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    out = ins.shapes[0]
    m = _LHS_CONTRACT_RE.search(ins.attrs)
    if not m or not ins.operands:
        return 2.0 * out.numel  # degenerate
    lhs = comp.instructions.get(ins.operands[0])
    if lhs is None:
        return 2.0 * out.numel
    lhs_shape = lhs.shapes[0]
    k = 1
    for d in (int(x) for x in m.group(1).split(",") if x):
        if d < len(lhs_shape.dims):
            k *= lhs_shape.dims[d]
    return 2.0 * out.numel * k


def _conv_flops(ins: Instruction, comp: Computation) -> float:
    out = ins.shapes[0]
    if len(ins.operands) < 2:
        return 2.0 * out.numel
    rhs = comp.instructions.get(ins.operands[1])
    lhs = comp.instructions.get(ins.operands[0])
    if rhs is None or lhs is None:
        return 2.0 * out.numel
    kshape = rhs.shapes[0]
    fg = 1
    m = _FEATURE_GROUP_RE.search(ins.attrs)
    if m:
        fg = int(m.group(1))
    # kernel numel = prod(spatial) * in_features/groups * out_features
    # flops = 2 * out_numel * prod(spatial) * in_features/groups
    #       = 2 * out_numel * kernel_numel / out_features
    dl = _DIM_LABELS_RE.search(ins.attrs)
    out_features = 1
    if dl:
        # rhs labels like "io01" / output labels like "bf01": find 'o' index
        rhs_labels = dl.group(2)
        if "o" in rhs_labels:
            out_features = kshape.dims[rhs_labels.index("o")]
    return 2.0 * out.numel * kshape.numel / max(out_features, 1)


class HloAnalyzer:
    """Walks a parsed module accumulating :class:`HloCost`."""

    def __init__(
        self,
        text: str,
        mesh_axes: Mapping[str, int] | None = None,
        default_trip_count: int = 1,
    ):
        self.text = text
        self.comps = parse_hlo(text)
        self.entry = find_entry(text, self.comps)
        self.mesh_axes = dict(mesh_axes or {})
        self.default_trip_count = default_trip_count

    # -- trip counts --------------------------------------------------------
    def _trip_count(self, ins: Instruction) -> int:
        m = _TRIP_RE.search(ins.attrs)
        if m:
            return int(m.group(1))
        # fallback: largest s32 constant in the condition computation
        mc = _COND_RE.search(ins.attrs)
        if mc and mc.group(1) in self.comps:
            consts = [
                int(x)
                for x in re.findall(
                    r"s32\[\]\s+constant\((\d+)\)",
                    "\n".join(
                        i.type_str + " constant" + i.attrs
                        for i in self.comps[mc.group(1)].instructions.values()
                        if i.opcode == "constant"
                    ),
                )
            ]
            # re-scan raw text of the condition computation
        mcond = _COND_RE.search(ins.attrs)
        if mcond:
            cname = mcond.group(1)
            pat = re.compile(
                re.escape(cname) + r".*?\{(.*?)\n\}", re.DOTALL
            )
            mm = pat.search(self.text)
            if mm:
                consts = [int(x) for x in re.findall(r"constant\((\d+)\)", mm.group(1))]
                if consts:
                    return max(consts)
        return self.default_trip_count

    # -- main walk ----------------------------------------------------------
    def analyze(self) -> HloCost:
        cost = HloCost()
        self._walk(self.entry, 1.0, cost, charge_bytes=True)
        return cost

    def _walk(
        self, comp_name: str, mult: float, cost: HloCost, charge_bytes: bool
    ) -> None:
        comp = self.comps.get(comp_name)
        if comp is None:
            return
        for name in comp.order:
            ins = comp.instructions[name]
            op = ins.opcode
            cost.instruction_count += mult

            if op == "dot":
                f = _dot_flops(ins, comp) * mult
                cost.flops += f
                cost.dot_flops += f
            elif op == "convolution":
                f = _conv_flops(ins, comp) * mult
                cost.flops += f
                cost.conv_flops += f

            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVE_OPS and not op.endswith("-done"):
                self._collective(ins, comp, mult, cost)

            if charge_bytes and op in ("copy", "copy-start"):
                self._transfer(ins, comp, mult, cost)

            if op == "while":
                trip = self._trip_count(ins)
                body = _BODY_RE.search(ins.attrs)
                cond = _COND_RE.search(ins.attrs)
                if body:
                    self._walk(body.group(1), mult * trip, cost, charge_bytes)
                if cond:
                    self._walk(cond.group(1), mult * trip, cost, charge_bytes=False)
                continue
            if op in ("call", "async-start"):
                mcalls = _TO_APPLY_RE.search(ins.attrs) or _CALLS_RE.search(ins.attrs)
                if mcalls:
                    self._walk(mcalls.group(1), mult, cost, charge_bytes)
                continue
            if op == "conditional":
                mb = _BRANCHES_RE.search(ins.attrs)
                if mb:
                    # charge the max branch? charge all branches / nbranches
                    branches = [
                        b.strip().lstrip("%")
                        for b in mb.group(1).split(",")
                        if b.strip()
                    ]
                    for b in branches:
                        self._walk(b, mult / max(len(branches), 1), cost, charge_bytes)
                if charge_bytes:
                    cost.hbm_bytes += ins.out_bytes * mult
                continue
            if op == "fusion":
                mcalls = _CALLS_RE.search(ins.attrs)
                if mcalls:
                    # FLOPs-only recursion: internals stay on-chip.
                    self._walk_flops_only(mcalls.group(1), mult, cost)

            if charge_bytes and op not in _SKIP_BYTES:
                nbytes = self._effective_bytes(ins, comp)
                cost.hbm_bytes += nbytes * mult
                mo = re.search(r'op_name="([^"]+)"', ins.attrs)
                key = _op_key(mo.group(1)) if mo else op
                cost.bytes_by_op[key] = (
                    cost.bytes_by_op.get(key, 0.0) + nbytes * mult
                )

    def _walk_flops_only(self, comp_name: str, mult: float, cost: HloCost) -> None:
        comp = self.comps.get(comp_name)
        if comp is None:
            return
        for name in comp.order:
            ins = comp.instructions[name]
            if ins.opcode == "dot":
                f = _dot_flops(ins, comp) * mult
                cost.flops += f
                cost.dot_flops += f
            elif ins.opcode == "convolution":
                f = _conv_flops(ins, comp) * mult
                cost.flops += f
                cost.conv_flops += f
            elif ins.opcode == "fusion":
                mcalls = _CALLS_RE.search(ins.attrs)
                if mcalls:
                    self._walk_flops_only(mcalls.group(1), mult, cost)

    # -- effective HBM traffic model -----------------------------------------
    def _effective_bytes(self, ins: Instruction, comp: Computation) -> float:
        """Bytes an op actually moves through HBM.

        Refinements over naive Σ(operand+output) — each one removes a class
        of phantom traffic the naive model invents (validated against the
        deepseek decode cell, where slicing the scan-stacked KV cache was
        naively charged as 60 full-cache reads, 240 GB/device of fiction):

        * dynamic-slice / slice read only the slice;
        * dynamic-update-slice / scatter write the update in place
          (XLA aliases the buffer inside loops);
        * gather reads ~the gathered bytes (embedding-lookup semantics);
        * a fusion whose parameter is consumed ONLY by slicing ops inside
          is charged those slices, not the whole operand; a fusion rooted
          in dynamic-update-slice is charged the update, not the buffer.
        """
        op = ins.opcode
        if op == "copy-done":
            # resolves the async handle; the bytes were charged at the
            # matching copy-start (double-count fix)
            return 0.0
        if op == "copy-start":
            # output tuple is (dest, src, context): one read + one write
            # of the payload, not 3x (tuple + operand) as the naive model
            # would charge
            shapes = ins.shapes
            return 2.0 * shapes[0].nbytes if shapes else 0.0
        if op in ("dynamic-slice", "slice"):
            return float(ins.out_bytes)  # reads ~output bytes
        if op in ("dynamic-update-slice", "scatter", "scatter-add"):
            # update operand(s) + indices; destination aliased in place
            nbytes = 0.0
            for opr in ins.operands[1:]:
                src = comp.instructions.get(opr)
                if src is not None:
                    nbytes += src.out_bytes
            return 2.0 * max(nbytes, 1.0)  # read-modify-write of the slice
        if op == "gather":
            idx = 0.0
            if len(ins.operands) > 1:
                src = comp.instructions.get(ins.operands[1])
                idx = src.out_bytes if src is not None else 0.0
            return float(ins.out_bytes) + idx

        if op == "fusion":
            mcalls = _CALLS_RE.search(ins.attrs)
            called = self.comps.get(mcalls.group(1)) if mcalls else None
            nbytes = float(ins.out_bytes)
            if called is not None:
                # in-place update fusion: the output buffer aliases an
                # operand (same size) and the computation contains a DUS —
                # charge the updated slice, not the whole buffer.
                dus = [
                    i for i in called.instructions.values()
                    if i.opcode == "dynamic-update-slice"
                ]
                operand_sizes = set()
                for opr in ins.operands:
                    src = comp.instructions.get(opr)
                    if src is not None:
                        operand_sizes.add(src.out_bytes)
                if dus and ins.out_bytes in operand_sizes:
                    upd_bytes = 0.0
                    for root in dus:
                        upd = called.instructions.get(
                            root.operands[1] if len(root.operands) > 1 else ""
                        )
                        if upd is not None:
                            upd_bytes += upd.out_bytes
                    if upd_bytes:
                        nbytes = 2.0 * upd_bytes
                # params consumed only by slicing: charge the slices
                params = {
                    i.name: i for i in called.instructions.values()
                    if i.opcode == "parameter"
                }
                uses: dict[str, list[Instruction]] = {p: [] for p in params}
                for i in called.instructions.values():
                    for opr in i.operands:
                        if opr in uses:
                            uses[opr].append(i)
                # param slot -> name via the parameter(N) argument
                slot_to_name: dict[int, str] = {}
                for pname, p in params.items():
                    try:
                        slot_to_name[int(p.raw_args.strip())] = pname
                    except ValueError:
                        pass
                skipped_alias = False
                for slot, opr in enumerate(ins.operands):
                    src = comp.instructions.get(opr)
                    if src is None or src.opcode == "tuple":
                        continue
                    if (
                        dus
                        and not skipped_alias
                        and src.out_bytes == ins.out_bytes
                    ):
                        skipped_alias = True   # in-place buffer: no read
                        continue
                    pname = slot_to_name.get(slot)
                    consumed = uses.get(pname, None) if pname else None
                    if consumed and all(
                        u.opcode in ("dynamic-slice", "slice", "gather")
                        and u.operands and u.operands[0] == pname
                        for u in consumed
                    ):
                        nbytes += sum(u.out_bytes for u in consumed)
                    else:
                        nbytes += src.out_bytes
                return nbytes
            for opr in ins.operands:
                src = comp.instructions.get(opr)
                if src is not None and src.opcode != "tuple":
                    nbytes += src.out_bytes
            return nbytes

        nbytes = float(ins.out_bytes)
        for opr in ins.operands:
            src = comp.instructions.get(opr)
            if src is not None and src.opcode not in ("tuple",):
                nbytes += src.out_bytes
        return nbytes

    def _transfer(
        self, ins: Instruction, comp: Computation, mult: float, cost: HloCost
    ) -> None:
        """Record a copy/copy-start with source/destination memory spaces."""
        mo = re.search(r'op_name="([^"]+)"', ins.attrs)
        op_name = _op_key(mo.group(1)) if mo else ""
        shapes = ins.shapes
        if ins.opcode == "copy-start":
            # tuple type is (dest, src, context) — both spaces are right
            # there in the layout annotations
            dst = shapes[0] if shapes else None
            src = shapes[1] if len(shapes) > 1 else None
        else:
            dst = shapes[0] if shapes else None
            src = None
            if ins.operands:
                opnd = comp.instructions.get(ins.operands[0])
                if opnd is not None and opnd.shapes:
                    src = opnd.shapes[0]
        if src is None:
            src = dst
        nbytes = float(dst.nbytes) if dst is not None else 0.0
        cost.transfers.append(
            TransferStat(
                opcode=ins.opcode,
                name=ins.name,
                nbytes=nbytes * mult,
                src_space=src.space if src is not None else 0,
                dst_space=dst.space if dst is not None else 0,
                count=mult,
                op_name=op_name,
            )
        )

    def _collective(
        self, ins: Instruction, comp: Computation, mult: float, cost: HloCost
    ) -> None:
        from repro.core.datapath import wire_bytes as _wire

        op = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
        mo = re.search(r'op_name="([^"]+)"', ins.attrs)
        op_name = _op_key(mo.group(1)) if mo else ""
        if op == "collective-permute":
            pairs = decode_permute_pairs(ins.attrs)
            payload = float(ins.out_bytes)
            axes = ()
            if pairs and self.mesh_axes:
                axes = group_axes([[a, b] for a, b in pairs], self.mesh_axes)
            cost.collectives.append(
                CollectiveStat(
                    opcode=op,
                    payload_bytes=payload * mult,
                    wire_bytes=payload * mult,
                    group_size=2,
                    axes=axes,
                    count=mult,
                    name=ins.name,
                    op_name=op_name,
                )
            )
            return

        groups = decode_replica_groups(ins.attrs)
        gsize = len(groups[0]) if groups else 1
        axes = group_axes(groups, self.mesh_axes) if groups else ()
        # payload: operand bytes for reduce-type, output bytes for gather-type
        if op in ("all-gather", "collective-broadcast"):
            payload = float(ins.out_bytes)
        else:
            payload = 0.0
            for opr in ins.operands:
                src = comp.instructions.get(opr)
                if src is not None:
                    payload += src.out_bytes
            if payload == 0.0:
                payload = float(ins.out_bytes)
        kind = "all-gather" if op == "collective-broadcast" else op
        wb = _wire(kind, payload, gsize)
        cost.collectives.append(
            CollectiveStat(
                opcode=op,
                payload_bytes=payload * mult,
                wire_bytes=wb * mult,
                group_size=gsize,
                axes=axes,
                count=mult,
                name=ins.name,
                op_name=op_name,
            )
        )


def analyze_hlo_text(
    text: str,
    mesh_axes: Mapping[str, int] | None = None,
    default_trip_count: int = 1,
) -> HloCost:
    """Convenience wrapper: parse + walk."""
    return HloAnalyzer(text, mesh_axes, default_trip_count).analyze()


# ---------------------------------------------------------------------------
# Donation (input/output aliasing) + entry-parameter extraction
# ---------------------------------------------------------------------------

#: one alias entry: ``{out_idx}: (param_num, {param_idx}, may-alias)`` —
#: the param-index tuple and the kind are both optional in XLA's printer
_ALIAS_ENTRY_RE = re.compile(
    r"\{([0-9,\s]*)\}:\s*\((\d+)(?:,\s*\{([0-9,\s]*)\})?(?:,\s*([a-z\-]+))?\)"
)


@dataclasses.dataclass(frozen=True)
class AliasPair:
    """One materialized donation: output tuple index ← parameter buffer."""

    output_index: tuple[int, ...]
    param_number: int
    param_index: tuple[int, ...] = ()
    kind: str = "may-alias"


def _idx_tuple(s: str | None) -> tuple[int, ...]:
    return tuple(int(x) for x in (s or "").replace(",", " ").split())


def parse_input_output_alias(text: str) -> list[AliasPair]:
    """Donation pairs from the ``input_output_alias={...}`` module header.

    Presence of a pair here is the ground truth that ``donate_argnums``
    actually materialized: a donated-but-unaliased buffer costs a silent
    full-size device copy per dispatch, which is exactly the failure the
    build-time Executor check and :mod:`repro.analysis.hlo_audit` exist to
    surface.  Returns ``[]`` when the module has no alias header.
    """
    marker = "input_output_alias={"
    start = text.find(marker)
    if start < 0:
        return []
    i = start + len(marker) - 1  # at the opening '{'
    depth, j = 0, i
    while j < len(text):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                break
        j += 1
    body = text[i + 1 : j]
    return [
        AliasPair(
            output_index=_idx_tuple(m.group(1)),
            param_number=int(m.group(2)),
            param_index=_idx_tuple(m.group(3)),
            kind=m.group(4) or "may-alias",
        )
        for m in _ALIAS_ENTRY_RE.finditer(body)
    ]


@dataclasses.dataclass(frozen=True)
class EntryParameter:
    """One entry-computation parameter with its jax arg-path label.

    ``op_name`` is the flattened jax argument path (``caches[0]``,
    ``state[\'tokens\']`` — quote escapes undone), which is how the auditor
    maps HLO parameter numbers back to planner roles even after XLA prunes
    unused arguments (numbering is the flat order of surviving leaves).
    """

    number: int
    shapes: tuple[Shape, ...]
    op_name: str = ""

    @property
    def arg_root(self) -> str:
        """Leading identifier of the arg path (``caches[0]`` → ``caches``)."""
        return re.split(r"[\[.]", self.op_name, maxsplit=1)[0]

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self.shapes)


def entry_parameters(
    text: str, comps: Mapping[str, Computation] | None = None
) -> list[EntryParameter]:
    """Entry-computation parameters sorted by parameter number."""
    comps = comps if comps is not None else parse_hlo(text)
    entry = find_entry(text, comps)
    out: list[EntryParameter] = []
    for ins in comps[entry].instructions.values():
        if ins.opcode != "parameter":
            continue
        try:
            num = int(ins.raw_args.strip())
        except ValueError:
            continue
        mo = re.search(r'op_name="([^"]+)"', ins.attrs)
        op_name = mo.group(1).replace("\\'", "'") if mo else ""
        out.append(EntryParameter(num, tuple(ins.shapes), op_name))
    out.sort(key=lambda p: p.number)
    return out
