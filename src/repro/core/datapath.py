"""Datapath model: theoretical bandwidth/latency bounds per memory operation.

This is the paper's central analytical device (Fig. 3): for an operation
that moves bytes between physical memories, enumerate the interconnect
segments the data traverses; the bound is the bandwidth of the *slowest*
segment, and any segment traversed **twice** by the same operation (e.g.
a copy whose source and destination both sit behind the same link)
contributes at **half** its bandwidth.

The paper instantiates this for {Grace, Hopper} x {DDR, HBM, peer variants};
here we instantiate it for a TPU chip against the tiers of
:class:`repro.core.hardware.MemoryTier`.  The same object also powers the
placement planner (predicting per-step time of a placement policy) and the
analytic mode of every microbenchmark.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Iterable, Sequence

from repro.core.hardware import (
    Link,
    MemoryTier,
    SystemSpec,
    get_active_system,
)

# ---------------------------------------------------------------------------
# Datapaths: tier -> sequence of links between the compute unit and the tier.
# A read of tier T traverses path(T) once; a copy src->dst traverses
# path(src) + path(dst), and shared links count twice (paper Fig. 3).
# ---------------------------------------------------------------------------

_PATHS: dict[MemoryTier, tuple[Link, ...]] = {
    MemoryTier.VMEM: (Link.VMEM_BUS,),
    MemoryTier.HBM: (Link.HBM_BUS,),
    MemoryTier.HOST: (Link.PCIE,),
    MemoryTier.PEER_HBM: (Link.ICI, Link.HBM_BUS),
    MemoryTier.PEER_HOST: (Link.ICI, Link.PCIE),
    MemoryTier.REMOTE_HBM: (Link.DCN, Link.HBM_BUS),
}


def path(tier: MemoryTier) -> tuple[Link, ...]:
    """Links between this chip's compute units and ``tier``."""
    return _PATHS[tier]


@dataclasses.dataclass(frozen=True)
class Bound:
    """A datapath bound: bandwidth + the link that limits it.

    ``fraction(measured)`` is the paper's headline metric — achieved
    bandwidth over the datapath bound, localizing inefficiency to
    ``limiting_link`` rather than to "the machine".
    """

    bandwidth: float                 # bytes/s
    limiting_link: Link
    latency: float                   # seconds, sum of segment latencies
    traversals: tuple[tuple[Link, int], ...]  # (link, times traversed)

    def fraction(self, measured_bandwidth: float) -> float:
        return measured_bandwidth / self.bandwidth

    def time(self, nbytes: float) -> float:
        """Predicted time to move ``nbytes`` through this datapath."""
        return self.latency + nbytes / self.bandwidth


def _bound_from_traversals(
    traversals: Counter[Link], system: SystemSpec | None
) -> Bound:
    """min over links of bw/traversals — the twice-traversed-halves rule."""
    system = system if system is not None else get_active_system()
    if not traversals:
        raise ValueError("empty datapath")
    best_bw = float("inf")
    limiting = None
    latency = 0.0
    for link, count in traversals.items():
        eff = system.link_bandwidth(link) / count
        latency += system.link_latency(link) * count
        if eff < best_bw:
            best_bw = eff
            limiting = link
    return Bound(
        bandwidth=best_bw,
        limiting_link=limiting,
        latency=latency,
        traversals=tuple(sorted(traversals.items())),
    )


def read_bound(
    tier: MemoryTier, system: SystemSpec | None = None
) -> Bound:
    """Bound for this chip reading from ``tier`` (paper Fig. 3, left)."""
    return _bound_from_traversals(Counter(path(tier)), system)


def write_bound(
    tier: MemoryTier, system: SystemSpec | None = None
) -> Bound:
    """Bound for this chip writing to ``tier``.

    Symmetric with reads in this model; the *measured* asymmetry the paper
    reports (write < read on some paths) is an efficiency effect, which is
    exactly why bounds and measurements are kept separate.
    """
    return _bound_from_traversals(Counter(path(tier)), system)


def copy_bound(
    src: MemoryTier,
    dst: MemoryTier,
    system: SystemSpec | None = None,
) -> Bound:
    """Bound for a chip-driven copy ``src -> dst``.

    Each link on the source path and on the destination path is traversed
    once; links appearing on both are traversed twice and contribute at
    half bandwidth (paper: DDR->DDR over C2C is bounded at 250 GB/s, half
    of the 450 GB/s C2C link; TPU: HOST->HOST over one PCIe link halves,
    HBM->HBM through the chip halves the HBM bus).
    """
    traversals: Counter[Link] = Counter(path(src))
    traversals.update(path(dst))
    return _bound_from_traversals(traversals, system)


def collective_bound(
    axis_size: int,
    axis_link: Link,
    kind: str,
    system: SystemSpec | None = None,
) -> float:
    """Per-chip algorithmic bandwidth bound of a ring collective.

    Returns effective bytes/s *of payload* per chip: a ring all-reduce of
    B bytes moves ``2*(N-1)/N * B`` bytes over the chip's slowest on-path
    link, etc.  Used by bench_collectives and the roofline collective term.
    """
    system = system if system is not None else get_active_system()
    link_bw = system.link_bandwidth(axis_link)
    n = axis_size
    if n <= 1:
        return float("inf")
    factor = {
        "all_reduce": 2.0 * (n - 1) / n,
        "all_gather": (n - 1) / n,
        "reduce_scatter": (n - 1) / n,
        "all_to_all": (n - 1) / n,
        "collective_permute": 1.0,
    }[kind]
    return link_bw / factor


# ---------------------------------------------------------------------------
# Wire-byte factors used by the roofline HLO analyzer (ring algorithms).
# ---------------------------------------------------------------------------

def wire_bytes(kind: str, payload_bytes: float, group_size: int) -> float:
    """Bytes a single chip puts on the wire for one collective.

    ``payload_bytes`` is the per-chip shard size as it appears in HLO
    (operand size for reduce-scatter/all-reduce, output size for
    all-gather).  Ring-algorithm accounting, matching ``collective_bound``.
    """
    n = max(group_size, 1)
    if n == 1:
        return 0.0
    factor = {
        "all-reduce": 2.0 * (n - 1) / n,
        "all-gather": (n - 1) / n,
        "reduce-scatter": (n - 1) / n,
        "all-to-all": (n - 1) / n,
        "collective-permute": 1.0,
        "ragged-all-to-all": (n - 1) / n,
    }[kind]
    return payload_bytes * factor


def bound_matrix(
    op: str,
    tiers: Sequence[MemoryTier] | None = None,
    system: SystemSpec | None = None,
) -> dict[str, dict[str, float]]:
    """Paper-Fig.-3-style matrix of GB/s bounds.

    ``op`` is 'read', 'write' (vector keyed by tier) or 'copy' (full
    src x dst matrix).
    """
    tiers = list(tiers or [t for t in MemoryTier if t != MemoryTier.VMEM])
    out: dict[str, dict[str, float]] = {}
    if op in ("read", "write"):
        fn = read_bound if op == "read" else write_bound
        out[op] = {str(t): fn(t, system).bandwidth / 1e9 for t in tiers}
        return out
    if op == "copy":
        for src in tiers:
            out[str(src)] = {
                str(dst): copy_bound(src, dst, system).bandwidth / 1e9
                for dst in tiers
            }
        return out
    raise ValueError(f"unknown op {op!r}")


def streaming_time(
    nbytes: float,
    tier: MemoryTier,
    system: SystemSpec | None = None,
    *,
    touches: int = 1,
) -> float:
    """Time for a compute step that touches ``nbytes`` living in ``tier``.

    ``touches`` models re-reads within the step (the paper's Fig. 4 axis:
    repeated device-side touches amortize migration).  Resident-vs-streamed
    policy comparison (Table II analogue):

    * resident in HBM: ``touches * nbytes / hbm_bw``
    * streamed from ``tier``: pay the tier path once per touch.
    """
    b = read_bound(tier, system)
    return touches * (nbytes / b.bandwidth) + b.latency


def migration_crossover_touches(
    tier: MemoryTier, system: SystemSpec | None = None
) -> float:
    """Touches after which migrate-to-HBM beats streaming from ``tier``.

    Closed form of the paper's Fig. 4 experiment: migration costs one copy
    ``tier -> HBM`` plus ``touches`` HBM reads; streaming costs ``touches``
    reads over the tier path.  Returns the break-even touch count.
    """
    system = system if system is not None else get_active_system()
    hbm = system.link_bandwidth(Link.HBM_BUS)
    tier_bw = read_bound(tier, system).bandwidth
    cp = copy_bound(tier, MemoryTier.HBM, system).bandwidth
    if tier_bw >= hbm:
        return float("inf")
    # t/tier_bw >= 1/cp + t/hbm  =>  t >= (1/cp) / (1/tier_bw - 1/hbm)
    return (1.0 / cp) / (1.0 / tier_bw - 1.0 / hbm)
