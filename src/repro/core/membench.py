"""Measurement infrastructure mirroring the paper's methodology (§III-B).

The paper's rules, kept verbatim where they transfer:

* a *kernel* is the measured function, excluding sync + measurement code;
* every number is the average of ``repeats`` measurements after discarding
  ``warmup`` runs;
* for multi-worker tests, total time = max over workers of their final
  timestamp minus the common start timestamp (we get this for free from
  ``block_until_ready`` on a sharded computation — the slowest shard gates).

On this CPU container the timer is ``time.perf_counter`` (the cntvct_el0 /
%globaltimer discussion in the paper becomes moot under a JIT runtime; the
dispatch-overhead measurement below plays the role of the paper's clock-
resolution measurement).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Sequence

import jax


@dataclasses.dataclass
class Measurement:
    name: str
    mean_s: float
    min_s: float
    max_s: float
    std_s: float
    repeats: int
    nbytes: float = 0.0
    flops: float = 0.0

    @property
    def bandwidth(self) -> float:
        """bytes/s, using the mean (paper reports averages)."""
        return self.nbytes / self.mean_s if self.mean_s else 0.0

    @property
    def gbps(self) -> float:
        return self.bandwidth / 1e9

    @property
    def tflops(self) -> float:
        return self.flops / self.mean_s / 1e12 if self.mean_s else 0.0

    @property
    def us_per_call(self) -> float:
        return self.mean_s * 1e6

    def csv(self, derived: str | None = None) -> str:
        d = derived
        if d is None:
            d = f"{self.gbps:.2f}GB/s" if self.nbytes else f"{self.tflops:.2f}TF/s"
        return f"{self.name},{self.us_per_call:.2f},{d}"


def measure(
    fn: Callable[[], Any],
    *,
    name: str = "",
    warmup: int = 1,
    repeats: int = 10,
    nbytes: float = 0.0,
    flops: float = 0.0,
) -> Measurement:
    """Time ``fn`` with the paper's warmup-then-average protocol.

    ``fn`` must return a jax array (or pytree); we block on it so the
    measured interval covers the full data movement, not dispatch.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return Measurement(
        name=name or getattr(fn, "__name__", "kernel"),
        mean_s=statistics.fmean(times),
        min_s=min(times),
        max_s=max(times),
        std_s=statistics.pstdev(times) if len(times) > 1 else 0.0,
        repeats=repeats,
        nbytes=nbytes,
        flops=flops,
    )


def dispatch_overhead(repeats: int = 50) -> float:
    """Seconds of fixed overhead per dispatched no-op (timer-resolution
    analogue of the paper's 32 ns clock-read experiment)."""
    import jax.numpy as jnp

    x = jnp.zeros((1,))
    f = jax.jit(lambda v: v + 0)
    jax.block_until_ready(f(x))
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = f(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def linear_fit(
    measurements: Sequence[Measurement],
) -> tuple[float, float]:
    """Least-squares fit ``t = latency + nbytes / bandwidth`` over a size
    sweep; returns ``(latency_s, bandwidth_Bps)``.

    This is how calibration separates the two terms a single measurement
    conflates (paper Figs. 11-13 vs 7-8: small buffers expose latency,
    large buffers expose bandwidth).  Degenerate sweeps (single size, or a
    non-positive slope from noisy timings) fall back to the largest-size
    measurement's effective bandwidth with zero latency.
    """
    pts = [(float(m.nbytes), m.mean_s) for m in measurements if m.nbytes]
    if not pts:
        raise ValueError("linear_fit needs measurements with nbytes set")
    big = max(measurements, key=lambda m: m.nbytes)
    if len(pts) < 2:
        return 0.0, big.bandwidth
    n = len(pts)
    mean_x = sum(x for x, _ in pts) / n
    mean_y = sum(y for _, y in pts) / n
    sxx = sum((x - mean_x) ** 2 for x, _ in pts)
    sxy = sum((x - mean_x) * (y - mean_y) for x, y in pts)
    if sxx <= 0.0 or sxy <= 0.0:
        return 0.0, big.bandwidth
    slope = sxy / sxx                       # s per byte
    intercept = mean_y - slope * mean_x     # s
    return max(intercept, 0.0), 1.0 / slope


def sweep(
    fn_of_size: Callable[[int], Callable[[], Any]],
    sizes: Sequence[int],
    *,
    name: str,
    warmup: int = 1,
    repeats: int = 10,
    bytes_of_size: Callable[[int], float] | None = None,
) -> list[Measurement]:
    """Buffer-size sweep (the x-axis of paper Figs. 8, 10, 12, 14, 18-19)."""
    out = []
    for size in sizes:
        fn = fn_of_size(size)
        out.append(
            measure(
                fn,
                name=f"{name}[{size}]",
                warmup=warmup,
                repeats=repeats,
                nbytes=bytes_of_size(size) if bytes_of_size else float(size),
            )
        )
    return out
