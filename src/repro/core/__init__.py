"""The paper's contribution as a library: datapath-aware placement.

Layout:

* :mod:`repro.core.hardware`     — TPU chip/pod/system model (constants).
* :mod:`repro.core.datapath`     — per-operation theoretical bounds (Fig. 3).
* :mod:`repro.core.placement`    — per-role memory placement policies.
* :mod:`repro.core.planner`      — policy selection from predicted step time.
* :mod:`repro.core.hlo_analysis` — compiled-HLO cost extraction.
* :mod:`repro.core.roofline`     — 3-term roofline reports.
* :mod:`repro.core.membench`     — paper-methodology measurement infra.
"""

from repro.core.hardware import (  # noqa: F401
    AXIS_LINK,
    CALIBRATED_TERMS,
    ChipSpec,
    Link,
    MemoryTier,
    PodSpec,
    SystemSpec,
    axis_bandwidth,
    get_active_system,
    link_for_axis,
    set_active_system,
)
from repro.core.datapath import (  # noqa: F401
    Bound,
    bound_matrix,
    collective_bound,
    copy_bound,
    migration_crossover_touches,
    read_bound,
    streaming_time,
    wire_bytes,
    write_bound,
)
from repro.core.placement import (  # noqa: F401
    DONOR_AXIS,
    HBM_RESIDENT,
    KV_HOST,
    KV_PEER_HBM,
    KV_REMOTE_HBM,
    OPT_HOST,
    OPT_PEER_HOST,
    REMOTE_DONOR_AXIS,
    WEIGHTS_PEER_HBM,
    WEIGHTS_STREAM,
    DonorAxisError,
    DonorStream,
    Placement,
    PlacementPolicy,
    PolicyBuilder,
    Role,
    Strategy,
    donor_allow_flags,
    donor_axes_for,
    get_policy,
    host_available,
    parse_policy,
    policy,
    register_policy,
    registered_policies,
    resolve_memory_kind,
    validate_policy_for_mesh,
)
from repro.core.planner import (  # noqa: F401
    CollectiveTerm,
    PlacementOOMError,
    PolicyPrediction,
    WorkloadProfile,
    decode_profile,
    eligible_policies,
    plan,
    pool_capacities,
    predict,
    train_profile,
)
from repro.core.replay import (  # noqa: F401
    ReplayLog,
    ReplayRecord,
    TermError,
)
from repro.core.roofline import (  # noqa: F401
    RooflineReport,
    load_reports,
    markdown_table,
    report_from_compiled,
    report_from_cost,
    save_reports,
)
from repro.core.hlo_analysis import (  # noqa: F401
    CollectiveStat,
    HloAnalyzer,
    HloCost,
    analyze_hlo_text,
)


def __getattr__(name: str):
    # deprecated names (POLICIES, put_like) forward to placement's PEP 562
    # shim so `from repro.core import POLICIES` keeps resolving — with the
    # same one-shot DeprecationWarning — without this package importing
    # them eagerly.
    if name in ("POLICIES", "put_like"):
        from repro.core import placement

        return getattr(placement, name)
    if name == "DEFAULT_SYSTEM":
        # the spec-sheet singleton: still reachable lazily for external
        # code, but in-repo callers must route through get_active_system()
        # / the Runtime facade (enforced by tools/check_deprecated.py).
        from repro.core import hardware

        return hardware.DEFAULT_SYSTEM
    if name in ("Calibration", "TermCalibration", "calibrate",
                "load_or_calibrate"):
        # calibration imports jax; keep `import repro.core` light for
        # pure-analytic callers.
        from repro.core import calibration

        return getattr(calibration, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
