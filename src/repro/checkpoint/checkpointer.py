"""Async sharded checkpointing with atomic manifests + elastic restore.

Fault-tolerance contract (the 1000-node requirement):

* **atomic**: leaves are written to ``step_XXXX.tmp/`` and the directory is
  renamed only after every array + the manifest fsync — a torn checkpoint
  is impossible to mistake for a complete one;
* **async**: arrays are snapshotted to host (device_get) synchronously —
  cheap — and written by a background thread, overlapping the next steps;
* **self-describing**: the manifest records tree structure, shapes, dtypes,
  step, and data-pipeline state — restore needs no live model object;
* **elastic**: arrays are stored unsharded (per-leaf ``.npy``); restore
  ``device_put``s onto *any* mesh/sharding, so a 512-chip checkpoint
  restarts on 256 chips (tested).  A production variant would write
  per-shard files; the manifest layout already carries everything needed.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.runtime.retry import CHECKPOINT_RETRY, retry_call


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None,
             blocking: bool = False) -> None:
        """Snapshot now, write in background (unless blocking)."""
        self.wait()  # one in-flight write at a time

        def to_host(v):
            a = np.asarray(jax.device_get(v))
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                # numpy can't serialize ml_dtypes: store losslessly as f32
                return np.asarray(a, np.float32), "bfloat16"
            return a, str(a.dtype)

        host_leaves = [
            (k, *to_host(v)) for k, v in _flatten_with_paths(tree)
        ]
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra or {},
            "leaves": [
                {"key": k, "shape": list(a.shape), "dtype": dt}
                for k, a, dt in host_leaves
            ],
        }

        def write_once():
            tmp = os.path.join(self.directory, f"step_{step:08d}.tmp")
            final = os.path.join(self.directory, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            for k, a, _dt in host_leaves:
                np.save(os.path.join(tmp, k.replace("/", "__") + ".npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)            # atomicity point
            self._gc()

        def write():
            # transient filesystem errors (a flaky network mount, a full
            # disk being reaped) retry under the checkpoint budget; the
            # .tmp/ staging makes re-running the whole write idempotent
            retry_call(
                write_once, retry_on=(OSError,), policy=CHECKPOINT_RETRY,
                label=f"checkpoint step {step}", seed=step,
            )

        def write_background():
            # the thread must capture failures for wait() to re-raise:
            # an exception dying with the thread would turn a failed
            # checkpoint into a silently missing one
            try:
                write()
            except Exception as e:
                self._error = e

        if blocking:
            write()
        else:
            self._error = None
            self._thread = threading.Thread(
                target=write_background, daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )

    # -- restore -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None,
                shardings=None) -> tuple[Any, dict]:
        """Restore into the structure of ``template``; optional
        ``shardings`` pytree device_puts each leaf (elastic resharding)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)

        dtypes = {d["key"]: d["dtype"] for d in manifest["leaves"]}
        keys = [k for k, _ in _flatten_with_paths(template)]
        arrays = []
        for k in keys:
            a = np.load(os.path.join(path, k.replace("/", "__") + ".npy"))
            arrays.append(jax.numpy.asarray(a, dtypes.get(k, a.dtype)))
        leaves, treedef = jax.tree_util.tree_flatten(template)
        restored = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            restored = jax.tree.map(jax.device_put, restored, shardings)
        return restored, manifest
