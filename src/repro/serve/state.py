"""Slot/sequence state: host mirrors + device serve state + upload rules.

The state layer of the serve stack.  A :class:`SlotTable` owns the
per-slot host mirrors (length, last token, active flag, and the per-slot
sampling parameters) and builds the device-side state dict the jitted
decode step carries.  Host mirrors advance from the token vector the
step *returns*; they are re-uploaded only on slot lifecycle events —
admission, free, suspend (preemption spill), resume (promotion) — never
per decode step.

Upload discipline (:func:`upload`, the PR 2/PR 3 lesson): a numpy buffer
handed to the device must never be mutated afterwards.  ``jnp.asarray``
can zero-copy alias the mirror, and even ``jnp.array``'s eager copy may
be *deferred* behind queued async dispatches on the CPU backend — so
every mirror upload hands over a fresh copy nothing else writes.

:class:`SpilledSequence` is the off-cache parking record for a preempted
request: its KV rows (device-put to the planner-priced spill tier by the
scheduler), its resume state, and the tick it started waiting — what
promotion needs to put it back bit-identically.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.serve.sampling import STOP_WIDTH, SamplingParams


def upload(arr: np.ndarray, dtype) -> jnp.ndarray:
    """Device copy of a host mirror that can NEVER see later writes."""
    return jnp.asarray(np.array(arr, dtype=dtype, copy=True))


def idle_device_state(batch_slots: int) -> dict:
    """All-idle device state with the canonical schema — same keys,
    shapes and dtypes as :meth:`SlotTable.device_state`.

    The Executor lowers its ahead-of-time decode step against this, so
    a schema drift between the two breaks loudly at build time instead
    of shape-erroring mid-serve.
    """
    B = batch_slots
    return {
        "tokens": jnp.zeros((B, 1), jnp.int32),
        "lengths": jnp.zeros((B,), jnp.int32),
        "active": jnp.zeros((B,), jnp.bool_),
        "temp": jnp.zeros((B,), jnp.float32),
        "top_k": jnp.zeros((B,), jnp.int32),
        "top_p": jnp.ones((B,), jnp.float32),
        "seed": jnp.zeros((B,), jnp.uint32),
        "stop": jnp.full((B, STOP_WIDTH), -1, jnp.int32),
    }


@dataclasses.dataclass
class SpilledSequence:
    """A preempted request parked off-cache: everything promotion needs."""

    rid: int
    rows: object            # per-slot cache-row pytree, on the spill tier
    length: int             # cache fill at spill time
    last_token: int         # the token the next decode step feeds
    sampling: SamplingParams
    since_tick: int         # when it started waiting (promotion ordering)
    spill_s: float = 0.0    # seconds the spill copy took (stats)
    #: MemoryTier the rows are parked on (tier-loss recovery re-queues
    #: sequences parked on a lost tier as fresh replays)
    tier: object = None
    #: checksum_tree() of rows at park time; None = verification off.
    #: Promotion verifies against it and a mismatch replays the request.
    checksum: float | None = None


class SlotTable:
    """Host mirrors of the per-slot serve state, one row per cache slot.

    The single owner of slot bookkeeping: which rid holds each slot, each
    row's fill/last-token/active mirrors, and the per-slot sampling
    parameter rows the device state carries.  All mutation goes through
    :meth:`claim` / :meth:`advance` / :meth:`free` / :meth:`resume` so a
    row can never be half-updated.
    """

    def __init__(self, batch_slots: int):
        self.batch_slots = batch_slots
        self.slots: list[int | None] = [None] * batch_slots
        self.lengths = np.zeros(batch_slots, np.int32)
        self.last_tokens = np.zeros((batch_slots, 1), np.int32)
        self.active = np.zeros(batch_slots, bool)
        # per-slot sampling mirrors (greedy defaults)
        self.temp = np.zeros(batch_slots, np.float32)
        self.top_k = np.zeros(batch_slots, np.int32)
        self.top_p = np.ones(batch_slots, np.float32)
        self.seed = np.zeros(batch_slots, np.uint32)
        self.stop = np.full((batch_slots, STOP_WIDTH), -1, np.int32)
        #: tick each slot was last (re)occupied — preemption's thrash
        #: guard (a just-admitted victim is not immediately re-spilled)
        self.claimed_tick = np.zeros(batch_slots, np.int64)

    # -- queries -----------------------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def slot_of(self, rid: int) -> int | None:
        try:
            return self.slots.index(rid)
        except ValueError:
            return None

    def occupancy(self, max_len: int) -> float:
        """Live cache utilization: resident tokens over the cache extent —
        what replan pricing feeds the planner."""
        return float(self.lengths.sum()) / float(self.batch_slots * max_len)

    # -- lifecycle ---------------------------------------------------------
    def _set_sampling(self, i: int, sampling: SamplingParams) -> None:
        self.temp[i] = sampling.temperature
        self.top_k[i] = sampling.top_k
        self.top_p[i] = sampling.top_p
        self.seed[i] = np.uint32(sampling.seed)
        self.stop[i] = sampling.stop_row()

    def claim(self, i: int, rid: int, sampling: SamplingParams,
              tick: int = 0) -> None:
        """Assign a fresh request to a free slot (prefill fills the rest)."""
        assert self.slots[i] is None, (i, self.slots[i])
        self.slots[i] = rid
        self.lengths[i] = 0
        self._set_sampling(i, sampling)
        self.claimed_tick[i] = tick

    def resume(self, i: int, spilled: SpilledSequence, tick: int = 0) -> None:
        """Re-occupy a free slot with a promoted (previously spilled)
        sequence: mirrors restored to the values at spill time."""
        assert self.slots[i] is None, (i, self.slots[i])
        self.slots[i] = spilled.rid
        self.lengths[i] = spilled.length
        self.last_tokens[i, 0] = spilled.last_token
        self.active[i] = True
        self._set_sampling(i, spilled.sampling)
        self.claimed_tick[i] = tick

    def advance(self, i: int, token: int) -> None:
        """Steady-state per-token mirror advance from the *returned*
        token vector (no re-upload)."""
        self.lengths[i] += 1
        self.last_tokens[i, 0] = token

    def free(self, i: int) -> int | None:
        """The single place a slot returns to the pool: clears the slot
        assignment and every mirror row together.  Stale cache content
        beyond the zeroed length is masked out and overwritten by the
        next prefill.  Returns the evicted rid."""
        rid = self.slots[i]
        self.slots[i] = None
        self.lengths[i] = 0
        self.last_tokens[i, 0] = 0
        self.active[i] = False
        self.temp[i] = 0.0
        self.top_k[i] = 0
        self.top_p[i] = 1.0
        self.seed[i] = 0
        self.stop[i] = -1
        return rid

    def suspend(self, i: int, tick: int) -> SpilledSequence:
        """Snapshot a slot's resume state for a preemption spill, then
        clear the row (the caches' rows are extracted by the executor).
        The caller attaches the off-cache rows to the returned record."""
        rid = self.slots[i]
        spilled = SpilledSequence(
            rid=rid,
            rows=None,
            length=int(self.lengths[i]),
            last_token=int(self.last_tokens[i, 0]),
            sampling=SamplingParams(
                temperature=float(self.temp[i]),
                top_k=int(self.top_k[i]),
                top_p=float(self.top_p[i]),
                seed=int(self.seed[i]),
                stop_tokens=tuple(
                    int(t) for t in self.stop[i] if t >= 0
                ),
            ),
            since_tick=tick,
        )
        self.free(i)
        return spilled

    # -- device state ------------------------------------------------------
    def device_state(self) -> dict:
        """Fresh device serve state from the mirrors (lifecycle events
        only — steady-state decode carries the device state through the
        jit and never re-uploads)."""
        return {
            "tokens": upload(self.last_tokens, np.int32),
            "lengths": upload(self.lengths, np.int32),
            "active": upload(self.active, bool),
            "temp": upload(self.temp, np.float32),
            "top_k": upload(self.top_k, np.int32),
            "top_p": upload(self.top_p, np.float32),
            "seed": upload(self.seed, np.uint32),
            "stop": upload(self.stop, np.int32),
        }
