from repro.serve.engine import Request, ServeConfig, Server  # noqa: F401
