"""Layered serve stack: state / sampling / scheduler / engine.

- :mod:`repro.serve.state` — slot/sequence host mirrors, device serve
  state, and the race-safe upload discipline.
- :mod:`repro.serve.sampling` — per-request sampling params computed
  in-jit (temperature / top-k / top-p / seeded draws / stop tokens)
  with a NumPy reference oracle.
- :mod:`repro.serve.scheduler` — the continuous-batching front end:
  bounded request queue, streaming callbacks, planner-priced KV
  preemption, and the public :class:`Server` / async
  :class:`Scheduler`.
- :mod:`repro.serve.engine` — the :class:`Executor`: every jitted
  dispatch (donated decode step, chunked prefill, slot
  extract/insert) and live re-placement.
- :mod:`repro.serve.handoff` — the DCN crossing of a disaggregated
  cluster: publish/adopt of KV tickets over the bridge mesh's
  ``donor_pod`` tier, with per-request crossing accounting.
- :mod:`repro.serve.disagg` — the disaggregated :class:`Cluster`:
  planner-split prefill/decode pools joined by the handoff, with
  replay-as-fresh fault recovery.
"""

from repro.serve.disagg import Cluster, DisaggConfig, PrefillPool  # noqa: F401
from repro.serve.engine import Executor  # noqa: F401
from repro.serve.handoff import (  # noqa: F401
    Handoff,
    HandoffLedger,
    HandoffTicket,
    make_bridge_mesh,
)
from repro.serve.sampling import GREEDY, SamplingParams  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    QueueFullError,
    Request,
    Scheduler,
    SchedulerClosed,
    ServeConfig,
    ServeHangError,
    Server,
)
from repro.serve.state import SlotTable, SpilledSequence  # noqa: F401
