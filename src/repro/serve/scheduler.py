"""Scheduler: the continuous-batching front end of the serve stack.

This layer owns *requests*: a bounded wait queue with FIFO-by-wait-start
admission, streaming per-token callbacks, and planner-priced preemption.
It composes the other layers — :class:`~repro.serve.state.SlotTable`
(host mirrors + device state), :mod:`repro.serve.sampling` (per-request
params, computed in-jit), and the :class:`~repro.serve.engine.Executor`
(every jitted dispatch) — behind the public :class:`Server`, plus an
asyncio front end (:class:`Scheduler`) for callers that want
``await submit()`` / ``async for token in stream()``.

Request lifecycle::

            submit/add_request          admit (FIFO by wait start)
    new ───────────────────────▶ queued ─────────────▶ active (decode)
             QueueFullError when            ▲                 │
             cfg.max_queue waiting          │ promote         │ preempt
                                            │ (slot frees)    ▼
                                         spilled ◀──── KV rows parked on the
                                                       planner-priced spill
                                                       tier; re-queued FIFO

    active ──▶ done: stop token (in-jit match) | max_new_tokens |
               cache extent; slot freed, rid evicted, mirrors re-synced

**Planner-priced preemption** (the paper's §IV decision made per slot at
runtime): when the oldest waiter has starved for ``preempt_wait`` ticks
and no slot is free, the scheduler asks the runtime what eviction
*costs* — ``Runtime.preemption_price`` prices the round trip of one
slot's cache rows to the cheapest realizable far tier (host DRAM, or the
peer/remote donor pools when the mesh has the axis) through the datapath
``copy_bound`` model — and what waiting costs — the planner-predicted
decode step time times the fewest remaining tokens of any active
request.  Only when spilling is cheaper than waiting does it evict, and
the victim is the active request with the *most* remaining work
(shortest-remaining-work-first keeps slots churning).  The victim's KV
rows are extracted in one jitted slice, parked off-cache, and scattered
back bit-identically when a slot frees — so greedy tokens are invariant
under any preemption/promotion history, which the tests and the CI soak
assert.
"""

from __future__ import annotations

import asyncio
import dataclasses
import logging
import time
from typing import Callable

import numpy as np

from repro.core.faults import (
    FaultKind,
    FaultPlan,
    SpillCorruptionError,
    TierLossError,
    checksum_tree,
    corrupt_tree,
    verify_spill,
)
from repro.core.hardware import MemoryTier
from repro.core.placement import PlacementPolicy, Role
from repro.runtime.supervisor import Watchdog, WatchdogConfig
from repro.serve.engine import Executor
from repro.serve.sampling import GREEDY, SamplingParams
from repro.serve.state import SlotTable, SpilledSequence

log = logging.getLogger("repro.serve.scheduler")


class QueueFullError(RuntimeError):
    """Backpressure: the bounded wait queue is at ``cfg.max_queue``.

    The sync surface raises so callers can shed or retry;
    :meth:`Scheduler.submit` absorbs it by awaiting queue space instead.
    """


class ServeHangError(RuntimeError):
    """The serve loop failed to make progress: ``run_until_done``
    exhausted its step budget with live requests still queued, or the
    watchdog escalated past its last rung.  Carries the diagnostics a
    post-mortem needs: queue depth, the live rids, and the last stats
    snapshot."""

    def __init__(
        self,
        message: str,
        *,
        queue_depth: int = 0,
        live_rids=(),
        stats: dict | None = None,
    ):
        self.queue_depth = int(queue_depth)
        self.live_rids = tuple(live_rids)
        self.stats = dict(stats or {})
        super().__init__(
            f"{message} [queue_depth={self.queue_depth} "
            f"live_rids={list(self.live_rids)} stats={self.stats}]"
        )


class SchedulerClosed(RuntimeError):
    """:meth:`Scheduler.close` was called: pending ``submit()`` waiters
    (and streams that can no longer finish) are cancelled with this
    instead of waiting forever."""


@dataclasses.dataclass
class Request:
    """One generation request.

    ``sampling`` defaults to greedy (temperature 0 — bit-identical to
    the pre-sampler engine); ``on_token`` streams each generated token
    as ``on_token(request, token)`` the tick it is decoded (check
    ``request.done`` inside the callback for end-of-stream; a cancelled
    or expired request streams one terminal ``-1`` sentinel with
    ``done`` already set).  The ``*_s`` fields are
    ``time.perf_counter`` stamps the benchmarks turn into queue-wait /
    time-to-first-token / completion latencies.  ``deadline_s`` bounds
    the request's *total* wall time from submission: past it the server
    expires the request at the next tick (slot freed, counted in
    ``stats()["expired"]``).
    """

    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int
    sampling: SamplingParams = GREEDY
    on_token: Callable[["Request", int], None] | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    preemptions: int = 0
    submitted_s: float | None = None
    first_token_s: float | None = None
    finished_s: float | None = None
    #: total wall-time budget from submission (None = unbounded)
    deadline_s: float | None = None
    cancelled: bool = False

    def cancel(self) -> None:
        """Cooperative cancellation: the server finalizes the request on
        its next tick — slot freed through ``_free_slot``, terminal
        ``-1`` sentinel streamed to ``on_token``, counted in
        ``stats()["cancelled"]``.  Idempotent; a no-op once done."""
        self.cancelled = True


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 512
    #: tokens per chunked-prefill dispatch during admission
    prefill_chunk: int = 32
    #: None -> consult the placement planner (datapath-bound model);
    #: otherwise any ``parse_policy`` spelling: a PlacementPolicy value,
    #: a registered name, ``"kv=host:stream,..."``, or policy JSON.
    policy: PlacementPolicy | str | dict | None = None
    rules: dict | None = None
    #: re-run the planner (and migrate KV/params if the pick changes)
    #: whenever cache occupancy crosses a band boundary — the live form
    #: of the paper's phase-dependent placement decision.
    auto_replan: bool = False
    #: number of occupancy bands for auto_replan (4 -> re-price at 25%
    #: occupancy steps)
    replan_bands: int = 4
    #: bound on *waiting* (not yet admitted) requests; None = unbounded.
    #: add_request raises QueueFullError beyond it — the documented
    #: backpressure path (spilled sequences hold progress and do not
    #: count against it).
    max_queue: int | None = None
    #: enable planner-priced KV preemption (spill a victim's slot rows
    #: to the cheapest realizable far tier when waiters starve)
    preempt: bool = False
    #: ticks the oldest waiter must starve before preemption is
    #: considered — also the thrash guard: a freshly (re)admitted slot
    #: cannot be re-evicted sooner
    preempt_wait: int = 8
    #: assert at Executor build time that every donation the policy
    #: requires actually materialized as input/output aliasing in the
    #: compiled module (repro.analysis.hlo_audit.DonationAliasError
    #: instead of a silent cache-sized copy per dispatch)
    verify_donation: bool = True
    #: injected-fault schedule (core.faults.FaultPlan); None = NO_FAULTS.
    #: Lives on the executor's Runtime so every site consults one plan.
    faults: FaultPlan | None = None
    #: checksum spilled rows at park time and verify at promotion; a
    #: mismatch drops the parked rows and replays the request
    #: (bit-identical continuation).  Always on while faults are active.
    verify_spills: bool = False
    #: step watchdog (stall -> retry -> evacuate -> ServeHangError);
    #: None disables it.  The deadline follows the runtime's
    #: measured-else-analytic decode-step price.
    watchdog: WatchdogConfig | None = dataclasses.field(
        default_factory=WatchdogConfig
    )
    #: pool label for disaggregated clusters ("prefill"/"decode"); tags
    #: the Executor's donation-audit reports so each pool's builds stay
    #: separately attributable.  Empty for colocated serving.
    pool: str = ""


class Server:
    """Single-model continuous-batching server.

    The public serve surface: composes the scheduler's queue/preemption
    policy with the :class:`~repro.serve.engine.Executor` (reachable as
    ``server.engine`` — jits, caches, params, Runtime) and the
    :class:`~repro.serve.state.SlotTable` (``server.table``).
    """

    def __init__(self, bundle, cfg: ServeConfig, params, mesh=None):
        self.bundle = bundle
        self.cfg = cfg
        self.engine = Executor(bundle, cfg, params, mesh)
        self.table = SlotTable(cfg.batch_slots)
        self._requests: dict[int, Request] = {}
        #: FIFO by wait start: ("fresh", rid) never yet admitted,
        #: ("spilled", rid) preempted and re-queued
        self._waitq: list[tuple[str, int]] = []
        self._spilled: dict[int, SpilledSequence] = {}
        self._wait_since: dict[int, int] = {}
        self._tick = 0
        self._state = self.engine.place_state(self.table.device_state())
        self._replan_band: int | None = None
        self._next_rid = 0
        #: rid -> replacement prompt for the next "fresh" admission: a
        #: replayed request (corrupted spill, tier loss mid-flight)
        #: prefills prompt + everything generated so far instead of its
        #: original prompt — bit-identical continuation
        self._replay_prompts: dict[int, np.ndarray] = {}
        #: disaggregation hook (repro.serve.disagg): when set,
        #: _requeue_fresh offers the request back to the cluster —
        #: ``hook(rid, replay_prompt) -> True`` means the cluster took it
        #: (it replays through the prefill pool and re-adopts), so this
        #: server drops its bookkeeping instead of re-queuing locally
        self.requeue_hook: Callable[[int, np.ndarray], bool] | None = None
        self._counters = {
            "preemptions": 0, "promotions": 0, "peak_queue": 0,
            "cancelled": 0, "expired": 0,
            "tier_losses": 0, "spill_corruptions": 0, "requeued_fresh": 0,
            "watchdog_stalls": 0, "watchdog_retries": 0,
            "watchdog_evacuations": 0,
        }
        #: serve-step watchdog: deadlines each decode against the
        #: runtime's measured-else-analytic step price (see
        #: repro.runtime.supervisor.Watchdog); None = disabled
        self.watchdog = (
            None if cfg.watchdog is None
            else Watchdog(
                lambda: self.rt.decode_step_seconds(
                    cfg.batch_slots, cfg.max_len
                ),
                cfg.watchdog,
            )
        )

    # -- introspection -----------------------------------------------------
    @property
    def rt(self):
        """The executor's :class:`repro.api.Runtime` (mesh + policy +
        planner)."""
        return self.engine.rt

    @property
    def policy(self) -> PlacementPolicy:
        """The placement policy currently in force (may change across
        :meth:`replan` migrations)."""
        return self.engine.policy

    @property
    def params(self):
        return self.engine.params

    @property
    def queue_depth(self) -> int:
        """Fresh (never admitted) requests waiting — what ``max_queue``
        bounds."""
        return sum(1 for kind, _ in self._waitq if kind == "fresh")

    @property
    def live_rids(self) -> tuple[int, ...]:
        """rids of all live (queued, active, or spilled) requests."""
        return tuple(self._requests)

    def has_work(self) -> bool:
        """Anything queued, spilled, or decoding?"""
        return bool(self._waitq or self._spilled or self.table.active_slots())

    def occupancy(self) -> float:
        """Live cache utilization — what replan pricing feeds the
        planner."""
        return self.table.occupancy(self.cfg.max_len)

    def stats(self) -> dict:
        """Counters across all layers: executor phase tokens/seconds and
        lifecycle events (``replans``/``migrations``/``evacuations``/
        ``migration_retries``/``decode_replay_prefills``/``spill_s``/
        ``restore_s``) merged with the scheduler's (``preemptions``/
        ``promotions``/``peak_queue``, plus the robustness set:
        ``cancelled``/``expired``/``tier_losses``/``spill_corruptions``/
        ``requeued_fresh``/``watchdog_stalls``/``watchdog_retries``/
        ``watchdog_evacuations``) and the live ``queued``/``spilled``
        depths."""
        return {
            **self.engine.counters,
            **self._counters,
            "queued": self.queue_depth,
            "spilled": len(self._spilled),
        }

    def throughput(self) -> dict:
        """Prefill/decode split tokens-per-second from the counters."""
        c = self.engine.counters
        return {
            "prefill_tokens": c["prefill_tokens"],
            "decode_tokens": c["decode_tokens"],
            "prefill_tps": (
                c["prefill_tokens"] / c["prefill_s"] if c["prefill_s"]
                else 0.0
            ),
            "decode_tps": (
                c["decode_tokens"] / c["decode_s"] if c["decode_s"]
                else 0.0
            ),
        }

    # -- request intake ----------------------------------------------------
    def add_request(self, req: Request) -> None:
        """Queue a request, validating it against the cache extent.

        Oversubscription is first-class: when every slot is busy the
        request simply waits its turn (and may trigger a preemption once
        it starves past ``preempt_wait``).  The only rejection paths are
        malformed requests and the bounded-queue backpressure:
        ``cfg.max_queue`` caps *waiting* requests, and the cap raises
        :class:`QueueFullError` so a front end can shed load or block —
        never a silent drop.
        """
        if req.rid < 0:
            raise ValueError(f"request rid must be >= 0, got {req.rid}")
        if req.rid in self._requests:
            raise ValueError(
                f"request {req.rid}: rid already queued or being served "
                "(rids must be unique among live requests; a duplicate "
                "would orphan the live request's slot bookkeeping — "
                "finished rids are evicted and may be reused)"
            )
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}"
            )
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) >= self.cfg.max_len:
            log.warning(
                "rejecting request %d: prompt of %d tokens needs "
                "len(prompt)+1 cache positions but max_len=%d",
                req.rid, len(req.prompt), self.cfg.max_len,
            )
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"does not fit max_len={self.cfg.max_len} "
                "(need len(prompt) < max_len)"
            )
        req.sampling.validate()
        if (
            self.cfg.max_queue is not None
            and self.queue_depth >= self.cfg.max_queue
        ):
            raise QueueFullError(
                f"request {req.rid}: wait queue is full "
                f"({self.cfg.max_queue} waiting); retry after a slot "
                "drains or raise ServeConfig.max_queue"
            )
        req.submitted_s = time.perf_counter()
        self._requests[req.rid] = req
        self._waitq.append(("fresh", req.rid))
        self._wait_since[req.rid] = self._tick
        self._counters["peak_queue"] = max(
            self._counters["peak_queue"], self.queue_depth
        )

    def add_requests(self, reqs) -> None:
        """Batched admission entry point: queue several requests at once
        (they prefill together in the next tick's chunked dispatches)."""
        for req in reqs:
            self.add_request(req)

    def adopt_spilled(self, req: Request, spilled: SpilledSequence) -> None:
        """Admit a request whose KV was prepared *elsewhere* — the
        decode-side entry point of a disaggregated handoff
        (``repro.serve.disagg``).

        ``spilled`` carries the rows a prefill pool filled and the
        handoff moved onto this server's mesh, shaped exactly like a
        preemption spill — so admission rides the existing promotion
        path (:meth:`_promote`: checksum verify, jitted row insert,
        mirror resume) with zero new machinery on the per-token path.
        Queued FIFO like any other waiter; a promotion-time integrity
        failure takes the same replay-as-fresh ladder (routed back to
        the cluster by the ``requeue_hook`` when installed).
        """
        if spilled.rid != req.rid:
            raise ValueError(
                f"ticket rid {spilled.rid} != request rid {req.rid}"
            )
        if req.rid in self._requests:
            raise ValueError(
                f"request {req.rid}: rid already live on this server"
            )
        if req.submitted_s is None:
            req.submitted_s = time.perf_counter()
        self._requests[req.rid] = req
        self._spilled[req.rid] = spilled
        self._waitq.append(("spilled", req.rid))
        self._wait_since[req.rid] = self._tick
        self._counters["peak_queue"] = max(
            self._counters["peak_queue"], self.queue_depth
        )

    def submit(
        self,
        prompt,
        *,
        max_new_tokens: int,
        sampling: SamplingParams = GREEDY,
        rid: int | None = None,
        on_token: Callable[[Request, int], None] | None = None,
    ) -> Request:
        """Convenience intake: build + queue a request, auto-assigning a
        free rid, and return it (tokens stream into ``out_tokens`` /
        ``on_token``)."""
        if rid is None:
            while self._next_rid in self._requests:
                self._next_rid += 1
            rid = self._next_rid
            self._next_rid += 1
        req = Request(
            rid=rid,
            prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens,
            sampling=sampling,
            on_token=on_token,
        )
        self.add_request(req)
        return req

    # -- admission / preemption -------------------------------------------
    def _sync_state(self) -> None:
        """Re-upload the small state arrays after a slot lifecycle event
        (admission / free / spill / promote).  Steady-state decode never
        calls this: the state lives on device and the host mirror
        advances from the *returned* token vector."""
        self._state = self.engine.place_state(self.table.device_state())

    def _free_slot(self, i: int) -> int | None:
        """The one place an *occupied* slot returns to the pool: clears
        the table row and evicts the rid's request bookkeeping together
        (requests map, wait-start stamp).  Returns the evicted rid."""
        rid = self.table.free(i)
        if rid is not None:
            self._requests.pop(rid, None)
            self._wait_since.pop(rid, None)
            self._replay_prompts.pop(rid, None)
        return rid

    def _requeue_fresh(self, rid: int) -> None:
        """Re-queue a live request as a ``"fresh"`` waiter whose next
        admission replays prompt + everything generated so far.

        The recovery primitive behind corrupted spills and lost spill
        tiers: chunked prefill ≡ decode replay and sampling draws are
        (seed, position)-deterministic, so the replayed continuation is
        bit-identical to never having been interrupted.  Inserted at
        the queue head — the request already waited its turn once.

        With a disaggregation ``requeue_hook`` installed, the cluster
        gets first refusal: a hook returning True takes the request back
        (replay routes through the *prefill* pool and re-enters via
        :meth:`adopt_spilled`), and this server forgets it entirely."""
        req = self._requests[rid]
        replay = np.asarray(req.prompt, np.int32)
        if req.out_tokens:
            replay = np.concatenate(
                [replay, np.asarray(req.out_tokens, np.int32)]
            )
        self._waitq = [(k, r) for k, r in self._waitq if r != rid]
        self._counters["requeued_fresh"] += 1
        if self.requeue_hook is not None and self.requeue_hook(rid, replay):
            self._requests.pop(rid, None)
            self._wait_since.pop(rid, None)
            self._replay_prompts.pop(rid, None)
            self._spilled.pop(rid, None)
            return
        if req.out_tokens:
            self._replay_prompts[rid] = replay
        self._waitq.insert(0, ("fresh", rid))
        self._wait_since[rid] = self._tick

    def _reap_cancelled_expired(self) -> None:
        """Finalize cancelled and deadline-expired requests (start of
        every tick): slot freed via :meth:`_free_slot`, queue/spill
        entries dropped, terminal ``-1`` sentinel streamed, counted in
        ``stats()["cancelled"]`` / ``["expired"]``."""
        now = time.perf_counter()
        freed = False
        for req in list(self._requests.values()):
            if req.done:
                continue
            expired = (
                req.deadline_s is not None
                and req.submitted_s is not None
                and now - req.submitted_s > req.deadline_s
            )
            if not (req.cancelled or expired):
                continue
            why = "cancelled" if req.cancelled else "expired"
            i = self.table.slot_of(req.rid)
            if i is not None:
                self._free_slot(i)
                freed = True
            else:
                self._waitq = [
                    (k, r) for k, r in self._waitq if r != req.rid
                ]
                self._spilled.pop(req.rid, None)
                self._requests.pop(req.rid, None)
                self._wait_since.pop(req.rid, None)
                self._replay_prompts.pop(req.rid, None)
            req.done = True
            req.finished_s = time.perf_counter()
            self._counters[why] += 1
            log.info(
                "request %d %s after %d generated token(s)",
                req.rid, why, len(req.out_tokens),
            )
            if req.on_token is not None:
                req.on_token(req, -1)
        if freed:
            self._sync_state()

    def _admit(self) -> None:
        """Fill free slots from the wait queue, FIFO by wait start.

        Fresh requests are claimed and prefilled *batched* (one chunked
        dispatch set for all of them); spilled sequences are promoted —
        their parked rows verified (when spill verification is on) and
        scattered back, no prefill (the KV is intact).  A promotion
        whose rows fail their integrity check does not consume the
        slot: the rows are dropped and the request replays as a fresh
        waiter.
        """
        free = self.table.free_slots()
        fresh: list[tuple[int, Request, np.ndarray]] = []
        changed = False
        while free and self._waitq:
            kind, rid = self._waitq.pop(0)
            i = free.pop(0)
            changed = True
            if kind == "fresh":
                req = self._requests[rid]
                self.table.claim(i, rid, req.sampling, self._tick)
                fresh.append(
                    (i, req, self._replay_prompts.pop(rid, req.prompt))
                )
            else:
                spilled = self._spilled.pop(rid)
                try:
                    self._promote(i, spilled)
                except SpillCorruptionError as e:
                    log.warning("%s", e)
                    self._counters["spill_corruptions"] += 1
                    free.insert(0, i)       # verify-first: slot untouched
                    self._requeue_fresh(rid)
        if fresh:
            self.engine.prefill(
                [(i, prompt) for i, _, prompt in fresh], self.table
            )
            for i, req, prompt in fresh:
                self.table.last_tokens[i, 0] = prompt[-1]
                self.table.active[i] = True
        if changed:
            self._sync_state()

    def _promote(self, i: int, spilled: SpilledSequence) -> None:
        """Scatter a spilled sequence's parked rows back into slot ``i``
        and resume its mirrors — bit-identical to never having moved.
        Verifies the rows against their park-time checksum first
        (:class:`~repro.core.faults.SpillCorruptionError` on mismatch,
        before anything is touched)."""
        verify_spill(spilled.rows, spilled.checksum, spilled.rid)
        self.engine.insert_slot(i, spilled.rows)
        self.table.resume(i, spilled, self._tick)
        self._wait_since.pop(spilled.rid, None)
        self._counters["promotions"] += 1
        log.info(
            "promoted rid %d into slot %d after %d ticks spilled",
            spilled.rid, i, self._tick - spilled.since_tick,
        )

    def _remaining(self, i: int) -> int:
        req = self._requests[self.table.slots[i]]
        return max(req.max_new_tokens - len(req.out_tokens), 0)

    def _maybe_preempt(self) -> None:
        """Evict one victim iff the oldest waiter has starved past
        ``preempt_wait`` ticks AND the planner prices the spill round
        trip below the predicted natural wait for a slot."""
        if not self.cfg.preempt or not self._waitq:
            return
        if self.table.free_slots():
            return
        _, head = self._waitq[0]
        if self._tick - self._wait_since.get(head, self._tick) \
                < self.cfg.preempt_wait:
            return
        # thrash guard: never evict a slot that was (re)occupied within
        # the same starvation window
        candidates = [
            i for i in self.table.active_slots()
            if self._tick - int(self.table.claimed_tick[i])
            >= self.cfg.preempt_wait
        ]
        if not candidates:
            return
        spill_to, price_s = self.rt.preemption_price(
            self.engine.slot_bytes()
        )
        # wait side: the runtime's decode-step price — the measured EWMA
        # once the Executor's warm steps have fed it (the observed cost
        # of waiting), the planner's analytic prediction before that.
        step_s = self.rt.decode_step_seconds(
            self.cfg.batch_slots, self.cfg.max_len
        )
        natural_wait_s = step_s * min(
            self._remaining(i) for i in self.table.active_slots()
        )
        if price_s >= natural_wait_s:
            log.debug(
                "preemption not worth it: spill round trip %.3gs >= "
                "natural slot free in %.3gs", price_s, natural_wait_s,
            )
            return
        # victim: most remaining work (shortest-remaining-first keeps
        # slots churning); deterministic tie-break on rid
        victim = max(
            candidates, key=lambda i: (self._remaining(i),
                                       self.table.slots[i])
        )
        self._spill(victim, spill_to)

    def _spill(self, i: int, spill_to) -> None:
        rid = self.table.slots[i]
        t0 = time.perf_counter()
        rows = self.engine.extract_slot(i, spill_to)
        spilled = self.table.suspend(i, self._tick)
        spilled.rows = rows
        spilled.tier = spill_to.tier
        faults = self.rt.faults
        if self.cfg.verify_spills or faults:
            # park-time checksum, verified at promotion; off the
            # per-token path (spill lifecycle events only) and off
            # entirely unless verification or fault injection is on
            spilled.checksum = checksum_tree(rows)
        if faults:
            ev = faults.check("spill")
            if ev is not None and ev.kind is FaultKind.SPILL_CORRUPT:
                spilled.rows = corrupt_tree(spilled.rows)
        spilled.spill_s = time.perf_counter() - t0
        self._spilled[rid] = spilled
        self._waitq.append(("spilled", rid))
        self._wait_since[rid] = self._tick
        self._requests[rid].preemptions += 1
        self._counters["preemptions"] += 1
        self._sync_state()
        log.info(
            "preempted rid %d (slot %d, %d tokens resident) -> %s",
            rid, i, spilled.length, spill_to.to_str(),
        )

    # -- live re-placement -------------------------------------------------
    def replan(self, policy=None, *, force: bool = False) -> bool:
        """Re-place the live KV cache (and params) mid-serve — see
        :meth:`repro.serve.engine.Executor.replan`.  Priced against the
        live :meth:`occupancy`."""
        return self.engine.replan(
            policy, force=force, occupancy=self.occupancy(),
            inflight=self._state["tokens"],
        )

    def _maybe_auto_replan(self) -> None:
        """Fire :meth:`replan` when occupancy crosses a band boundary —
        only for planner-owned policies (a forced ``cfg.policy`` pins
        placement; call :meth:`replan` explicitly to move it)."""
        if not self.cfg.auto_replan or self.cfg.policy is not None:
            return
        band = int(self.occupancy() * max(self.cfg.replan_bands, 1))
        if band != self._replan_band:
            self._replan_band = band
            self.replan()

    # -- tier-loss recovery ------------------------------------------------
    def _lose_tier(self, tier) -> None:
        """Degrade off ``tier`` and keep serving: evacuate the live
        KV/params roles (planner re-pick excluding the lost tier, jits
        rebuilt), replay any spilled sequence whose parked rows lived
        there, and re-sync the device state."""
        # un-claim any slot caught mid-admission (claimed, prefill never
        # completed): free the row and put its request back at the queue
        # head — _requeue_fresh rebuilds the replay prompt if it had
        # already generated tokens
        for i in range(self.table.batch_slots):
            rid = self.table.slots[i]
            if rid is not None and not bool(self.table.active[i]):
                self.table.free(i)
                self._requeue_fresh(rid)
        self.engine.evacuate(
            tier, occupancy=self.occupancy(),
            inflight=self._state["tokens"],
        )
        # parked rows on a lost tier: drop them and replay the request
        # from its prompt + generated tokens (bit-identical continuation)
        for rid, sp in list(self._spilled.items()):
            if sp.tier is not None and sp.tier in self.rt.lost_tiers:
                self._spilled.pop(rid)
                self._requeue_fresh(rid)
        self._sync_state()

    def _recover_tier_loss(self, e: TierLossError) -> None:
        self._counters["tier_losses"] += 1
        log.warning(
            "tier loss at tick %d: %s — evacuating and continuing "
            "degraded", self._tick, e,
        )
        self._lose_tier(e.tier)

    def _escalate(self, action: str) -> None:
        """Act on a watchdog verdict: ``stall`` warns and counts;
        ``retry`` rebuilds the jitted dispatch path; ``evacuate``
        degrades off the presumed-slow far tier (the GH200 failure
        mode: an access-path fault showing up as a slowdown, not an
        error); ``hang`` raises :class:`ServeHangError`."""
        if action == "stall":
            self._counters["watchdog_stalls"] += 1
            return
        if action == "retry":
            self._counters["watchdog_retries"] += 1
            log.warning(
                "watchdog retry: rebuilding the jitted dispatch path"
            )
            self.engine._build_steps()
            return
        if action == "evacuate":
            far = [
                self.policy.placement(r).tier
                for r in (Role.KV_CACHE, Role.PARAMS)
                if self.policy.placement(r).tier is not MemoryTier.HBM
                and self.policy.placement(r).tier not in self.rt.lost_tiers
            ]
            if not far:
                # nothing left to degrade; the ladder continues to hang
                self._counters["watchdog_stalls"] += 1
                return
            self._counters["watchdog_evacuations"] += 1
            log.warning(
                "watchdog evacuate: abandoning presumed-degraded tier %s",
                far[0].value,
            )
            self._lose_tier(far[0])
            return
        if action == "hang":
            raise ServeHangError(
                f"watchdog: {self.watchdog.breaches} consecutive steps "
                f"over the {self.watchdog.deadline_s():.3g}s deadline "
                f"(last step {self.watchdog.last_step_s:.3g}s)",
                queue_depth=self.queue_depth,
                live_rids=self.live_rids,
                stats=self.stats(),
            )

    # -- one decode tick ---------------------------------------------------
    def step(self) -> int:
        """Preempt/admit/promote, then decode one token for every active
        slot.  Returns the number of active slots.

        The decode step consumes and returns the on-device state; the
        only per-step host↔device traffic is the packed (2, B)
        token/stopped vector coming back (one async transfer, then
        blocked on).  Tokens stream to ``on_token`` callbacks the tick
        they are decoded.

        Self-healing: a :class:`~repro.core.faults.TierLossError` from
        any dispatch is caught here — the server evacuates the lost
        tier, rebuilds its jits, replays what was parked there, and
        continues degraded (greedy tokens bit-identical for requests
        untouched by the fault).  The watchdog deadlines the decode
        against the runtime's step price and escalates consecutive
        breaches stall → retry → evacuate → :class:`ServeHangError`.
        """
        self._tick += 1
        self._reap_cancelled_expired()
        try:
            return self._step_inner()
        except TierLossError as e:
            self._recover_tier_loss(e)
            return 0

    def _step_inner(self) -> int:
        self._maybe_preempt()
        self._admit()
        self._maybe_auto_replan()
        active = self.table.active_slots()
        if not active:
            return 0
        now = time.perf_counter
        t0 = now()
        tokens, stopped, self._state = self.engine.decode(self._state)
        decode_dt = now() - t0
        self.engine.counters["decode_tokens"] += len(active)
        freed = False
        for i in active:
            req = self._requests[self.table.slots[i]]
            # host numpy already (the engine's one sanctioned fetch)
            tok = int(tokens[i])  # repro: lint-disable=blocking-transfer-in-hot-path
            req.out_tokens.append(tok)
            if req.first_token_s is None:
                req.first_token_s = now()
            self.table.advance(i, tok)
            if (
                bool(stopped[i])
                or len(req.out_tokens) >= req.max_new_tokens
                or self.table.lengths[i] >= self.cfg.max_len - 1
            ):
                req.done = True
                req.finished_s = now()
                self._free_slot(i)
                freed = True
            if req.on_token is not None:
                req.on_token(req, tok)
        if freed:
            self._sync_state()
            self._maybe_auto_replan()
        # feed the watchdog the decode wall time (admission/compile
        # excluded — the first step after a jit build is compile-
        # dominated and skipped, same warm-up rule as the step EWMA)
        if self.watchdog is not None and self.engine._steps_since_build > 1:
            self._escalate(self.watchdog.observe(decode_dt))
        return len(active)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        """Drive :meth:`step` until nothing is live.  Exhausting
        ``max_steps`` with work still queued raises
        :class:`ServeHangError` with full queue/slot diagnostics —
        never a silent return with requests stranded."""
        for _ in range(max_steps):
            if not self.has_work():
                return
            self.step()
        if not self.has_work():
            return
        raise ServeHangError(
            f"serve loop did not drain within max_steps={max_steps}",
            queue_depth=self.queue_depth,
            live_rids=self.live_rids,
            stats=self.stats(),
        )


class Scheduler:
    """Asyncio front end over a :class:`Server`.

    ``await submit()`` absorbs :class:`QueueFullError` by waiting for
    queue space (backpressure as flow control instead of an exception);
    :meth:`stream` yields tokens as the driver loop decodes them; and
    :meth:`run` drives the server until it is closed *and* drained —
    decode steps run in a worker thread (``asyncio.to_thread``) so the
    event loop keeps serving submissions and streams between ticks::

        server = Server(bundle, ServeConfig(...), params)
        sched = Scheduler(server)
        async def client():
            req = await sched.submit(prompt, max_new_tokens=32)
            async for tok in sched.stream(req):
                ...
            sched.close()
        await asyncio.gather(sched.run(), client())
    """

    def __init__(self, server: Server, *, step_timeout_s: float | None = 60.0):
        self.server = server
        #: off-thread bound on one server.step(); a step that outlives it
        #: surfaces as ServeHangError instead of wedging the event loop's
        #: driver task forever.  None = unbounded.
        self.step_timeout_s = step_timeout_s
        self._tick_ev = asyncio.Event()
        self._closed = False

    def _notify(self) -> None:
        ev, self._tick_ev = self._tick_ev, asyncio.Event()
        ev.set()

    async def _wait_tick(self) -> None:
        ev = self._tick_ev
        await ev.wait()

    async def submit(self, prompt, **kw) -> Request:
        """Queue a request, awaiting queue space under backpressure.
        Raises :class:`SchedulerClosed` (immediately, or on wake while
        waiting for space) once :meth:`close` has been called."""
        while True:
            if self._closed:
                raise SchedulerClosed(
                    "scheduler closed; submission cancelled"
                )
            try:
                return self.server.submit(prompt, **kw)
            except QueueFullError:
                await self._wait_tick()

    async def stream(self, req: Request):
        """Async-yield ``req``'s tokens as they are decoded.  A stream
        that can no longer finish — the scheduler closed and the server
        drained without completing ``req`` — raises
        :class:`SchedulerClosed` instead of waiting forever."""
        sent = 0
        while True:
            while sent < len(req.out_tokens):
                yield req.out_tokens[sent]
                sent += 1
            if req.done:
                return
            if self._closed and not self.server.has_work():
                raise SchedulerClosed(
                    f"scheduler closed with request {req.rid} unfinished"
                )
            await self._wait_tick()

    async def run(self) -> None:
        """Drive the server until :meth:`close` is called and every live
        request has drained.  Each off-thread step is bounded by
        ``step_timeout_s``: a wedged dispatch raises
        :class:`ServeHangError` with the server's diagnostics instead of
        blocking the driver task indefinitely."""
        try:
            while not (self._closed and not self.server.has_work()):
                if self.server.has_work():
                    step = asyncio.to_thread(self.server.step)
                    if self.step_timeout_s is None:
                        await step
                    else:
                        try:
                            await asyncio.wait_for(
                                step, self.step_timeout_s
                            )
                        except asyncio.TimeoutError:
                            raise ServeHangError(
                                "serve step exceeded the scheduler's "
                                f"{self.step_timeout_s:.3g}s off-thread "
                                "bound",
                                queue_depth=self.server.queue_depth,
                                live_rids=self.server.live_rids,
                                stats=self.server.stats(),
                            ) from None
                else:
                    await asyncio.sleep(0.001)
                self._notify()
        finally:
            self._notify()

    def close(self) -> None:
        """Let :meth:`run` return once the last live request drains, and
        wake every ``submit()``/``stream()`` waiter so those that can no
        longer complete fail fast with :class:`SchedulerClosed`."""
        self._closed = True
        self._notify()
