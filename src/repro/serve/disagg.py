"""Disaggregated prefill/decode serving: two pools, one DCN handoff.

Colocated serving time-multiplexes prefill and decode on one mesh, so a
long prompt admission stalls every decoding request behind its chunked
dispatches.  A disaggregated cluster splits the device set instead — a
*prefill pool* ingests prompts and a *decode pool* generates — and pays
for the isolation with one inter-pool KV transfer per request, the
tightly-coupled-systems trade the paper's datapath model prices: the
handoff rides the slowest link in the hierarchy (the pod-to-pod DCN
path, ``copy_bound(REMOTE_HBM, HBM)``), so disaggregation wins exactly
when the per-request crossing costs less than the prefill interference
it removes.

Topology (:class:`Cluster`):

* **Pool split** — either explicit (``DisaggConfig.split``, or a
  ``pools=prefill:N,decode:M`` directive carried inside the policy
  string — see :func:`repro.core.placement.extract_pool_split`) or
  chosen by :func:`repro.core.planner.plan_pool_split`, which prices
  every split's prefill ingest rate against its decode generation rate
  and takes the one with the highest *bottleneck* tok/s (smallest
  inter-pool imbalance that fits both capacities).
* **Pool meshes** — each pool is a plain ``("data",)`` compute mesh over
  its own device slice (:func:`make_pool_mesh`); the ``donor_pod`` axis
  exists only on the *bridge* mesh the :class:`~repro.serve.handoff.
  Handoff` owns, so no pool can accidentally realize a remote placement.
* **Prefill side** (:class:`PrefillPool`) — a pool-tagged
  :class:`~repro.serve.engine.Executor` plus a private
  :class:`~repro.serve.state.SlotTable`.  Each admitted request is
  claimed, chunk-prefilled, its slot row extracted
  (:meth:`~repro.serve.engine.Executor.extract_slot`) and immediately
  published as a :class:`~repro.serve.handoff.HandoffTicket`; the slot
  frees for the next waiter, so prefill-pool slots recycle every tick.
* **Decode side** — an unmodified :class:`~repro.serve.scheduler.
  Server` on the decode mesh.  Finalized tickets enter through
  :meth:`~repro.serve.scheduler.Server.adopt_spilled` and ride the
  existing promotion machinery; nothing on the per-token path knows
  disaggregation exists.

Bit-identity: the ticket carries exactly the resume state a colocated
fresh admission would have left behind (``length = len(prompt) - 1``
cache positions filled, ``last_token = prompt[-1]`` feeding the first
decode step), the extract→publish→adopt→insert round trip is
bit-preserving, and the decode pool's mesh shape matches a colocated
reference's — so greedy tokens are bit-identical to the colocated path,
which the tests and ``tools/serve_disagg.py`` assert token-for-token.

Overlap: :meth:`Cluster.step` *issues* ticket adopts (asynchronous
device transfers), runs a decode step while the bytes are in flight,
then blocks in :meth:`~repro.serve.handoff.Handoff.finalize` — the
:class:`~repro.core.placement.DonorStream` double-buffering discipline
applied across requests, bounded by ``DisaggConfig.max_staged``.

Fault recovery (the ``handoff`` site): a lost ticket
(:class:`~repro.core.faults.TicketLossError`) or a transfer whose bytes
fail their publish-time checksum at finalize adopts **nothing** — the
request replays as fresh through the prefill pool from prompt plus
everything generated so far (bit-identical continuation, chunked
prefill ≡ decode replay).  Decode-side failures after adoption
(corrupted preemption spill, lost spill tier) route back the same way
through :attr:`~repro.serve.scheduler.Server.requeue_hook`.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.faults import (
    FaultPlan,
    SpillCorruptionError,
    TicketLossError,
)
from repro.core.hardware import MemoryTier
from repro.core.placement import (
    Placement,
    PlacementPolicy,
    PoolSplit,
    extract_pool_split,
)
from repro.core.planner import plan_pool_split
from repro.runtime.supervisor import WatchdogConfig
from repro.serve.engine import Executor
from repro.serve.handoff import Handoff, HandoffTicket, make_bridge_mesh
from repro.serve.sampling import GREEDY, SamplingParams
from repro.serve.scheduler import (
    QueueFullError,
    Request,
    ServeConfig,
    ServeHangError,
    Server,
)
from repro.serve.state import SlotTable

log = logging.getLogger("repro.serve.disagg")

__all__ = [
    "DisaggConfig",
    "Cluster",
    "PrefillPool",
    "make_pool_mesh",
]


def make_pool_mesh(devices) -> Mesh:
    """A pool's private compute mesh: 1-D ``("data",)`` over its device
    slice.  Deliberately donor-less — peer/remote tiers are not
    realizable inside a pool, so the only way KV can leave it is the
    bridge mesh the :class:`~repro.serve.handoff.Handoff` owns."""
    devs = np.asarray(list(devices), dtype=object)
    if devs.size == 0:
        raise ValueError("a pool needs at least one device")
    return Mesh(devs.reshape(-1), ("data",))


@dataclasses.dataclass
class DisaggConfig:
    """Cluster-level knobs; per-pool ``ServeConfig``\\ s are derived."""

    batch_slots: int = 8
    max_len: int = 512
    prefill_chunk: int = 32
    #: explicit device split (``PoolSplit`` or ``"prefill:N,decode:M"``);
    #: None defers to ``policy``'s embedded ``pools=`` directive, else to
    #: :func:`repro.core.planner.plan_pool_split`
    split: PoolSplit | str | None = None
    #: placement policy for *both* pools (any ``parse_policy`` spelling);
    #: a string may carry the ``pools=prefill:N,decode:M`` directive.
    #: None -> each pool consults the planner on its own mesh.
    policy: PlacementPolicy | str | dict | None = None
    rules: dict | None = None
    #: bound on cluster-level *waiting* requests (replay re-queues are
    #: recovery, not new load, and are exempt); None = unbounded
    max_queue: int | None = None
    #: decode-pool preemption (same semantics as ServeConfig)
    preempt: bool = False
    preempt_wait: int = 8
    verify_donation: bool = True
    #: one shared fault schedule: the ``handoff`` site fires in the
    #: Handoff, ``decode``/``spill``/... in the decode pool, and
    #: ``prefill``/``extract`` in the prefill pool
    faults: FaultPlan | None = None
    #: decode-pool step watchdog; None disables it
    watchdog: WatchdogConfig | None = dataclasses.field(
        default_factory=WatchdogConfig
    )
    #: handoff double-buffer depth: tickets adopted-but-not-finalized at
    #: once (DonorStream discipline across requests)
    max_staged: int = 2
    #: prefill-pool slot count (defaults to ``batch_slots``); slots
    #: recycle per tick, so this bounds prompts prefilled per step
    prefill_slots: int | None = None


class PrefillPool:
    """The prefill side: claim → chunked prefill → extract → free.

    A pool-tagged :class:`~repro.serve.engine.Executor` and a private
    :class:`~repro.serve.state.SlotTable`, with no scheduler: requests
    never *decode* here, so a slot's whole life is one :meth:`run` call
    and the table is empty between ticks.
    """

    def __init__(self, bundle, cfg: DisaggConfig, params, mesh, policy):
        slots = int(cfg.prefill_slots or cfg.batch_slots)
        self.cfg = ServeConfig(
            batch_slots=slots,
            max_len=cfg.max_len,
            prefill_chunk=cfg.prefill_chunk,
            policy=policy,
            rules=cfg.rules,
            verify_donation=cfg.verify_donation,
            faults=cfg.faults,
            watchdog=None,
            pool="prefill",
        )
        self.engine = Executor(bundle, self.cfg, params, mesh)
        self.table = SlotTable(slots)

    @property
    def capacity(self) -> int:
        """Prompts one :meth:`run` call can take."""
        return len(self.table.free_slots())

    def run(self, batch):
        """Prefill ``[(rid, prompt, sampling), ...]`` in one batched
        chunked-dispatch set and hand back publishable slot rows as
        ``[(rid, rows, length, last_token, sampling), ...]``.

        The rows are extracted onto pool-local HBM (the handoff's
        publish moves them to the bridge's remote tier); ``length`` is
        the cache fill a colocated admission would have left
        (``len(prompt) - 1`` — the last prompt token is withheld for
        the first decode step) and every slot frees before returning.
        """
        claimed = []
        free = self.table.free_slots()
        for rid, prompt, sampling in batch:
            i = free.pop(0)
            self.table.claim(i, rid, sampling)
            claimed.append((i, rid, prompt, sampling))
        self.engine.prefill(
            [(i, prompt) for i, _, prompt, _ in claimed], self.table
        )
        out = []
        for i, rid, prompt, sampling in claimed:
            rows = self.engine.extract_slot(
                i, Placement(MemoryTier.HBM)
            )
            out.append((
                rid, rows, int(self.table.lengths[i]),
                int(prompt[-1]), sampling,
            ))
            self.table.free(i)
        return out


class Cluster:
    """A disaggregated serve cluster: prefill pool → handoff → decode pool.

    The public surface mirrors :class:`~repro.serve.scheduler.Server`
    (``submit`` / ``add_request`` / ``step`` / ``run_until_done`` /
    ``has_work`` / ``stats``); internally every request flows::

        pending ──▶ PrefillPool.run ──▶ Handoff.publish (DCN, blocking)
                                              │ ticket
                  Handoff.adopt (async) ◀─────┘
                        │ overlapped with decode.step()
                  Handoff.finalize ──▶ decode.adopt_spilled ──▶ tokens

    and a handoff fault (lost ticket, corrupted transfer) re-enters the
    flow at ``pending`` with a replay prompt — nothing was adopted, so
    recovery is a plain re-submission the ledger records as ``lost``.
    """

    def __init__(self, bundle, cfg: DisaggConfig, params, devices=None):
        self.bundle = bundle
        self.cfg = cfg
        devs = list(devices) if devices is not None else list(jax.devices())
        split, policy = self._resolve_split(bundle, cfg, len(devs))
        if split.total > len(devs):
            raise ValueError(
                f"pool split {split.to_str()} needs {split.total} "
                f"device(s), only {len(devs)} available"
            )
        self.split = split
        pre = devs[: split.prefill]
        dec = devs[split.prefill : split.total]
        self.prefill_mesh = make_pool_mesh(pre)
        self.decode_mesh = make_pool_mesh(dec)
        #: the one cross-pool surface: a bridge mesh over both pools
        #: with the donor_pod axis on the pool boundary
        self.handoff = Handoff(
            bundle, make_bridge_mesh(pre, dec),
            faults=cfg.faults, max_staged=cfg.max_staged,
        )
        self.prefill = PrefillPool(
            bundle, cfg, params, self.prefill_mesh, policy
        )
        self.decode = Server(
            bundle,
            ServeConfig(
                batch_slots=cfg.batch_slots,
                max_len=cfg.max_len,
                prefill_chunk=cfg.prefill_chunk,
                policy=policy,
                rules=cfg.rules,
                preempt=cfg.preempt,
                preempt_wait=cfg.preempt_wait,
                verify_donation=cfg.verify_donation,
                faults=cfg.faults,
                verify_spills=bool(cfg.faults),
                watchdog=cfg.watchdog,
                pool="decode",
            ),
            params,
            mesh=self.decode_mesh,
        )
        # decode-side recovery (corrupted preemption spill, lost spill
        # tier) routes back here: the request replays through the
        # prefill pool instead of re-prefilling on the decode mesh
        self.decode.requeue_hook = self._take_back
        self._requests: dict[int, Request] = {}
        #: cluster wait queue, FIFO; replays re-enter at the head
        self._pending: list[int] = []
        self._replay: dict[int, np.ndarray] = {}
        #: published tickets awaiting an adopt slot
        self._tickets: list[HandoffTicket] = []
        #: rids with adopts issued but not finalized (<= max_staged)
        self._inflight: list[int] = []
        self._next_rid = 0
        self._counters = {"handoff_replays": 0, "peak_pending": 0}

    @staticmethod
    def _resolve_split(bundle, cfg: DisaggConfig, num_devices: int):
        """Explicit split > policy-embedded ``pools=`` directive >
        planner.  Returns ``(PoolSplit, pool policy with the directive
        removed)``."""
        split = cfg.split
        policy = cfg.policy
        if isinstance(split, str):
            split = PoolSplit.parse(split)
        if isinstance(policy, str):
            embedded, policy = extract_pool_split(policy)
            if embedded is not None:
                if split is not None and embedded != split:
                    raise ValueError(
                        f"conflicting pool splits: cfg.split="
                        f"{split.to_str()} vs policy directive "
                        f"{embedded.to_str()}"
                    )
                split = split or embedded
        if split is None:
            best, _ = plan_pool_split(
                bundle, num_devices,
                batch_slots=cfg.batch_slots, max_len=cfg.max_len,
                prefill_chunk=cfg.prefill_chunk,
            )
            split = PoolSplit(best.prefill_devices, best.decode_devices)
            log.info(
                "planner chose %s for %s (bottleneck %.3g tok/s, "
                "imbalance %.3gx)", split.to_str(), bundle.cfg.name,
                best.bottleneck_tps, best.imbalance,
            )
        return split, policy

    # -- introspection -----------------------------------------------------
    @property
    def ledger(self):
        """The handoff's crossing ledger (ground truth for "every
        admitted request's KV crossed donor_pod exactly once")."""
        return self.handoff.ledger

    @property
    def pending(self) -> int:
        return len(self._pending)

    def has_work(self) -> bool:
        return bool(
            self._pending or self._tickets or self._inflight
            or self.decode.has_work()
        )

    def stats(self) -> dict:
        """Cluster counters: the pool split, handoff ledger totals, and
        each pool's own counters nested under its name."""
        return {
            "split": self.split.to_str(),
            "pending": len(self._pending),
            "tickets_waiting": len(self._tickets),
            "tickets_inflight": len(self._inflight),
            **self._counters,
            "handoff": self.handoff.ledger.to_json(),
            "prefill_pool": dict(self.prefill.engine.counters),
            "decode_pool": self.decode.stats(),
        }

    def throughput(self) -> dict:
        """Per-pool token rates — what the pool-split planner predicted,
        measured."""
        pc = self.prefill.engine.counters
        out = self.decode.throughput()
        out["prefill_tokens"] = pc["prefill_tokens"]
        out["prefill_tps"] = (
            pc["prefill_tokens"] / pc["prefill_s"] if pc["prefill_s"]
            else 0.0
        )
        return out

    # -- request intake ----------------------------------------------------
    def add_request(self, req: Request) -> None:
        """Queue a request on the cluster (validation mirrors
        :meth:`repro.serve.scheduler.Server.add_request`; the bounded
        queue raises :class:`~repro.serve.scheduler.QueueFullError`)."""
        if req.rid < 0:
            raise ValueError(f"request rid must be >= 0, got {req.rid}")
        if req.rid in self._requests:
            raise ValueError(
                f"request {req.rid}: rid already live on the cluster"
            )
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}"
            )
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) >= self.cfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"does not fit max_len={self.cfg.max_len} "
                "(need len(prompt) < max_len)"
            )
        req.sampling.validate()
        if (
            self.cfg.max_queue is not None
            and len(self._pending) >= self.cfg.max_queue
        ):
            raise QueueFullError(
                f"request {req.rid}: cluster queue is full "
                f"({self.cfg.max_queue} waiting); retry after the "
                "prefill pool drains or raise DisaggConfig.max_queue"
            )
        req.submitted_s = time.perf_counter()
        self._requests[req.rid] = req
        self._pending.append(req.rid)
        self._counters["peak_pending"] = max(
            self._counters["peak_pending"], len(self._pending)
        )

    def add_requests(self, reqs) -> None:
        for req in reqs:
            self.add_request(req)

    def submit(
        self,
        prompt,
        *,
        max_new_tokens: int,
        sampling: SamplingParams = GREEDY,
        rid: int | None = None,
        on_token: Callable[[Request, int], None] | None = None,
    ) -> Request:
        """Build + queue a request with an auto-assigned free rid."""
        if rid is None:
            while self._next_rid in self._requests:
                self._next_rid += 1
            rid = self._next_rid
            self._next_rid += 1
        req = Request(
            rid=rid,
            prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens,
            sampling=sampling,
            on_token=on_token,
        )
        self.add_request(req)
        return req

    # -- recovery ----------------------------------------------------------
    def _take_back(self, rid: int, replay: np.ndarray) -> bool:
        """The decode server's ``requeue_hook``: reclaim a request whose
        decode-side state was lost (corrupted spill, lost spill tier) so
        its replay prefills through the *prefill* pool and re-adopts."""
        if rid not in self._requests:
            return False
        self._replay[rid] = np.asarray(replay, np.int32)
        self._pending.insert(0, rid)
        self._counters["handoff_replays"] += 1
        return True

    def _recover(self, rid: int, why: str) -> None:
        """Replay-as-fresh after a handoff fault: nothing was adopted,
        so re-enter the flow at the pending head with prompt + every
        token generated so far (bit-identical continuation)."""
        req = self._requests[rid]
        replay = np.asarray(req.prompt, np.int32)
        if req.out_tokens:
            replay = np.concatenate(
                [replay, np.asarray(req.out_tokens, np.int32)]
            )
        self._replay[rid] = replay
        self._pending.insert(0, rid)
        self._counters["handoff_replays"] += 1
        log.warning(
            "handoff for rid %d %s; replaying through the prefill pool",
            rid, why,
        )

    def _reap_pending_cancelled(self) -> None:
        """Finalize cancelled requests still waiting for prefill (after
        adoption the decode server's reaper owns them)."""
        for rid in list(self._pending):
            req = self._requests[rid]
            if not req.cancelled or req.done:
                continue
            self._pending.remove(rid)
            self._requests.pop(rid)
            self._replay.pop(rid, None)
            req.done = True
            req.finished_s = time.perf_counter()
            if req.on_token is not None:
                req.on_token(req, -1)

    # -- one cluster tick --------------------------------------------------
    def step(self) -> int:
        """Advance every stage of the pipeline once; returns the number
        of decode slots that generated a token.

        Stage order is the overlap schedule: adopts are *issued*
        (asynchronous transfers) before the decode step and *finalized*
        (blocked on, verified, admitted) after it — the DCN crossing
        hides behind generation, double-buffered up to ``max_staged``
        tickets, exactly the :class:`~repro.core.placement.DonorStream`
        window discipline.
        """
        self._reap_pending_cancelled()
        # 1. prefill + publish: fill up to the pool's slot capacity
        take = []
        while self._pending and len(take) < self.prefill.capacity:
            rid = self._pending.pop(0)
            req = self._requests[rid]
            take.append((
                rid, self._replay.pop(rid, req.prompt), req.sampling,
            ))
        if take:
            for rid, rows, length, last, sampling in self.prefill.run(take):
                self._tickets.append(self.handoff.publish(
                    rid, rows, length, last, sampling
                ))
        # 2. issue adopts (async DCN transfers, bounded staging)
        while self._tickets and self.handoff.staged < self.handoff.max_staged:
            ticket = self._tickets.pop(0)
            try:
                self.handoff.adopt(ticket, self.decode_mesh)
            except TicketLossError:
                self._recover(ticket.rid, "ticket lost in flight")
            else:
                self._inflight.append(ticket.rid)
        # 3. decode while the adopt bytes are in flight
        active = self.decode.step() if self.decode.has_work() else 0
        # 4. finalize: block, verify the crossing, admit (or replay)
        for rid in list(self._inflight):
            self._inflight.remove(rid)
            try:
                spilled = self.handoff.finalize(rid)
            except SpillCorruptionError as e:
                log.warning("%s", e)
                self._recover(rid, "transfer failed its checksum")
            else:
                self.decode.adopt_spilled(self._requests[rid], spilled)
        # drop finished requests from the cluster map (the decode server
        # already evicted its own bookkeeping when it freed the slot)
        for rid, req in list(self._requests.items()):
            if req.done:
                self._requests.pop(rid)
                self._replay.pop(rid, None)
        return active

    def run_until_done(self, max_steps: int = 10_000) -> None:
        """Drive :meth:`step` until nothing is live anywhere in the
        pipeline; raises :class:`~repro.serve.scheduler.ServeHangError`
        with full diagnostics if the budget is exhausted first."""
        for _ in range(max_steps):
            if not self.has_work():
                return
            self.step()
        if not self.has_work():
            return
        raise ServeHangError(
            f"disaggregated cluster did not drain within "
            f"max_steps={max_steps}",
            queue_depth=len(self._pending),
            live_rids=tuple(self._requests),
            stats={
                k: v for k, v in self.stats().items()
                if not isinstance(v, dict)
            },
        )
