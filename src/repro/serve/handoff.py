"""Handoff: the DCN crossing of a disaggregated prefill/decode cluster.

A disaggregated cluster (:mod:`repro.serve.disagg`) splits the device set
into a prefill pool and a decode pool.  The KV a prefill Executor fills
for one request must physically move to the decode pool before generation
can start — the inter-pool transfer the paper's successors price on the
slowest link in the system, the pod-to-pod DCN path.  This module is the
**only** place that transfer may happen (the ``cross-pool-device-put``
lint rule pins every other serve module to its pool-local mesh):

* :func:`make_bridge_mesh` — a mesh over *all* devices, prefill pool
  first, whose leading axis is :data:`~repro.core.placement.
  REMOTE_DONOR_AXIS` (``donor_pod``).  With equal pools the axis has size
  2 — slice 0 is the prefill pool, slice 1 the decode pool — so a tensor
  realized on :attr:`~repro.core.hardware.MemoryTier.REMOTE_HBM` is
  sharded *across the pool boundary*: publishing and adopting each move
  half its bytes over the inter-pool link, and together every byte
  crosses ``donor_pod`` exactly once in each direction.
* :class:`HandoffTicket` — the unit of handoff: one request's filled KV
  rows parked on the bridge's remote tier, plus the resume state a
  decode-side admission needs (deliberately shaped like
  :class:`~repro.serve.state.SpilledSequence`, so the decode Server's
  promotion machinery — insert + resume + checksum verification — is
  reused unchanged).
* :class:`Handoff` — publish/adopt over a bridge-mesh
  :class:`repro.api.Runtime` pinned to ``kv_remote_hbm``.  ``publish``
  realizes the rows onto the remote tier (``Runtime.realize``);
  ``adopt`` pulls them back to local HBM via donation-aware
  :meth:`repro.api.Runtime.migrate_roles` — the ticket's remote buffer
  is freed as the copy lands, and a faulted adopt adopts nothing — then
  re-commits them onto the decode pool's mesh.  Both ends are priced
  against the calibrated ``copy_bound(REMOTE_HBM, HBM)`` DCN bound and
  recorded in the :class:`HandoffLedger`.
* Overlap — ``adopt`` only *issues* the (asynchronous) transfers; the
  cluster runs a decode step before blocking on the bytes
  (:meth:`Handoff.finalize`), the :class:`~repro.core.placement.
  DonorStream` double-buffering discipline applied across tickets
  instead of layer windows.  ``max_staged`` bounds the in-flight tickets
  exactly like ``DonorStream.depth`` bounds staged windows.

Fault sites: ``handoff`` fires once per adopt.  ``TICKET_LOSS`` raises
:class:`~repro.core.faults.TicketLossError` (the ticket vanished on the
DCN path — nothing was adopted); ``SPILL_CORRUPT`` perturbs the bytes in
flight so the park-time checksum fails at :meth:`finalize`.  Both recover
by replaying the request as fresh through the prefill pool (see
``disagg.Cluster``) — bit-identical continuation, because chunked prefill
≡ decode replay.

Crossing accounting lives in the :class:`HandoffLedger`, not
``Runtime.audit``: the HLO audit sees compiled modules, and these
transfers are ``device_put`` reshards outside any jit — so the ledger is
the ground truth for "every admitted request's KV crossed ``donor_pod``
exactly once", and what ``tools/serve_disagg.py`` turns into
``BENCH_disagg.json``'s measured-bandwidth-vs-calibrated-bound rows.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api import Runtime
from repro.core.faults import (
    FaultKind,
    checksum_tree,
    corrupt_tree,
    verify_spill,
)
from repro.core.hardware import MemoryTier
from repro.core.placement import REMOTE_DONOR_AXIS, Placement, Role
from repro.serve.sampling import SamplingParams
from repro.serve.state import SpilledSequence

__all__ = [
    "HandoffTicket",
    "HandoffLedger",
    "Handoff",
    "make_bridge_mesh",
    "tree_nbytes",
]


def make_bridge_mesh(prefill_devices, decode_devices) -> Mesh:
    """Mesh over both pools with a leading ``donor_pod`` axis.

    Device order is prefill pool first, then decode pool.  With equal
    pools the ``donor_pod`` axis has size 2 and its slice boundary *is*
    the pool boundary — a ``REMOTE_HBM`` tensor shards half its bytes
    into each pool, so one publish + one adopt moves every byte across
    the inter-pool link exactly once each way.  Unequal pools fall back
    to sharding across all devices (axis size = device count); the
    crossing accounting is unchanged, only the per-device shard sizes
    differ.
    """
    pre = list(prefill_devices)
    dec = list(decode_devices)
    if not pre or not dec:
        raise ValueError(
            f"bridge mesh needs both pools non-empty, got "
            f"{len(pre)} prefill / {len(dec)} decode device(s)"
        )
    devs = np.asarray(pre + dec, dtype=object)
    if len(pre) == len(dec):
        devs = devs.reshape(2, len(pre))
    else:
        devs = devs.reshape(len(devs), 1)
    return Mesh(devs, (REMOTE_DONOR_AXIS, "data"))


def tree_nbytes(tree) -> int:
    """Total buffer bytes of a pytree's leaves."""
    return int(sum(leaf.nbytes for leaf in jax.tree.leaves(tree)))


@dataclasses.dataclass
class HandoffTicket:
    """One request's prefilled KV, published for a decode pool to adopt.

    ``rows`` live on the bridge mesh's ``kv_remote_hbm`` placement
    (donor_pod-sharded across the pool boundary) from publish until
    adopt, when the transfer donates them away.  The resume fields mirror
    :class:`~repro.serve.state.SpilledSequence` so
    :meth:`to_spilled` hands the decode Server a record its existing
    promotion path (checksum verify → insert → resume) consumes as-is.
    """

    rid: int
    rows: object                 # slot-row pytree on the bridge remote tier
    length: int                  # cache fill at publish (len(prompt) - 1)
    last_token: int              # prompt[-1]: the first decode step's input
    sampling: SamplingParams
    #: checksum_tree() of the rows *before* the publish crossing; adopt
    #: verifies the far side of the round trip against it
    checksum: float | None
    nbytes: int = 0
    publish_s: float = 0.0       # measured publish transfer (blocking)
    adopt_s: float = 0.0         # measured un-overlapped adopt tail
    bound_s: float = 0.0         # calibrated one-way copy_bound price

    def to_spilled(self, rows) -> SpilledSequence:
        """The decode-side admission record, carrying ``rows`` already
        committed to the decode pool's mesh."""
        return SpilledSequence(
            rid=self.rid,
            rows=rows,
            length=self.length,
            last_token=self.last_token,
            sampling=self.sampling,
            since_tick=0,
            tier=MemoryTier.REMOTE_HBM,
            checksum=self.checksum,
        )


class HandoffLedger:
    """Per-request crossing accounting for the donor_pod tier.

    ``Runtime.audit`` reads compiled HLO; handoff transfers are
    ``device_put`` reshards outside any jit, so the ledger — not the
    audit — answers "did this rid's KV cross exactly once?".  Every
    publish/adopt appends a record with measured seconds next to the
    calibrated DCN bound, which is what the soak's
    ``BENCH_disagg.json`` summarizes.
    """

    def __init__(self):
        self.publishes: dict[int, int] = {}
        self.adopts: dict[int, int] = {}
        self.lost: dict[int, int] = {}
        self.records: list[dict] = []

    def record(self, event: str, rid: int, nbytes: int,
               seconds: float, bound_s: float) -> None:
        counter = {"publish": self.publishes, "adopt": self.adopts,
                   "lost": self.lost}[event]
        counter[rid] = counter.get(rid, 0) + 1
        self.records.append({
            "event": event,
            "rid": int(rid),
            "nbytes": int(nbytes),
            "seconds": float(seconds),
            "bound_s": float(bound_s),
        })

    def crossings(self, rid: int) -> int:
        """Completed publish→adopt round trips for ``rid``."""
        return self.adopts.get(rid, 0)

    def total_bytes(self, event: str = "publish") -> int:
        return sum(
            r["nbytes"] for r in self.records if r["event"] == event
        )

    def to_json(self) -> dict:
        return {
            "published": sum(self.publishes.values()),
            "adopted": sum(self.adopts.values()),
            "lost": sum(self.lost.values()),
            "bytes_published": self.total_bytes("publish"),
            "bytes_adopted": self.total_bytes("adopt"),
            "records": list(self.records),
        }


class Handoff:
    """Publish/adopt KV slot rows across the pool boundary.

    Owns a :class:`repro.api.Runtime` over the bridge mesh, pinned to
    the registered ``kv_remote_hbm`` policy — construction therefore
    validates up front that the bridge really has a ``donor_pod`` axis
    (a bridge that cannot realize the remote tier must never silently
    publish into local memory).  ``faults`` is the cluster's shared
    :class:`~repro.core.faults.FaultPlan`; the ``handoff`` site fires
    once per adopt.
    """

    def __init__(self, bundle, bridge_mesh: Mesh, *, faults=None,
                 system=None, max_staged: int = 2):
        self.mesh = bridge_mesh
        self.rt = Runtime(bundle, bridge_mesh, "kv_remote_hbm",
                          system=system)
        self._remote = self.rt.policy
        self._local = self.rt.policy.with_placement(
            Role.KV_CACHE, Placement(MemoryTier.HBM)
        ).renamed("handoff_adopt_hbm")
        self.faults = faults
        self.ledger = HandoffLedger()
        #: DonorStream-style staging bound: at most this many adopted
        #: tickets may be in flight (issued, not yet finalized) at once
        self.max_staged = max(int(max_staged), 2)
        #: rid -> (ticket, rows, issue wall-clock stamp)
        self._staged: dict[int, tuple[HandoffTicket, object, float]] = {}

    # -- pricing -----------------------------------------------------------
    def bound_s(self, nbytes: int) -> float:
        """Calibrated one-way DCN price for ``nbytes`` (the
        ``copy_bound(REMOTE_HBM, HBM)`` 'dcn' term the soak compares
        measured transfers against)."""
        return self.rt.price_copy(
            nbytes, MemoryTier.HBM, src=MemoryTier.REMOTE_HBM
        )

    # -- prefill side ------------------------------------------------------
    def publish(self, rid: int, rows, length: int, last_token: int,
                sampling: SamplingParams) -> HandoffTicket:
        """Park one request's filled KV rows on the bridge's remote tier.

        ``rows`` arrive on the prefill pool's mesh (one extracted slot
        row per cache leaf); they are checksummed *first* — the stamp
        travels with the ticket and the adopt side verifies the full
        round trip against it — then realized donor_pod-sharded.
        Blocking: the measured ``publish_s`` is an honest transfer time,
        the publish half of the BENCH bandwidth row.
        """
        checksum = checksum_tree(rows)
        nbytes = tree_nbytes(rows)
        t0 = time.perf_counter()
        self.rt.policy = self._remote
        remote_rows = self.rt.realize(rows, Role.KV_CACHE)
        jax.block_until_ready(remote_rows)
        dt = time.perf_counter() - t0
        bound = self.bound_s(nbytes)
        self.ledger.record("publish", rid, nbytes, dt, bound)
        return HandoffTicket(
            rid=rid, rows=remote_rows, length=length,
            last_token=last_token, sampling=sampling,
            checksum=checksum, nbytes=nbytes,
            publish_s=dt, bound_s=bound,
        )

    # -- decode side -------------------------------------------------------
    @property
    def staged(self) -> int:
        """Tickets issued but not yet finalized."""
        return len(self._staged)

    def adopt(self, ticket: HandoffTicket, target_mesh: Mesh) -> None:
        """Issue the adopt transfers for ``ticket`` (non-blocking).

        Fires the ``handoff`` fault site (a ``TICKET_LOSS`` event raises
        :class:`~repro.core.faults.TicketLossError` before any transfer
        — nothing is adopted and the remote rows are dropped; a
        ``SPILL_CORRUPT`` event perturbs the bytes so :meth:`finalize`'s
        checksum verification catches the transfer).  The DCN crossing
        itself is donation-aware :meth:`repro.api.Runtime.migrate_roles`
        over the bridge runtime — remote → local HBM, the ticket's
        donor-sharded buffer freed as the copy lands — followed by a
        re-commit onto the decode pool's own mesh.  Both device_puts are
        asynchronous: the caller overlaps a decode step before blocking
        in :meth:`finalize` (double buffering across tickets, bounded by
        ``max_staged``).
        """
        if len(self._staged) >= self.max_staged:
            raise RuntimeError(
                f"handoff staging full ({self.max_staged} tickets in "
                "flight); finalize() before adopting more"
            )
        if self.faults:
            try:
                ev = self.faults.check("handoff", rid=ticket.rid)
            except Exception:
                nb = ticket.nbytes
                self.ledger.record("lost", ticket.rid, nb, 0.0,
                                   self.bound_s(nb))
                raise
        else:
            ev = None
        t0 = time.perf_counter()
        trees = {Role.KV_CACHE: ticket.rows}
        self.rt.policy = self._remote
        self.rt.migrate_roles(trees, self._local)
        rows = trees[Role.KV_CACHE]
        if ev is not None and ev.kind is FaultKind.SPILL_CORRUPT:
            rows = corrupt_tree(rows)
        # the bridge-local result is replicated over every device, so
        # this re-commit onto the decode pool's mesh moves no new bytes
        # — it only narrows the device set the insert jit may address
        rows = jax.device_put(rows, NamedSharding(target_mesh, P()))
        self._staged[ticket.rid] = (ticket, rows, t0)

    def finalize(self, rid: int) -> SpilledSequence:
        """Block on an issued adopt and hand back the admission record.

        Verifies the round trip against the publish-time checksum
        (:class:`~repro.core.faults.SpillCorruptionError` on mismatch —
        the staged rows are dropped and nothing was admitted).  The
        recorded ``adopt_s`` is the *un-overlapped* tail: wall time from
        issue to ready minus whatever the caller overlapped it with.
        """
        ticket, rows, t0 = self._staged.pop(rid)
        jax.block_until_ready(rows)
        dt = time.perf_counter() - t0
        try:
            verify_spill(rows, ticket.checksum, rid)
        except Exception:
            self.ledger.record("lost", rid, ticket.nbytes, dt,
                               ticket.bound_s)
            raise
        ticket.adopt_s = dt
        self.ledger.record("adopt", rid, ticket.nbytes, dt,
                           ticket.bound_s)
        return ticket.to_spilled(rows)

    def drop(self, rid: int) -> None:
        """Discard a staged adopt (cluster-side recovery bookkeeping)."""
        self._staged.pop(rid, None)
