"""Batched serving engine: continuous batching over prefill/decode steps.

The serving loop is the paper's Fig. 17 workload industrialized: per decoded
token, every parameter byte and every cache byte crosses the compute
datapath once.  The engine owns (a) slot-based continuous batching — new
requests claim free batch rows, finished rows free them — and (b) the KV
placement policy: when ``ServeConfig.policy`` is ``None`` the engine builds
a decode :class:`~repro.core.planner.WorkloadProfile` from the model config
and asks :func:`repro.core.planner.plan` for the fastest policy that fits
every memory pool (logging each prediction and the pick); under ``kv_host``
the cache shardings carry the host memory kind and stream through PCIe each
step.  Host tiers are only offered to the planner when the backend exposes
them (:func:`host_available`); peer/remote tiers are analysis-level until a
donor mesh axis realizes them, so the auto pick never selects one.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import (
    POLICIES,
    PlacementPolicy,
    Role,
    host_available,
)
from repro.core.planner import plan
from repro.models.model_zoo import ModelBundle
from repro.models.sharding import defs_to_specs, use_sharding

log = logging.getLogger("repro.serve.engine")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 512
    #: None -> consult the placement planner (datapath-bound model)
    policy: PlacementPolicy | None = None
    rules: dict | None = None


def plan_serve_policy(
    bundle: ModelBundle,
    cfg: ServeConfig,
    num_chips: int = 1,
    *,
    realizable: bool = True,
) -> PlacementPolicy:
    """Planner-selected policy for this server's decode workload.

    ``realizable=False`` (no mesh: the server cannot re-place anything)
    restricts the pick to the default placement.  Peer/remote tiers are
    analysis-level for now: the engine has no donor mesh axis, so a
    device_put under those policies would land in *local* HBM — never let
    the auto pick choose a placement the runtime would silently realize as
    hbm_resident (and then OOM where the planner predicted a fit).
    Forcing any policy via ``ServeConfig.policy`` remains possible.
    """
    from repro.configs import ShapeSpec

    shape = ShapeSpec("serve", cfg.max_len, cfg.batch_slots, "decode")
    prof = bundle.decode_workload(shape, num_chips=num_chips)
    candidates = None if realizable else [POLICIES["hbm_resident"]]
    best, preds = plan(
        prof,
        candidates,
        allow_host=host_available(),
        allow_peer=False,
        allow_remote=False,
    )
    for p in preds:
        log.info("planner: %s", p.explain())
    log.info(
        "planner picked %s for %s (%d slots x %d ctx)",
        best.policy, bundle.cfg.name, cfg.batch_slots, cfg.max_len,
    )
    return POLICIES[best.policy]


class Server:
    """Single-model continuous-batching server (greedy decoding)."""

    def __init__(self, bundle: ModelBundle, cfg: ServeConfig, params, mesh=None):
        self.bundle = bundle
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        num_chips = int(mesh.devices.size) if mesh is not None else 1
        self.policy = cfg.policy or plan_serve_policy(
            bundle, cfg, num_chips, realizable=mesh is not None
        )
        self._requests: dict[int, Request] = {}
        self._slots: list[int | None] = [None] * cfg.batch_slots
        self._lengths = np.zeros(cfg.batch_slots, np.int32)
        self._caches = bundle.init_cache(cfg.batch_slots, cfg.max_len)
        if mesh is not None:
            # realize the policy for every role the server owns: the KV
            # cache AND the params (weights_stream keeps params host-side)
            cache_defs = bundle.cache_defs(cfg.batch_slots, cfg.max_len)
            kind = self.policy.memory_kind(Role.KV_CACHE)
            specs = defs_to_specs(cache_defs, mesh, cfg.rules, memory_kind=kind)
            self._caches = jax.tree.map(jax.device_put, self._caches, specs)
            param_specs = defs_to_specs(
                bundle.param_defs(), mesh, cfg.rules,
                memory_kind=self.policy.memory_kind(Role.PARAMS),
            )
            self.params = jax.tree.map(jax.device_put, self.params, param_specs)
        self._decode = jax.jit(
            lambda p, b, c: bundle.decode_step(p, b, c)
        )
        self._pending: list[Request] = []

    # -- request lifecycle -------------------------------------------------
    def add_request(self, req: Request) -> None:
        self._requests[req.rid] = req
        self._pending.append(req)

    def _admit(self) -> None:
        """Prefill pending requests into free slots (one at a time here;
        a production build would batch same-length prefills)."""
        for i, slot in enumerate(self._slots):
            if slot is not None or not self._pending:
                continue
            req = self._pending.pop(0)
            # feed prompt[:-1]; the LAST prompt token is fed by the first
            # step() so its logits produce the first generated token
            # (matching the prefill-then-decode contract).
            L = len(req.prompt) - 1
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            # single-row prefill via decode steps over the prompt
            # (keeps cache row-isolated; row-sliced prefill is an
            #  optimization lever documented in EXPERIMENTS.md)
            for t in range(L):
                row_tok = jnp.zeros(
                    (self.cfg.batch_slots, 1), jnp.int32
                ).at[i, 0].set(toks[0, t])
                lens = jnp.asarray(self._lengths, jnp.int32)
                _, self._caches = self._decode(
                    self.params,
                    {"tokens": row_tok, "lengths": lens},
                    self._caches,
                )
                self._lengths[i] += 1
            self._slots[i] = req.rid

    # -- one decode tick -----------------------------------------------------
    def step(self) -> int:
        """Admit + decode one token for every active slot. Returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return 0
        last_tokens = np.zeros((self.cfg.batch_slots, 1), np.int32)
        for i in active:
            req = self._requests[self._slots[i]]
            seq = list(req.prompt) + req.out_tokens
            last_tokens[i, 0] = seq[-1]
        logits, self._caches = self._decode(
            self.params,
            {
                "tokens": jnp.asarray(last_tokens),
                "lengths": jnp.asarray(self._lengths),
            },
            self._caches,
        )
        next_tokens = np.asarray(jnp.argmax(logits, -1))
        for i in active:
            req = self._requests[self._slots[i]]
            req.out_tokens.append(int(next_tokens[i]))
            self._lengths[i] += 1
            if (
                len(req.out_tokens) >= req.max_new_tokens
                or self._lengths[i] >= self.cfg.max_len - 1
            ):
                req.done = True
                self._slots[i] = None
                self._lengths[i] = 0
        return len(active)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self._pending and all(s is None for s in self._slots):
                return
            self.step()
        raise RuntimeError("serve loop did not drain")
