"""Batched serving engine: continuous batching over prefill/decode steps.

The serving loop is the paper's Fig. 17 workload industrialized: per decoded
token, every parameter byte and every cache byte crosses the compute
datapath once.  The engine owns (a) slot-based continuous batching — new
requests claim free batch rows, finished rows free them — and (b) the KV
placement policy: when ``ServeConfig.policy`` is ``None`` the engine builds
a decode :class:`~repro.core.planner.WorkloadProfile` from the model config
and asks :func:`repro.core.planner.plan` for the fastest policy that fits
every memory pool (logging each prediction and the pick); under ``kv_host``
the cache shardings carry the host memory kind and stream through PCIe each
step.  Tiers are offered to the planner exactly when this runtime realizes
them: host tiers when the backend exposes a distinct host memory space
(:func:`host_available`), peer tiers (``kv_peer_hbm``,
``weights_peer_hbm``, ``opt_peer_host``) when the mesh has a ``donor``
axis, and ``kv_remote_hbm`` when it has a ``donor_pod`` axis — under a
donor mesh the auto pick may (and with the cache out of local headroom,
will) choose a peer tier, and the engine realizes it by sharding the
role's tensors across the donor slices
(:func:`repro.models.sharding.policy_specs`).  A forced
``ServeConfig.policy`` that names a peer/remote tier on a donor-less mesh
raises :class:`repro.core.placement.DonorAxisError` instead of silently
serving from local HBM.
"""

from __future__ import annotations

import dataclasses
import logging
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import (
    POLICIES,
    PlacementPolicy,
    Role,
    donor_allow_flags,
    validate_policy_for_mesh,
)
from repro.core.planner import plan
from repro.models.model_zoo import ModelBundle
from repro.models.sharding import policy_specs

log = logging.getLogger("repro.serve.engine")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 512
    #: None -> consult the placement planner (datapath-bound model)
    policy: PlacementPolicy | None = None
    rules: dict | None = None


def plan_serve_policy(
    bundle: ModelBundle,
    cfg: ServeConfig,
    num_chips: int = 1,
    *,
    mesh=None,
) -> PlacementPolicy:
    """Planner-selected policy for this server's decode workload.

    With ``mesh=None`` the server cannot re-place anything, so the pick is
    restricted to the default placement.  With a mesh, the candidate tiers
    are exactly the ones this runtime realizes
    (:func:`repro.core.placement.donor_allow_flags`): host tiers when the
    backend has a host memory space, peer/remote tiers when the mesh has
    the ``donor``/``donor_pod`` axis that physically holds their bytes —
    so the auto pick never chooses a placement the engine would have to
    silently realize as ``hbm_resident``.  When nothing fits, the
    least-HBM policy is returned and the per-pool overflow is logged (the
    OOM report the operator acts on).  Forcing any policy via
    ``ServeConfig.policy`` remains possible.
    """
    from repro.configs import ShapeSpec

    shape = ShapeSpec("serve", cfg.max_len, cfg.batch_slots, "decode")
    prof = bundle.decode_workload(shape, num_chips=num_chips)
    candidates = None if mesh is not None else [POLICIES["hbm_resident"]]
    best, preds = plan(prof, candidates, **donor_allow_flags(mesh))
    for p in preds:
        log.info("planner: %s", p.explain())
    if not best.fits:
        for p in preds:
            log.warning(
                "planner OOM: %s overflows pools %s",
                p.policy, ", ".join(p.overflow_pools) or "none",
            )
    log.info(
        "planner picked %s for %s (%d slots x %d ctx)",
        best.policy, bundle.cfg.name, cfg.batch_slots, cfg.max_len,
    )
    return POLICIES[best.policy]


class Server:
    """Single-model continuous-batching server (greedy decoding)."""

    def __init__(self, bundle: ModelBundle, cfg: ServeConfig, params, mesh=None):
        self.bundle = bundle
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        num_chips = int(mesh.devices.size) if mesh is not None else 1
        self.policy = cfg.policy or plan_serve_policy(
            bundle, cfg, num_chips, mesh=mesh
        )
        # A forced peer/remote policy needs the donor axis that realizes
        # it — refuse up front rather than serving from local HBM.
        validate_policy_for_mesh(self.policy, mesh)
        self._requests: dict[int, Request] = {}
        self._slots: list[int | None] = [None] * cfg.batch_slots
        self._lengths = np.zeros(cfg.batch_slots, np.int32)
        self._caches = bundle.init_cache(cfg.batch_slots, cfg.max_len)
        cache_specs = None
        if mesh is not None:
            # realize the policy for every role the server owns: the KV
            # cache AND the params (weights_stream keeps params host-side;
            # kv_peer_hbm/weights_peer_hbm shard across the donor slices)
            cache_defs = bundle.cache_defs(cfg.batch_slots, cfg.max_len)
            cache_specs = policy_specs(
                cache_defs, mesh, cfg.rules, Role.KV_CACHE, self.policy
            )
            self._caches = jax.tree.map(
                jax.device_put, self._caches, cache_specs
            )
            param_specs = policy_specs(
                bundle.param_defs(), mesh, cfg.rules, Role.PARAMS, self.policy
            )
            self.params = jax.tree.map(jax.device_put, self.params, param_specs)
        self._decode = jax.jit(
            lambda p, b, c: bundle.decode_step(p, b, c),
            # pin the returned cache to its realized placement so a donor
            # or host placement survives across steps instead of drifting
            # to whatever layout XLA prefers for the first output
            **({} if cache_specs is None
               else {"out_shardings": (None, cache_specs)}),
        )
        self._pending: list[Request] = []

    # -- request lifecycle -------------------------------------------------
    def add_request(self, req: Request) -> None:
        """Queue a request, validating it against the cache extent.

        Prefill writes ``len(prompt) - 1`` cache positions and the decode
        loop at least one more, so a prompt only fits when ``len(prompt) <
        max_len``.  Admitting a longer one would advance ``_lengths`` past
        the cache and silently clamp/corrupt KV writes — reject it here,
        logged, before it ever claims a slot.
        """
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) >= self.cfg.max_len:
            log.warning(
                "rejecting request %d: prompt of %d tokens needs "
                "len(prompt)+1 cache positions but max_len=%d",
                req.rid, len(req.prompt), self.cfg.max_len,
            )
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"does not fit max_len={self.cfg.max_len} "
                "(need len(prompt) < max_len)"
            )
        self._requests[req.rid] = req
        self._pending.append(req)

    def _admit(self) -> None:
        """Prefill pending requests into free slots (one at a time here;
        a production build would batch same-length prefills)."""
        for i, slot in enumerate(self._slots):
            if slot is not None or not self._pending:
                continue
            req = self._pending.pop(0)
            # feed prompt[:-1]; the LAST prompt token is fed by the first
            # step() so its logits produce the first generated token
            # (matching the prefill-then-decode contract).
            L = len(req.prompt) - 1
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            # single-row prefill via decode steps over the prompt
            # (keeps cache row-isolated; row-sliced prefill is an
            #  optimization lever documented in EXPERIMENTS.md)
            for t in range(L):
                row_tok = jnp.zeros(
                    (self.cfg.batch_slots, 1), jnp.int32
                ).at[i, 0].set(toks[0, t])
                _, self._caches = self._decode(
                    self.params,
                    {"tokens": row_tok, "lengths": self._lengths_dev()},
                    self._caches,
                )
                self._lengths[i] += 1
            self._slots[i] = req.rid

    def _lengths_dev(self) -> jnp.ndarray:
        """Device copy of the per-slot lengths.

        Must COPY: ``jnp.asarray`` of a numpy array can be zero-copy (CPU
        backend), aliasing ``_lengths``'s buffer into the asynchronously
        dispatched decode — a subsequent ``_lengths[i] += 1`` then races
        the device read and corrupts the step's masking/cache writes.
        """
        return jnp.array(self._lengths, jnp.int32)

    def _free_slot(self, i: int) -> None:
        """The single place a slot returns to the pool: clears the slot
        assignment and its cache length together (stale cache rows beyond
        the zeroed length are masked out and overwritten by next prefill)."""
        self._slots[i] = None
        self._lengths[i] = 0

    # -- one decode tick -----------------------------------------------------
    def step(self) -> int:
        """Admit + decode one token for every active slot. Returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return 0
        last_tokens = np.zeros((self.cfg.batch_slots, 1), np.int32)
        for i in active:
            req = self._requests[self._slots[i]]
            seq = list(req.prompt) + req.out_tokens
            last_tokens[i, 0] = seq[-1]
        logits, self._caches = self._decode(
            self.params,
            {
                "tokens": jnp.asarray(last_tokens),
                "lengths": self._lengths_dev(),
            },
            self._caches,
        )
        next_tokens = np.asarray(jnp.argmax(logits, -1))
        for i in active:
            req = self._requests[self._slots[i]]
            req.out_tokens.append(int(next_tokens[i]))
            self._lengths[i] += 1
            if (
                len(req.out_tokens) >= req.max_new_tokens
                or self._lengths[i] >= self.cfg.max_len - 1
            ):
                req.done = True
                self._free_slot(i)
        return len(active)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self._pending and all(s is None for s in self._slots):
                return
            self.step()
        raise RuntimeError("serve loop did not drain")
