"""Executor: the jitted device half of the serve stack.

The serve package is layered (see ``docs/serving.md``):

* :mod:`repro.serve.state` — slot/sequence host mirrors + device state,
  upload discipline;
* :mod:`repro.serve.sampling` — per-request temperature/top-k/top-p/
  seeds/stop tokens, computed in-jit;
* :mod:`repro.serve.scheduler` — the continuous-batching front end
  (request queue, admission ordering, streaming callbacks, planner-priced
  KV preemption) and the public :class:`~repro.serve.scheduler.Server`;
* this module — the **executor**: it owns the params, the KV cache, the
  :class:`repro.api.Runtime` (mesh + policy + planner), and every jitted
  dispatch.  Nothing here knows about requests or queues; it moves
  batches of tokens and cache rows.

The hot path keeps the zero-copy discipline of the Fig. 17 rework —
per decoded token every parameter byte and cache byte crosses the
compute datapath exactly once:

* **Donated caches** — decode/prefill jits donate the cache pytree
  (gated per policy by ``donation_compatible``; ``Strategy.STREAM``
  placements keep their far-tier resident buffer undonated), with
  ``Runtime.specs``-pinned ``out_shardings`` so donor/host placements
  survive the aliasing across steps.
* **Chunked batched prefill** — admission writes whole prompt chunks for
  all newly claimed slots per :meth:`ModelBundle.prefill_at` dispatch, so
  a batch of length-L prompts costs O(L / prefill_chunk) dispatches.
  Encoder-decoder bundles now take this path too
  (:func:`~repro.models.encdec.encdec_prefill_at`); only a bundle whose
  ``prefill_at`` raises ``NotImplementedError`` falls back to the O(B·L)
  decode-step replay — warned once and counted
  (``decode_replay_prefills``) instead of silent.
* **On-device serve state** — lengths/last-token/active *and the
  per-slot sampling parameters* live in a device state dict carried
  through the jitted step; sampling + stop detection happen in-jit, and
  the only per-step host↔device traffic is one packed ``(2, B)``
  next-token/stopped vector fetched back.
* **Slot extract/insert** — preemption's device half: one jitted
  ``dynamic_slice`` pulls a victim's cache rows out (then parked on the
  planner-priced spill tier), one jitted ``dynamic_update_slice`` puts
  them back on promotion.  Both preserve the pinned cache placement.

:meth:`Executor.replan` re-places the live cache/params mid-serve via
:meth:`repro.api.Runtime.migrate` and rebuilds the jits (donation flags
and pinned out_shardings are placement-dependent); decode output is
bit-identical across the move.
"""

from __future__ import annotations

import logging
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api import Runtime
from repro.core.faults import TransientFault
from repro.core.placement import Placement, PlacementPolicy, Role, parse_policy
from repro.runtime.retry import MIGRATION_RETRY, retry_call
from repro.serve import sampling as sampling_mod
from repro.serve.state import idle_device_state, upload

log = logging.getLogger("repro.serve.engine")


class Executor:
    """Jitted decode/prefill/extract/insert steps over one model bundle.

    ``cfg`` is the scheduler's ``ServeConfig`` (duck-typed: only the
    shape/policy fields are read here).  The executor owns ``params``,
    ``caches`` and the :class:`repro.api.Runtime`; the scheduler owns
    requests, slots, and the device state dict it threads through
    :meth:`decode`.
    """

    def __init__(self, bundle, cfg, params, mesh=None):
        self.bundle = bundle
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        # The Runtime facade owns mesh + policy + planner.  A forced
        # peer/remote policy on a donor-less mesh raises DonorAxisError
        # here, up front, rather than serving from local HBM.
        if cfg.policy is not None:
            self.rt = Runtime(bundle, mesh, cfg.policy, rules=cfg.rules)
        else:
            self.rt = Runtime.auto(
                bundle, mesh, phase="serve", rules=cfg.rules,
                batch_slots=cfg.batch_slots, max_len=cfg.max_len,
                prefill_chunk=cfg.prefill_chunk,
            )
            log.info(
                "planner picked %s for %s (%d slots x %d ctx, prefill "
                "chunk %d)", self.rt.policy.name, bundle.cfg.name,
                cfg.batch_slots, cfg.max_len, cfg.prefill_chunk,
            )
        # injected-fault schedule (ServeConfig.faults): lives on the
        # Runtime so migrate()/realize() sites and the executor's
        # dispatch sites consult one plan; NO_FAULTS default costs one
        # truthiness test per site
        faults = getattr(cfg, "faults", None)
        if faults:
            self.rt.faults = faults
        self.caches = bundle.init_cache(cfg.batch_slots, cfg.max_len)
        if mesh is not None:
            # realize the policy for every role the executor owns: the KV
            # cache AND the params (weights_stream keeps params host-side;
            # kv_peer_hbm/weights_peer_hbm shard across the donor slices)
            self.caches = self.rt.realize(
                self.caches, Role.KV_CACHE, self._cache_defs()
            )
            self.params = self.rt.realize(self.params, Role.PARAMS)
        # slot extract/insert slice the batch axis; every cache family
        # stacks layers first, batch second — verify rather than assume
        for leaf in jax.tree.leaves(self.caches):
            if leaf.ndim < 2 or leaf.shape[1] != cfg.batch_slots:
                raise ValueError(
                    "cache leaf does not carry the batch on axis 1: "
                    f"shape {leaf.shape} with batch_slots="
                    f"{cfg.batch_slots}"
                )
        #: phase counters (tokens and wall seconds) + lifecycle events
        self.counters = {
            "prefill_tokens": 0, "prefill_s": 0.0,
            "decode_tokens": 0, "decode_s": 0.0,
            "replans": 0, "migrations": 0,
            "decode_replay_prefills": 0,
            "spill_s": 0.0, "restore_s": 0.0,
            "migration_retries": 0, "evacuations": 0,
        }
        self._build_steps()

    @property
    def policy(self) -> PlacementPolicy:
        """The placement policy currently in force (may change across
        :meth:`replan` migrations)."""
        return self.rt.policy

    @property
    def donates_cache(self) -> bool:
        """Whether the decode/prefill jits donate the cache pytree under
        the current policy (RESIDENT yes, STREAM no)."""
        return self._donate_cache

    @property
    def supports_chunked_prefill(self) -> bool:
        return self._prefill is not None

    def _cache_defs(self):
        return self.bundle.cache_defs(self.cfg.batch_slots, self.cfg.max_len)

    def slot_bytes(self) -> int:
        """Resident bytes of one cache slot row — what a preemption spill
        moves (each way)."""
        return sum(
            leaf.nbytes // self.cfg.batch_slots
            for leaf in jax.tree.leaves(self.caches)
        )

    # -- jit construction --------------------------------------------------
    def _build_steps(self) -> None:
        """(Re)build the jitted steps for the current policy: donation
        flags and pinned cache out_shardings are placement-dependent, so
        :meth:`replan` calls this after a migration."""
        bundle, cfg = self.bundle, self.cfg
        cache_specs = (
            None if self.mesh is None
            else self.rt.specs(Role.KV_CACHE, self._cache_defs())
        )
        self._state_sharding = (
            None if self.mesh is None
            else NamedSharding(self.mesh, P())
        )
        # warm-up counter restarts with each jit build: the first step
        # after a (re)build is compile-dominated and must not feed the
        # runtime's measured-step calibration.  The EWMA itself lives on
        # the Runtime (keyed by shape + policy), so a replan migration
        # starts a fresh observation under the new policy's key while the
        # old policy's measurements survive a later flip back.
        self._steps_since_build = 0

        # STREAM placements (kv_host & co.) keep the resident cache buffer
        # undonated — it is the source of truth the next step's staged
        # migration reads.  Everything RESIDENT donates: the decode step
        # then updates KV in place, no per-token cache-sized allocation.
        self._donate_cache = self.rt.donate_ok(Role.KV_CACHE)
        log.info(
            "decode step %s the KV cache under policy %s",
            "donates" if self._donate_cache else "does NOT donate",
            self.policy.name,
        )

        def _step_fn(p, state, caches):
            logits, new_caches = bundle.decode_step(
                p,
                {"tokens": state["tokens"], "lengths": state["lengths"]},
                caches,
            )
            # the sampler layer, in-jit: greedy rows (temp == 0) take the
            # plain argmax — bit-identical to the pre-sampler engine
            next_tok = sampling_mod.sample_tokens(logits, state)    # (B,)
            stopped = sampling_mod.hit_stop(next_tok, state["stop"])
            active = state["active"]
            new_state = dict(
                state,
                # inactive rows keep their token/length so idle slots and
                # freshly prefilled slots ride through untouched
                tokens=jnp.where(
                    active[:, None], next_tok[:, None], state["tokens"]
                ),
                lengths=state["lengths"] + active.astype(jnp.int32),
            )
            # one packed (2, B) vector back per step: next token + stop hit
            out = jnp.stack(
                [next_tok, (stopped & active).astype(jnp.int32)]
            )
            return out, new_state, new_caches

        donate = (1, 2) if self._donate_cache else (1,)
        decode_jit = jax.jit(
            _step_fn,
            donate_argnums=donate,
            # pin the returned cache to its realized placement so a donor
            # or host placement survives across steps (and donation keeps
            # aliasing the same tier) instead of drifting to whatever
            # layout XLA prefers for the first output.  The state dict is
            # pinned replicated: several of its arrays (sampling params,
            # stop table) pass through unchanged, and a donated
            # pass-through must come back with the sharding it arrived
            # with (place_state) or aliasing fails.
            out_shardings=(
                None if cache_specs is None
                else (None, self._state_sharding, cache_specs)
            ),
        )
        # Ahead-of-time: lower + compile against the live params/caches
        # and the canonical idle state NOW, so the donation contract is
        # checked at build time (not first dispatch), and reuse the
        # Compiled object AS the dispatch path — one compile, not two.
        # (.lower().compile() does not warm the jit dispatch cache, so
        # dispatching through the jit wrapper would recompile.)
        self._proto_state = self.place_state(
            idle_device_state(cfg.batch_slots)
        )
        self._decode = decode_jit.lower(
            self.params, self._proto_state, self.caches
        ).compile()

        # offset-chunk prefill, probed by capability rather than family:
        # encoder-decoder bundles chunk-prefill too now (their cross KV
        # is read-only during generation); only a bundle whose
        # prefill_at raises NotImplementedError falls back to the
        # decode-step replay admission.
        prefill_jit = jax.jit(
            lambda p, batch, caches, offsets: bundle.prefill_at(
                p, batch, caches, offsets
            ),
            donate_argnums=(2,) if self._donate_cache else (),
            out_shardings=(
                None if cache_specs is None else (None, cache_specs)
            ),
        )
        chunk = max(int(cfg.prefill_chunk), 1)
        B = cfg.batch_slots
        proto_batch = self.place_state({
            "tokens": jnp.zeros((B, chunk), jnp.int32),
            "new_lens": jnp.zeros((B,), jnp.int32),
        })
        proto_offsets = self.place_state(jnp.zeros((B,), jnp.int32))
        try:
            self._prefill = prefill_jit.lower(
                self.params, proto_batch, self.caches, proto_offsets
            ).compile()
        except NotImplementedError:
            self._prefill = None

        # preemption's device half: one slot row out / back in.  Extract
        # must NOT donate (the cache lives on); insert donates like the
        # decode step and keeps the pinned placement.  Both stay lazy
        # jits: promoted rows arrive from whatever spill tier preemption
        # parked them on, so insert's input shardings vary per call and
        # an AOT executable would be too strict.
        self._extract = jax.jit(
            lambda caches, i: jax.tree.map(
                lambda x: lax.dynamic_slice_in_dim(x, i, 1, axis=1), caches
            ),
        )
        self._insert = jax.jit(
            lambda caches, rows, i: jax.tree.map(
                lambda x, r: lax.dynamic_update_slice_in_dim(
                    x, r, i, axis=1
                ),
                caches, rows,
            ),
            donate_argnums=(0,) if self._donate_cache else (),
            out_shardings=cache_specs,
        )
        self._audit_builds()

    # -- build-time movement audit ----------------------------------------
    def _audit_builds(self) -> None:
        """Audit every donation path's compiled module at build time.

        The compiled text's ``input_output_alias`` header is the ground
        truth for whether ``donate_argnums`` materialized; a donation the
        policy requires that did NOT alias is a silent cache-sized copy
        per dispatch — raised here as
        :class:`repro.analysis.hlo_audit.DonationAliasError` (gated by
        ``cfg.verify_donation``).  Reports land in ``self.audit_reports``
        for ``tools/audit.py`` and the tests.
        """
        cfg = self.cfg
        arg_roles = {"p": Role.PARAMS, "caches": Role.KV_CACHE}
        donated = {"caches"} if self._donate_cache else set()
        # disaggregated clusters run one Executor per pool; the pool tag
        # keeps each pool's donation audit separately attributable
        pool = getattr(cfg, "pool", "")
        tag = f"{pool}:" if pool else ""
        # Fig. 17 allowance: one (B,1) token upload + one packed (2,B)
        # readback per step — nothing else may cross host<->device
        host_allow = 3 * cfg.batch_slots * 4
        self.audit_reports = {
            "decode": self.rt.audit(
                self._decode, arg_roles, donated=donated,
                host_bytes_allowed=host_allow,
                label=f"{tag}decode:{self.bundle.cfg.name}:{self.policy.name}",
            ),
        }
        if self._prefill is not None:
            self.audit_reports["prefill"] = self.rt.audit(
                self._prefill, arg_roles, donated=donated,
                host_bytes_allowed=host_allow,
                label=f"{tag}prefill:{self.bundle.cfg.name}:{self.policy.name}",
            )
        verify = getattr(cfg, "verify_donation", True)
        if verify and self._donate_cache:
            # the insert jit stays lazy (spill-tier inputs vary), so
            # verify its donation on a one-off compile against the
            # resident placement
            proto_rows = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(
                    (x.shape[0], 1) + x.shape[2:], x.dtype
                ),
                self.caches,
            )
            insert_compiled = self._insert.lower(
                self.caches, proto_rows, jnp.int32(0)
            ).compile()
            self.audit_reports["insert"] = self.rt.audit(
                insert_compiled, {"caches": Role.KV_CACHE},
                donated=donated, host_bytes_allowed=0.0,
                label=f"{tag}insert:{self.bundle.cfg.name}:{self.policy.name}",
            )
        if verify:
            for report in self.audit_reports.values():
                report.raise_on_donation_errors()

    def place_state(self, state: dict) -> dict:
        """Replicate a freshly uploaded state dict onto the mesh so the
        decode step's donated pass-through arrays alias cleanly (their
        pinned output sharding must match the input's)."""
        if self._state_sharding is None:
            return state
        return jax.device_put(state, self._state_sharding)

    # -- decode ------------------------------------------------------------
    def decode(self, state: dict) -> tuple[np.ndarray, np.ndarray, dict]:
        """One jitted decode step over every slot.

        Returns ``(next_tokens (B,), stopped (B,) bool, new_state)`` with
        the packed result fetched through a single async transfer — the
        only per-step host↔device traffic.
        """
        # pre-dispatch injection: the decode jit donates state + caches,
        # so a fault must fire before the call consumes the buffers — a
        # recovery path then sees intact pre-step state
        if self.rt.faults:
            self.rt.faults.check("decode")
        t0 = time.perf_counter()
        out, new_state, self.caches = self._decode(
            self.params, state, self.caches
        )
        copy_async = getattr(out, "copy_to_host_async", None)
        if copy_async is not None:
            copy_async()
        # the sanctioned once-per-step fetch: the packed (2, B) vector
        out_host = np.asarray(out)  # repro: lint-disable=blocking-transfer-in-hot-path
        dt = time.perf_counter() - t0
        self.counters["decode_s"] += dt
        # each warm step becomes a calibration observation on the Runtime:
        # it updates the measured EWMA behind rt.decode_step_seconds (the
        # preemption ledger's wait side) and logs predicted-vs-measured
        # into rt.replay.  The first step after a (re)build is
        # compile-dominated and skipped.
        self._steps_since_build += 1
        if self._steps_since_build > 1:
            self.rt.observe_decode_step(
                self.cfg.batch_slots, self.cfg.max_len, dt
            )
        return out_host[0], out_host[1].astype(bool), new_state

    @property
    def measured_step_s(self) -> float | None:
        """EWMA of observed decode-step wall time under the current
        policy (None until the second step after a jit build feeds the
        runtime) — the wait-side price preemption uses via
        ``rt.decode_step_seconds``."""
        return self.rt.measured_step_s(
            self.cfg.batch_slots, self.cfg.max_len
        )

    # -- prefill (admission) ----------------------------------------------
    def prefill(self, new, table) -> None:
        """Write the newly claimed rows' prompts into the cache.

        ``new`` is ``[(slot, prompt ndarray), ...]``; ``table`` is the
        scheduler's :class:`~repro.serve.state.SlotTable`, whose
        ``lengths`` mirror advances as chunks land.  The last prompt
        token is withheld: the first decode step feeds it so its logits
        produce the first generated token.  Blocks on the dispatches so
        the prefill/decode split in the counters is honest.
        """
        if self.rt.faults:
            self.rt.faults.check("prefill")
        t0 = time.perf_counter()
        if self._prefill is None:
            self._replay_prefill(new, table)
        else:
            self._chunked_prefill(new, table)
        jax.block_until_ready(self.caches)
        self.counters["prefill_tokens"] += sum(
            len(prompt) - 1 for _, prompt in new
        )
        self.counters["prefill_s"] += time.perf_counter() - t0

    def _chunked_prefill(self, new, table) -> None:
        chunk = max(int(self.cfg.prefill_chunk), 1)
        lens = {i: len(prompt) - 1 for i, prompt in new}
        # at least one dispatch even when every prompt has length 1
        # (lens all 0): recurrent (SSM) state is cumulative and a freed
        # slot keeps integrating garbage while idle, so admission must
        # run prefill_at once for its offsets==0 zero-state reset even
        # with nothing to write.
        max_len = max(max(lens.values()), 1)
        B = self.cfg.batch_slots
        for lo in range(0, max_len, chunk):
            toks = np.zeros((B, chunk), np.int32)
            new_lens = np.zeros(B, np.int32)
            for i, prompt in new:
                n = int(np.clip(lens[i] - lo, 0, chunk))
                if n > 0:
                    toks[i, :n] = prompt[lo : lo + n]
                    new_lens[i] = n
            _, self.caches = self._prefill(
                self.params,
                # toks/new_lens are freshly built per chunk and never
                # mutated after the handoff; lengths is a live mirror
                # and goes through the race-safe upload copy.  place_state
                # commits them to the replicated sharding the AOT
                # executable was lowered against.
                self.place_state({
                    "tokens": jnp.asarray(toks),
                    "new_lens": jnp.asarray(new_lens),
                }),
                self.caches,
                self.place_state(upload(table.lengths, np.int32)),
            )
            for i, _ in new:
                table.lengths[i] += int(new_lens[i])

    def _replay_prefill(self, new, table) -> None:
        """Fallback admission for bundles whose ``prefill_at`` raises
        ``NotImplementedError``: replay each prompt token-by-token
        through the full-batch decode step — O(B·L) dispatches,
        correctness-only.  Warned once and counted so the slow path is
        visible."""
        from repro.analysis.warnings_registry import mark

        if mark(f"decode_replay:{self.bundle.cfg.name}"):
            log.warning(
                "%s has no chunked prefill (prefill_at raised "
                "NotImplementedError): admission falls back to O(B*L) "
                "decode-step replay — correctness-only; counted in "
                "stats()['decode_replay_prefills']",
                self.bundle.cfg.name,
            )
        self.counters["decode_replay_prefills"] += len(new)
        B = self.cfg.batch_slots

        def idle_state(toks):
            # rebuilt per dispatch from the canonical schema: the decode
            # jit donates the state, so these buffers are consumed by
            # each call
            return dict(
                idle_device_state(B),
                tokens=jnp.asarray(toks),
                lengths=upload(table.lengths, np.int32),
            )

        for i, prompt in new:
            for t in range(len(prompt) - 1):
                toks = np.zeros((B, 1), np.int32)
                toks[i, 0] = prompt[t]
                _, _, self.caches = self._decode(
                    self.params, self.place_state(idle_state(toks)),
                    self.caches,
                )
                table.lengths[i] += 1

    # -- preemption: slot spill / restore ---------------------------------
    def extract_slot(self, i: int, spill_to: Placement):
        """Pull slot ``i``'s cache rows out and park them on
        ``spill_to`` (the planner-priced spill tier).  Blocking — the
        rows are consistent when this returns.  Counted in ``spill_s``."""
        if self.rt.faults:
            self.rt.faults.check("extract")
        t0 = time.perf_counter()
        rows = self._extract(self.caches, jnp.int32(i))
        if self.mesh is not None:
            park = self.rt.policy.with_placement(Role.KV_CACHE, spill_to)
            rows = self.rt.realize(
                rows, Role.KV_CACHE, specs=None, policy=park
            )
        jax.block_until_ready(rows)
        self.counters["spill_s"] += time.perf_counter() - t0
        return rows

    def insert_slot(self, i: int, rows) -> None:
        """Scatter parked rows back into slot ``i`` (promotion).  The
        insert jit donates the cache like the decode step and keeps the
        pinned placement, so the move is bit-preserving and in place."""
        t0 = time.perf_counter()
        self.caches = self._insert(self.caches, rows, jnp.int32(i))
        jax.block_until_ready(self.caches)
        self.counters["restore_s"] += time.perf_counter() - t0

    # -- live re-placement -------------------------------------------------
    def replan(
        self, policy=None, *, force: bool = False, occupancy: float = 1.0,
        inflight=None,
    ) -> bool:
        """Re-place the live KV cache (and params) mid-serve.

        With ``policy=None``, re-runs the planner's combined serve
        pricing against the *current* cache occupancy (``occupancy``
        scales the KV bytes, so a near-empty cache prices like a
        near-empty cache); with an explicit ``policy`` (any
        ``parse_policy`` spelling), adopts it directly.  When the target
        differs from the policy in force, the KV cache and — if its
        placement changed — the params are migrated between tiers via
        :meth:`repro.api.Runtime.migrate` (donation-aware ``device_put``
        onto the new shardings; decode output is bit-identical across
        the move), and the jitted steps are rebuilt for the new donation
        flags and pinned out_shardings.  ``inflight`` is blocked on
        before the buffers move (the scheduler passes its device state).
        Returns True iff a migration happened.  No mesh -> nothing is
        realizable, always False.
        """
        if self.mesh is None:
            return False
        old = self.rt.policy
        self.counters["replans"] += 1
        if policy is None:
            self.rt.plan_phase(
                "serve",
                batch_slots=self.cfg.batch_slots,
                max_len=self.cfg.max_len,
                prefill_chunk=self.cfg.prefill_chunk,
                kv_utilization=occupancy,
                log_table=False,
            )
            target = self.rt.policy
        else:
            target = parse_policy(policy)
        # structural comparison, not names: a custom 'kv=host:stream' is
        # the same placement as the registered kv_host (no-op), while a
        # JSON policy reusing a registered name may carry new placements
        same = all(
            target.placement(r) == old.placement(r) for r in Role
        )
        if same and not force:
            self.rt.policy = old
            return False
        # drain in-flight dispatches against the old placement before the
        # buffers move out from under them
        jax.block_until_ready(
            (self.caches,) if inflight is None else (self.caches, inflight)
        )
        # plan_phase may have already adopted the target into rt.policy;
        # migrate_roles() owns the handover: it mutates the trees dict in
        # place as each role lands, and on partial failure sets rt.policy
        # to what the live buffers actually are.  Transient faults (link
        # hiccups, injected MigrationFault) are retried under the
        # migration budget — the retry re-reads the partial policy, so
        # only the unfinished roles move again.
        self.rt.policy = old
        trees = {Role.KV_CACHE: self.caches, Role.PARAMS: self.params}
        defs = {Role.KV_CACHE: self._cache_defs()}

        def _on_retry(attempt, err, delay):
            self.counters["migration_retries"] += 1

        try:
            moved = retry_call(
                lambda: self.rt.migrate_roles(
                    trees, target, defs, force=force
                ),
                retry_on=(TransientFault,),
                policy=MIGRATION_RETRY,
                label=f"replan {old.name}->{target.name}",
                seed=self.counters["replans"],
                on_retry=_on_retry,
            )
        except BaseException:
            # migrated roles' old buffers were donated (freed): adopt
            # whatever landed before re-raising, or the executor would
            # dispatch against dead buffers.  Rebuild the jits only if
            # something actually moved — a clean adopt-nothing failure
            # leaves the compiled steps valid as-is.
            self.caches = trees[Role.KV_CACHE]
            self.params = trees[Role.PARAMS]
            if self.rt.policy is not old:
                self._build_steps()
            raise
        self.caches = trees[Role.KV_CACHE]
        self.params = trees[Role.PARAMS]
        self._build_steps()
        self.counters["migrations"] += 1
        log.info(
            "replan: migrated %s -> %s (%s) at occupancy %.0f%%",
            old.name, target.name,
            ",".join(r.value for r in moved) or "forced no-op",
            100 * occupancy,
        )
        return True

    def evacuate(
        self, tier, *, occupancy: float = 1.0, inflight=None
    ) -> list[Role]:
        """Serve-side tier loss: drain in-flight work, delegate to
        :meth:`repro.api.Runtime.evacuate` (planner re-pick with the
        lost tier excluded, transient faults retried under the
        migration budget), adopt the moved trees and rebuild the jits.
        Returns the roles that moved."""
        if self.mesh is None:
            self.rt.mark_tier_lost(tier)
            return []
        old = self.rt.policy
        jax.block_until_ready(
            (self.caches,) if inflight is None else (self.caches, inflight)
        )
        trees = {Role.KV_CACHE: self.caches, Role.PARAMS: self.params}
        defs = {Role.KV_CACHE: self._cache_defs()}

        def _on_retry(attempt, err, delay):
            self.counters["migration_retries"] += 1

        try:
            _, moved = retry_call(
                lambda: self.rt.evacuate(
                    tier, trees, defs, phase="serve",
                    batch_slots=self.cfg.batch_slots,
                    max_len=self.cfg.max_len,
                    prefill_chunk=self.cfg.prefill_chunk,
                    kv_utilization=occupancy,
                ),
                retry_on=(TransientFault,),
                policy=MIGRATION_RETRY,
                label=f"evacuate {tier}",
                seed=self.counters["evacuations"],
                on_retry=_on_retry,
            )
        except BaseException:
            self.caches = trees[Role.KV_CACHE]
            self.params = trees[Role.PARAMS]
            if self.rt.policy is not old:
                self._build_steps()
            raise
        self.caches = trees[Role.KV_CACHE]
        self.params = trees[Role.PARAMS]
        self.counters["evacuations"] += 1
        if moved:
            self._build_steps()
            self.counters["migrations"] += 1
        return moved
