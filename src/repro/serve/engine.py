"""Batched serving engine: continuous batching over prefill/decode steps.

The serving loop is the paper's Fig. 17 workload industrialized: per decoded
token, every parameter byte and every cache byte crosses the compute
datapath once — and, as of the zero-copy rework, *exactly* once:

* **Donated caches** — the jitted decode step (and the chunked-prefill jit)
  donates the KV cache pytree, so XLA updates KV in place instead of
  allocating and copying a cache-sized buffer per token.  The
  placement-pinned ``out_shardings`` (``Runtime.specs``) keep donor/host
  placements on the aliased buffer across steps.  Donation is gated per policy by
  :func:`repro.models.sharding.donation_compatible`: ``Strategy.STREAM``
  placements keep their far-tier resident buffer undonated.
* **Chunked batched prefill** — admission writes whole prompt chunks for
  every newly claimed slot in one :meth:`ModelBundle.prefill_at` dispatch
  per chunk (row-sliced cache scatter at per-slot offsets), so admitting a
  batch of length-L prompts costs O(L / prefill_chunk) dispatches instead
  of replaying O(B·L) full-batch decode steps.
* **On-device serve state** — per-slot lengths and last tokens live in a
  device-side state dict carried through the jitted step; the greedy
  argmax happens in-jit and the only per-step host↔device traffic is the
  (B,) next-token vector fetched back.  Host mirrors are updated from that
  returned vector, never re-uploaded per step (uploads happen only on slot
  lifecycle events: admission and free).

Placement is owned by a :class:`repro.api.Runtime` facade: when
``ServeConfig.policy`` is ``None`` the runtime's planner prices decode
*and* chunked-prefill profiles and picks the fastest policy that fits
every memory pool in both phases, restricted to the tiers this runtime
realizes (host tiers when the backend exposes a distinct host memory
space, peer/remote tiers when the mesh has the ``donor``/``donor_pod``
axis).  A forced policy — any :func:`repro.core.placement.parse_policy`
spelling, including custom string/JSON policies — that names a
peer/remote tier on a donor-less mesh raises
:class:`repro.core.placement.DonorAxisError` instead of silently serving
from local HBM.  :meth:`Server.replan` re-runs the planner against the
*live* cache occupancy and, when the pick changes, migrates the KV cache
and params between tiers mid-serve via :meth:`repro.api.Runtime.migrate`
(decode output is bit-identical across the move — it is a placement
change, not a recompute).  See ``docs/serving.md`` for the slot
lifecycle, chunking, and donation rules in full, and
``docs/placement.md`` for the policy grammar + migration semantics.
"""

from __future__ import annotations

import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Runtime
from repro.core.placement import PlacementPolicy, Role, parse_policy
from repro.models.model_zoo import ModelBundle
from repro.models.sharding import donation_compatible

log = logging.getLogger("repro.serve.engine")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 512
    #: tokens per chunked-prefill dispatch during admission
    prefill_chunk: int = 32
    #: None -> consult the placement planner (datapath-bound model);
    #: otherwise any ``parse_policy`` spelling: a PlacementPolicy value,
    #: a registered name, ``"kv=host:stream,..."``, or policy JSON.
    policy: PlacementPolicy | str | dict | None = None
    rules: dict | None = None
    #: re-run the planner (and migrate KV/params if the pick changes)
    #: whenever cache occupancy crosses a band boundary — the live form
    #: of the paper's phase-dependent placement decision.
    auto_replan: bool = False
    #: number of occupancy bands for auto_replan (4 -> re-price at 25%
    #: occupancy steps)
    replan_bands: int = 4


class Server:
    """Single-model continuous-batching server (greedy decoding)."""

    def __init__(self, bundle: ModelBundle, cfg: ServeConfig, params, mesh=None):
        self.bundle = bundle
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        # The Runtime facade owns mesh + policy + planner.  A forced
        # peer/remote policy on a donor-less mesh raises DonorAxisError
        # here, up front, rather than serving from local HBM.
        if cfg.policy is not None:
            self.rt = Runtime(bundle, mesh, cfg.policy, rules=cfg.rules)
        else:
            self.rt = Runtime.auto(
                bundle, mesh, phase="serve", rules=cfg.rules,
                batch_slots=cfg.batch_slots, max_len=cfg.max_len,
                prefill_chunk=cfg.prefill_chunk,
            )
            log.info(
                "planner picked %s for %s (%d slots x %d ctx, prefill "
                "chunk %d)", self.rt.policy.name, bundle.cfg.name,
                cfg.batch_slots, cfg.max_len, cfg.prefill_chunk,
            )
        self._requests: dict[int, Request] = {}
        self._slots: list[int | None] = [None] * cfg.batch_slots
        # host mirrors of the device-side serve state (see _sync_state)
        self._lengths = np.zeros(cfg.batch_slots, np.int32)
        self._last_tokens = np.zeros((cfg.batch_slots, 1), np.int32)
        self._active = np.zeros(cfg.batch_slots, bool)
        self._caches = bundle.init_cache(cfg.batch_slots, cfg.max_len)
        if mesh is not None:
            # realize the policy for every role the server owns: the KV
            # cache AND the params (weights_stream keeps params host-side;
            # kv_peer_hbm/weights_peer_hbm shard across the donor slices)
            self._caches = self.rt.realize(
                self._caches, Role.KV_CACHE, self._cache_defs()
            )
            self.params = self.rt.realize(self.params, Role.PARAMS)
        self._build_steps()
        self._state = self._make_state()
        self._pending: list[Request] = []
        self._replan_band: int | None = None
        #: serve-phase throughput counters (tokens and wall seconds)
        self.stats = {
            "prefill_tokens": 0, "prefill_s": 0.0,
            "decode_tokens": 0, "decode_s": 0.0,
            "replans": 0, "migrations": 0,
        }

    @property
    def policy(self) -> PlacementPolicy:
        """The placement policy currently in force (may change across
        :meth:`replan` migrations)."""
        return self.rt.policy

    def _cache_defs(self):
        return self.bundle.cache_defs(self.cfg.batch_slots, self.cfg.max_len)

    def _build_steps(self) -> None:
        """(Re)build the jitted decode/prefill steps for the current
        policy: donation flags and pinned cache out_shardings are
        placement-dependent, so :meth:`replan` calls this after a
        migration."""
        bundle, cfg = self.bundle, self.cfg
        cache_specs = (
            None if self.mesh is None
            else self.rt.specs(Role.KV_CACHE, self._cache_defs())
        )

        # STREAM placements (kv_host & co.) keep the resident cache buffer
        # undonated — it is the source of truth the next step's staged
        # migration reads.  Everything RESIDENT donates: the decode step
        # then updates KV in place, no per-token cache-sized allocation.
        self._donate_cache = self.rt.donate_ok(Role.KV_CACHE)
        log.info(
            "decode step %s the KV cache under policy %s",
            "donates" if self._donate_cache else "does NOT donate",
            self.policy.name,
        )

        def _step_fn(p, state, caches):
            logits, new_caches = bundle.decode_step(
                p,
                {"tokens": state["tokens"], "lengths": state["lengths"]},
                caches,
            )
            next_tok = jnp.argmax(logits, -1).astype(jnp.int32)     # (B,)
            active = state["active"]
            new_state = {
                # inactive rows keep their token/length so idle slots and
                # freshly prefilled slots ride through untouched
                "tokens": jnp.where(
                    active[:, None], next_tok[:, None], state["tokens"]
                ),
                "lengths": state["lengths"] + active.astype(jnp.int32),
                "active": active,
            }
            return next_tok, new_state, new_caches

        donate = (1, 2) if self._donate_cache else (1,)
        self._decode = jax.jit(
            _step_fn,
            donate_argnums=donate,
            # pin the returned cache to its realized placement so a donor
            # or host placement survives across steps (and donation keeps
            # aliasing the same tier) instead of drifting to whatever
            # layout XLA prefers for the first output
            **({} if cache_specs is None
               else {"out_shardings": (None, None, cache_specs)}),
        )

        # encoder-decoder bundles have no offset-chunk prefill (their
        # prefill also projects the cross-attention memory) — they fall
        # back to the decode-step replay admission.
        if bundle.cfg.family == "audio" and bundle.cfg.n_encoder_layers:
            self._prefill = None
        else:
            self._prefill = jax.jit(
                lambda p, batch, caches, offsets: bundle.prefill_at(
                    p, batch, caches, offsets
                ),
                donate_argnums=(2,) if self._donate_cache else (),
                **({} if cache_specs is None
                   else {"out_shardings": (None, cache_specs)}),
            )

    # -- live re-placement -------------------------------------------------
    def occupancy(self) -> float:
        """Live cache utilization: tokens resident across all slots over
        the cache extent — what replan pricing feeds the planner."""
        return float(self._lengths.sum()) / float(
            self.cfg.batch_slots * self.cfg.max_len
        )

    def replan(self, policy=None, *, force: bool = False) -> bool:
        """Re-place the live KV cache (and params) mid-serve.

        With ``policy=None``, re-runs the planner's combined serve
        pricing against the *current* cache occupancy
        (:meth:`occupancy` scales the KV bytes, so a near-empty cache
        prices like a near-empty cache); with an explicit ``policy`` (any
        ``parse_policy`` spelling), adopts it directly.  When the target
        differs from the policy in force, the KV cache and — if its
        placement changed — the params are migrated between tiers via
        :meth:`repro.api.Runtime.migrate` (donation-aware ``device_put``
        onto the new shardings; decode output is bit-identical across
        the move), and the jitted steps are rebuilt for the new donation
        flags and pinned out_shardings.  Returns True iff a migration
        happened.  No mesh -> nothing is realizable, always False.
        """
        if self.mesh is None:
            return False
        old = self.rt.policy
        self.stats["replans"] += 1
        if policy is None:
            self.rt.plan_phase(
                "serve",
                batch_slots=self.cfg.batch_slots,
                max_len=self.cfg.max_len,
                prefill_chunk=self.cfg.prefill_chunk,
                kv_utilization=self.occupancy(),
                log_table=False,
            )
            target = self.rt.policy
        else:
            target = parse_policy(policy)
        # structural comparison, not names: a custom 'kv=host:stream' is
        # the same placement as the registered kv_host (no-op), while a
        # JSON policy reusing a registered name may carry new placements
        same = all(
            target.placement(r) == old.placement(r) for r in Role
        )
        if same and not force:
            self.rt.policy = old
            return False
        # drain in-flight dispatches against the old placement before the
        # buffers move out from under them
        jax.block_until_ready((self._caches, self._state["tokens"]))
        # plan_phase may have already adopted the target into rt.policy;
        # migrate() owns the handover, and on failure rt.policy must keep
        # describing what the live buffers actually are.  Donation is
        # decided by the SOURCE placement (a STREAM source keeps its
        # resident buffer undonated) — pass it explicitly.
        self.rt.policy = old
        moved_kv = False
        try:
            if force or target.placement(Role.KV_CACHE) != old.placement(
                Role.KV_CACHE
            ):
                self._caches = self.rt.migrate(
                    self._caches, Role.KV_CACHE, target, self._cache_defs(),
                    donate=donation_compatible(old, Role.KV_CACHE),
                )
                moved_kv = True
            if force or target.placement(Role.PARAMS) != old.placement(
                Role.PARAMS
            ):
                self.params = self.rt.migrate(
                    self.params, Role.PARAMS, target,
                    donate=donation_compatible(old, Role.PARAMS),
                )
        except Exception:
            # a half-done replan must not lie about the live placement:
            # nothing moved -> the old policy; KV moved but params did
            # not -> old with the KV placement swapped in
            self.rt.policy = (
                old.with_placement(
                    Role.KV_CACHE, target.placement(Role.KV_CACHE)
                ).renamed(
                    f"{old.name}+kv_cache="
                    f"{target.placement(Role.KV_CACHE).to_str()}"
                )
                if moved_kv else old
            )
            self._build_steps()
            raise
        self.rt.policy = target
        self._build_steps()
        self.stats["migrations"] += 1
        log.info(
            "replan: migrated %s -> %s at occupancy %.0f%%",
            old.name, target.name, 100 * self.occupancy(),
        )
        return True

    def _maybe_auto_replan(self) -> None:
        """Fire :meth:`replan` when occupancy crosses a band boundary —
        only for planner-owned policies (a forced ``cfg.policy`` pins
        placement; call :meth:`replan` explicitly to move it)."""
        if not self.cfg.auto_replan or self.cfg.policy is not None:
            return
        band = int(self.occupancy() * max(self.cfg.replan_bands, 1))
        if band != self._replan_band:
            self._replan_band = band
            self.replan()

    # -- device-side serve state ------------------------------------------
    @staticmethod
    def _upload(arr: np.ndarray, dtype) -> jnp.ndarray:
        """Device copy of a host mirror that can NEVER see later writes.

        The PR 2 lesson, sharpened: ``jnp.asarray`` can zero-copy alias
        the mirror, and even ``jnp.array`` — which copies eagerly on an
        idle runtime — may *defer* reading the numpy buffer behind queued
        async dispatches on the CPU backend, so a subsequent
        ``mirror[i] += 1`` still races the device read.  Handing over a
        fresh ``.copy()`` that nothing ever mutates is the only upload
        that is safe under queue pressure.
        """
        return jnp.asarray(np.array(arr, dtype=dtype, copy=True))

    def _make_state(self) -> dict:
        """Fresh device state from the host mirrors."""
        return {
            "tokens": self._upload(self._last_tokens, np.int32),
            "lengths": self._upload(self._lengths, np.int32),
            "active": self._upload(self._active, bool),
        }

    def _sync_state(self) -> None:
        """Re-upload the small state arrays after a slot lifecycle event
        (admission / free).  Steady-state decode never calls this: the
        state lives on device and the host mirror advances from the
        *returned* token vector."""
        self._state = self._make_state()

    # -- request lifecycle -------------------------------------------------
    def add_request(self, req: Request) -> None:
        """Queue a request, validating it against the cache extent.

        Prefill writes ``len(prompt) - 1`` cache positions and the decode
        loop at least one more, so a prompt only fits when ``len(prompt) <
        max_len``.  Admitting a longer one would advance lengths past the
        cache and silently clamp/corrupt KV writes — reject it here,
        logged, before it ever claims a slot.  Duplicate (or negative)
        rids are rejected too: the rid is the slot-bookkeeping key, and a
        silent overwrite would orphan the live request's slot.
        """
        if req.rid < 0:
            raise ValueError(f"request rid must be >= 0, got {req.rid}")
        if req.rid in self._requests:
            raise ValueError(
                f"request {req.rid}: rid already queued or being served "
                "(rids must be unique among live requests; a duplicate "
                "would orphan the live request's slot bookkeeping — "
                "finished rids are evicted and may be reused)"
            )
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}"
            )
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) >= self.cfg.max_len:
            log.warning(
                "rejecting request %d: prompt of %d tokens needs "
                "len(prompt)+1 cache positions but max_len=%d",
                req.rid, len(req.prompt), self.cfg.max_len,
            )
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"does not fit max_len={self.cfg.max_len} "
                "(need len(prompt) < max_len)"
            )
        self._requests[req.rid] = req
        self._pending.append(req)

    def add_requests(self, reqs) -> None:
        """Batched admission entry point: queue several requests at once
        (they prefill together in the next tick's chunked dispatches)."""
        for req in reqs:
            self.add_request(req)

    def _admit(self) -> None:
        """Claim free slots for pending requests and prefill them batched.

        Every newly claimed row's prompt is written through
        ``bundle.prefill_at``: one dispatch per ``prefill_chunk`` tokens
        covers *all* admitted rows (row-sliced cache scatter at per-slot
        offsets), so admission costs O(max_prompt_len / prefill_chunk)
        dispatches.  The last prompt token is withheld: the first decode
        step feeds it so its logits produce the first generated token
        (the prefill-then-decode contract).  See ``docs/serving.md``.
        """
        new: list[tuple[int, Request]] = []
        for i in range(self.cfg.batch_slots):
            if self._slots[i] is not None or not self._pending:
                continue
            req = self._pending.pop(0)
            self._slots[i] = req.rid
            new.append((i, req))
        if not new:
            return
        t0 = time.perf_counter()
        if self._prefill is None:
            self._admit_replay(new)
        else:
            self._admit_chunked(new)
        n_prefill = sum(len(req.prompt) - 1 for _, req in new)
        for i, req in new:
            self._last_tokens[i, 0] = req.prompt[-1]
            self._active[i] = True
        self._sync_state()
        # drain the prefill dispatches themselves (the state upload has no
        # data dependency on them) so the prefill/decode split in stats is
        # honest — otherwise queued prefill compute would be absorbed into
        # the next step()'s decode timing.
        jax.block_until_ready((self._caches, self._state["tokens"]))
        self.stats["prefill_tokens"] += n_prefill
        self.stats["prefill_s"] += time.perf_counter() - t0

    def _admit_chunked(self, new: list[tuple[int, Request]]) -> None:
        chunk = max(int(self.cfg.prefill_chunk), 1)
        lens = {i: len(req.prompt) - 1 for i, req in new}
        # at least one dispatch even when every prompt has length 1
        # (lens all 0): recurrent (SSM) state is cumulative and a freed
        # slot keeps integrating garbage while idle, so admission must
        # run prefill_at once for its offsets==0 zero-state reset even
        # with nothing to write.
        max_len = max(max(lens.values()), 1)
        for lo in range(0, max_len, chunk):
            toks = np.zeros((self.cfg.batch_slots, chunk), np.int32)
            new_lens = np.zeros(self.cfg.batch_slots, np.int32)
            for i, req in new:
                n = int(np.clip(lens[i] - lo, 0, chunk))
                if n > 0:
                    toks[i, :n] = req.prompt[lo : lo + n]
                    new_lens[i] = n
            _, self._caches = self._prefill(
                self.params,
                {
                    # toks/new_lens are freshly built per chunk and never
                    # mutated after the handoff; _lengths is a live mirror
                    # and goes through the race-safe _upload copy.
                    "tokens": jnp.asarray(toks),
                    "new_lens": jnp.asarray(new_lens),
                },
                self._caches,
                self._upload(self._lengths, np.int32),
            )
            for i, _ in new:
                self._lengths[i] += int(new_lens[i])

    def _admit_replay(self, new: list[tuple[int, Request]]) -> None:
        """Fallback admission for bundles without ``prefill_at``
        (encoder-decoder): replay each prompt token-by-token through the
        full-batch decode step — O(B·L) dispatches, correctness-only."""
        idle = np.zeros(self.cfg.batch_slots, bool)
        for i, req in new:
            for t in range(len(req.prompt) - 1):
                toks = np.zeros((self.cfg.batch_slots, 1), np.int32)
                toks[i, 0] = req.prompt[t]
                state = {
                    "tokens": jnp.asarray(toks),
                    "lengths": self._upload(self._lengths, np.int32),
                    "active": jnp.asarray(idle),
                }
                _, _, self._caches = self._decode(
                    self.params, state, self._caches
                )
                self._lengths[i] += 1

    def _free_slot(self, i: int) -> None:
        """The single place a slot returns to the pool: clears the slot
        assignment, its state mirrors, and the request-table entry
        together (stale cache rows beyond the zeroed length are masked
        out and overwritten by next prefill; evicting the finished rid
        lets callers reuse it and bounds the table to live requests).
        The caller re-syncs device state after the batch of frees."""
        self._requests.pop(self._slots[i], None)
        self._slots[i] = None
        self._lengths[i] = 0
        self._last_tokens[i, 0] = 0
        self._active[i] = False

    # -- one decode tick -----------------------------------------------------
    def step(self) -> int:
        """Admit + decode one token for every active slot. Returns #active.

        The decode step consumes and returns the on-device state; the only
        per-step host↔device traffic is the (B,) next-token vector coming
        back (fetched via one async transfer, then blocked on).
        """
        self._admit()
        self._maybe_auto_replan()
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return 0
        t0 = time.perf_counter()
        next_tok, self._state, self._caches = self._decode(
            self.params, self._state, self._caches
        )
        copy_async = getattr(next_tok, "copy_to_host_async", None)
        if copy_async is not None:
            copy_async()
        next_host = np.asarray(next_tok)
        self.stats["decode_tokens"] += len(active)
        self.stats["decode_s"] += time.perf_counter() - t0
        freed = False
        for i in active:
            req = self._requests[self._slots[i]]
            req.out_tokens.append(int(next_host[i]))
            self._lengths[i] += 1
            self._last_tokens[i, 0] = next_host[i]
            if (
                len(req.out_tokens) >= req.max_new_tokens
                or self._lengths[i] >= self.cfg.max_len - 1
            ):
                req.done = True
                self._free_slot(i)
                freed = True
        if freed:
            self._sync_state()
            self._maybe_auto_replan()
        return len(active)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self._pending and all(s is None for s in self._slots):
                return
            self.step()
        raise RuntimeError("serve loop did not drain")

    def throughput(self) -> dict:
        """Prefill/decode split tokens-per-second from the stats counters."""
        s = self.stats
        return {
            "prefill_tokens": s["prefill_tokens"],
            "decode_tokens": s["decode_tokens"],
            "prefill_tps": (
                s["prefill_tokens"] / s["prefill_s"] if s["prefill_s"] else 0.0
            ),
            "decode_tps": (
                s["decode_tokens"] / s["decode_s"] if s["decode_s"] else 0.0
            ),
        }
