"""Per-request token sampling, computed in-jit.

The sampler layer of the serve stack: every active slot carries its own
``temperature`` / ``top_k`` / ``top_p`` / ``seed`` / stop-token set, and
the whole transform — filter, draw, stop detection — runs *inside* the
jitted decode step over the batched ``(batch_slots, vocab)`` logits, so
sampling adds zero per-step host↔device traffic beyond the packed
next-token/stopped vector the step already returns.

Determinism contract: the draw for a request at absolute position ``t``
uses ``fold_in(PRNGKey(seed), t)`` — a function of *(seed, position)*
only.  Tokens are therefore reproducible across admission order, slot
assignment, preemption/promotion cycles, and devices (threefry is
backend-deterministic), which is what lets the scheduler soak assert
token equality under load.  ``temperature == 0`` short-circuits to
``argmax`` — bit-identical to the pre-sampler greedy engine.

The filter semantics (the part with room for off-by-one disagreement)
have a NumPy oracle, :func:`filter_logits_ref`, tested against the jit
path in ``tests/test_serve_sampling.py``:

* **temperature** scales logits after filtering (masked entries stay
  ``-inf``); it never changes *which* tokens are eligible, only how the
  eligible mass is flattened.
* **top_k** keeps every logit ``>=`` the k-th largest (ties at the
  threshold are all kept).  ``top_k <= 0`` disables the filter.
* **top_p** keeps the smallest prefix of the temperature-scaled,
  probability-sorted distribution whose mass reaches ``top_p`` — a token
  survives iff the mass *strictly before* it is ``< top_p``, so the
  argmax always survives and ``top_p >= 1`` keeps everything.
* **stop tokens** match in-jit against a ``-1``-padded ``(B, W)`` table;
  the matching token is still emitted (and counted), then the scheduler
  retires the request.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

#: widest stop-token set a request may carry (the in-jit match table is a
#: fixed-width, -1-padded (batch_slots, STOP_WIDTH) array).
STOP_WIDTH = 4

_NEG_INF = float("-inf")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters.

    The default is greedy (``temperature=0``): ``argmax`` in-jit,
    bit-identical to the pre-sampler engine, which keeps every greedy
    equivalence test anchoring correctness.  ``seed`` only matters when
    ``temperature > 0``; ``stop_tokens`` always apply.
    """

    temperature: float = 0.0
    top_k: int = 0           # 0 -> no top-k filter
    top_p: float = 1.0       # 1.0 -> no nucleus filter
    seed: int = 0
    stop_tokens: tuple[int, ...] = ()

    def validate(self) -> None:
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0 (0 = greedy), got "
                f"{self.temperature}"
            )
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(
                f"top_p must be in (0, 1], got {self.top_p}"
            )
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), got {self.top_k}")
        if not 0 <= self.seed < 2**32:
            raise ValueError(f"seed must be a uint32, got {self.seed}")
        if len(self.stop_tokens) > STOP_WIDTH:
            raise ValueError(
                f"at most {STOP_WIDTH} stop tokens per request, got "
                f"{len(self.stop_tokens)}"
            )
        if any(int(t) < 0 for t in self.stop_tokens):
            raise ValueError(
                f"stop tokens must be non-negative token ids, got "
                f"{self.stop_tokens}"
            )

    def stop_row(self) -> np.ndarray:
        """The request's ``(STOP_WIDTH,)`` -1-padded stop-token row."""
        row = np.full(STOP_WIDTH, -1, np.int32)
        row[: len(self.stop_tokens)] = np.asarray(
            self.stop_tokens, np.int32
        )
        return row


GREEDY = SamplingParams()


def filter_logits(logits, temperature, top_k, top_p):
    """In-jit filter: ``(B, V)`` logits -> temperature-scaled logits with
    every filtered entry at ``-inf``.  Row-wise ``temperature``/``top_k``/
    ``top_p`` are traced ``(B,)`` arrays — the filter thresholds are
    computed by sorting, not by static-k ``lax.top_k``, so per-request
    values need no retrace."""
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    temperature = jnp.maximum(temperature.astype(jnp.float32), 1e-6)
    sorted_desc = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)   # (B, V)

    # top-k: keep logits >= k-th largest; k <= 0 disables (threshold at
    # the smallest logit keeps everything)
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V)
    thr_k = jnp.take_along_axis(
        sorted_desc, (k_eff - 1)[:, None].astype(jnp.int32), axis=-1
    )

    # top-p on the temperature-scaled distribution: a sorted position
    # survives iff the probability mass strictly before it is < top_p
    probs = jax.nn.softmax(sorted_desc / temperature[:, None], axis=-1)
    before = jnp.cumsum(probs, axis=-1) - probs
    n_keep = jnp.sum(before < top_p[:, None], axis=-1)           # >= 1
    thr_p = jnp.take_along_axis(
        sorted_desc, (n_keep - 1)[:, None].astype(jnp.int32), axis=-1
    )
    # top_p >= 1 disables the filter outright: the cumulative mass can
    # saturate to exactly 1.0 in float32 (sharp distributions), which
    # would spuriously drop the underflowed tail via `before < top_p`
    thr_p = jnp.where(top_p[:, None] >= 1.0, -jnp.inf, thr_p)

    keep = (logits >= thr_k) & (logits >= thr_p)
    return jnp.where(keep, logits, _NEG_INF) / temperature[:, None]


def filter_logits_ref(logits, temperature, top_k, top_p):
    """NumPy oracle for :func:`filter_logits` — the executable spec the
    equivalence tests hold the jit path to."""
    logits = np.asarray(logits, np.float64).copy()
    B, V = logits.shape
    out = np.empty_like(logits, np.float32)
    for b in range(B):
        row = logits[b]
        temp = max(float(temperature[b]), 1e-6)
        order = np.argsort(-row, kind="stable")
        sorted_desc = row[order]
        k = int(top_k[b])
        thr_k = sorted_desc[min(k, V) - 1] if k > 0 else sorted_desc[-1]
        scaled = sorted_desc / temp
        probs = np.exp(scaled - scaled.max())
        probs /= probs.sum()
        before = np.cumsum(probs) - probs
        n_keep = max(int(np.sum(before < float(top_p[b]))), 1)
        thr_p = sorted_desc[n_keep - 1] if float(top_p[b]) < 1.0 \
            else -np.inf
        keep = (row >= thr_k) & (row >= thr_p)
        out[b] = np.where(keep, row, _NEG_INF) / temp
    return out


def sample_tokens(logits, state):
    """In-jit next-token draw for every row of ``(B, V)`` logits.

    ``state`` is the device serve state carrying the per-slot sampling
    arrays (``temp``/``top_k``/``top_p``/``seed``) and ``lengths``.
    Greedy rows (``temp == 0``) take the plain argmax — the exact op the
    pre-sampler engine ran; sampled rows draw categorically from the
    filtered logits with ``fold_in(PRNGKey(seed), position)`` so the draw
    depends only on (seed, position)."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    temp = state["temp"]
    filtered = filter_logits(logits, temp, state["top_k"], state["top_p"])

    def draw(seed, pos, row):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
        return jax.random.categorical(key, row)

    sampled = jax.vmap(draw)(
        state["seed"], state["lengths"], filtered
    ).astype(jnp.int32)
    return jnp.where(temp > 0.0, sampled, greedy)


def hit_stop(tokens, stop_table):
    """In-jit stop detection: ``(B,)`` bool — did this row's new token
    match any entry of its ``(B, W)`` -1-padded stop set?"""
    return jnp.any(tokens[:, None] == stop_table, axis=-1)
