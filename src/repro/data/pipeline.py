"""Deterministic synthetic LM data pipeline with sharding + prefetch.

Production shape without external deps: a seeded, *stateless-indexable*
token source (any (step, position) is recomputable — the property that
makes data-state checkpointing trivial and restarts exact), per-process
sharding for multi-host launches, and a background prefetch thread so host
data prep overlaps device compute (the pipeline-level cousin of the
paper's overlap argument).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish structure so CE actually decreases during training
    structure: float = 0.8      # prob of deterministic next-token rule


class SyntheticLM:
    """Deterministic synthetic corpus: y[t+1] = (a*y[t]+c) % vocab with
    probability ``structure``, else uniform random (seeded per step).

    ``state()``/``restore()`` capture the iterator exactly (checkpointable
    alongside the model); ``shard(process_index, process_count)`` yields
    only this host's rows.
    """

    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        assert cfg.global_batch % process_count == 0
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        self.local_batch = cfg.global_batch // process_count
        self._step = 0

    # -- checkpointable state ------------------------------------------------
    def state(self) -> dict:
        return {"step": self._step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch"
        self._step = int(state["step"])

    # -- batch generation -----------------------------------------------------
    def _batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rows = []
        base = self.process_index * self.local_batch
        for r in range(self.local_batch):
            rng = np.random.default_rng(
                (cfg.seed, step, base + r)
            )
            toks = np.empty(cfg.seq_len + 1, np.int32)
            toks[0] = rng.integers(cfg.vocab)
            a, c = 6364136223846793005 % cfg.vocab or 1, 1442695040888963407 % cfg.vocab
            rand_mask = rng.random(cfg.seq_len) >= cfg.structure
            rand_toks = rng.integers(cfg.vocab, size=cfg.seq_len)
            for t in range(cfg.seq_len):
                toks[t + 1] = (
                    rand_toks[t] if rand_mask[t]
                    else (a * int(toks[t]) + c) % cfg.vocab
                )
            rows.append(toks)
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        b = self._batch_at(self._step)
        self._step += 1
        return b


class Prefetcher:
    """Background-thread prefetch with bounded queue (overlap host prep)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Exception | None = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        except Exception as e:                      # pragma: no cover
            self._err = e
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
