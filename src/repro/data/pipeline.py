"""Deterministic synthetic LM data pipeline with sharding + prefetch.

Production shape without external deps: a seeded, *stateless-indexable*
token source (any (step, position) is recomputable — the property that
makes data-state checkpointing trivial and restarts exact), per-process
sharding for multi-host launches, and a background prefetch thread so host
data prep overlaps device compute (the pipeline-level cousin of the
paper's overlap argument).
"""

from __future__ import annotations

import dataclasses
import logging
import queue
import threading
import time
from typing import Iterator

import numpy as np

log = logging.getLogger("repro.data.pipeline")


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish structure so CE actually decreases during training
    structure: float = 0.8      # prob of deterministic next-token rule


class SyntheticLM:
    """Deterministic synthetic corpus: y[t+1] = (a*y[t]+c) % vocab with
    probability ``structure``, else uniform random (seeded per step).

    ``state()``/``restore()`` capture the iterator exactly (checkpointable
    alongside the model); ``shard(process_index, process_count)`` yields
    only this host's rows.
    """

    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        assert cfg.global_batch % process_count == 0
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        self.local_batch = cfg.global_batch // process_count
        self._step = 0

    # -- checkpointable state ------------------------------------------------
    def state(self) -> dict:
        return {"step": self._step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch"
        self._step = int(state["step"])

    # -- batch generation -----------------------------------------------------
    def _batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rows = []
        base = self.process_index * self.local_batch
        for r in range(self.local_batch):
            rng = np.random.default_rng(
                (cfg.seed, step, base + r)
            )
            toks = np.empty(cfg.seq_len + 1, np.int32)
            toks[0] = rng.integers(cfg.vocab)
            a, c = 6364136223846793005 % cfg.vocab or 1, 1442695040888963407 % cfg.vocab
            rand_mask = rng.random(cfg.seq_len) >= cfg.structure
            rand_toks = rng.integers(cfg.vocab, size=cfg.seq_len)
            for t in range(cfg.seq_len):
                toks[t + 1] = (
                    rand_toks[t] if rand_mask[t]
                    else (a * int(toks[t]) + c) % cfg.vocab
                )
            rows.append(toks)
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        b = self._batch_at(self._step)
        self._step += 1
        return b


class Prefetcher:
    """Background-thread prefetch with bounded queue (overlap host prep).

    Shutdown contract: the worker never blocks indefinitely in ``q.put``
    (it re-checks the stop event on a timeout), ``close()`` drains the
    queue *while joining* the worker — a one-shot drain would let a
    producer blocked under backpressure repopulate the queue and leak the
    thread — and a producer exception is re-raised by ``close()`` (as
    well as by ``__next__``) instead of being swallowed with the drained
    sentinel.
    """

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Exception | None = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Bounded put that gives up when the stop event is set."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _run(self):
        try:
            for item in self._it:
                if not self._put(item):
                    return
        except Exception as e:
            self._err = e
        finally:
            # end-of-stream sentinel: wakes a consumer blocked in q.get
            # (carrying _err if set).  _put keeps retrying a full queue
            # until it lands or close() takes over the shutdown.
            self._put(None)

    def __iter__(self):
        return self

    def _end_of_stream(self):
        """Raise the producer's error (delivered once) or StopIteration."""
        if self._err is not None:
            err, self._err = self._err, None
            raise err
        raise StopIteration

    def __next__(self):
        # Never block on a queue no one will refill: once the worker is
        # gone (close() drained its sentinel, or it died) an empty queue
        # is end-of-stream, not "wait for more".
        while True:
            try:
                item = self._q.get(timeout=0.05)
                break
            except queue.Empty:
                if self._stop.is_set() or not self._thread.is_alive():
                    # the worker may have published its final item(s) and
                    # exited between our Empty and the liveness check —
                    # drain before declaring end-of-stream
                    try:
                        item = self._q.get_nowait()
                        break
                    except queue.Empty:
                        self._end_of_stream()
        if item is None:
            self._end_of_stream()
        return item

    def close(self, timeout: float = 5.0):
        """Stop and join the worker; re-raise a pending producer error.

        Drains the queue in lockstep with the join so a worker blocked in
        ``q.put`` under backpressure gets unblocked, observes the stop
        event, and exits — then drains whatever it published last (incl.
        the ``None`` sentinel) so nothing keeps the thread referenced.
        """
        self._stop.set()
        deadline = time.monotonic() + timeout
        while self._thread.is_alive() and time.monotonic() < deadline:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.05)
        if self._thread.is_alive():                  # pragma: no cover
            log.warning("Prefetcher worker did not exit within %.1fs "
                        "(producer stuck outside q.put?)", timeout)
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        if self._err is not None:
            # deliver once: a repeated close() (e.g. in a finally block)
            # must be a no-op, not re-raise and mask a primary exception
            err, self._err = self._err, None
            raise err
