from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM  # noqa: F401
