"""Version-portability shims for jax API drift.

The repo targets the current jax API; older installs (0.4.x) spell several
things differently.  Each shim resolves the available spelling once at
import time.  Sibling shims live next to their consumers:
``repro.kernels.tpu_compiler_params`` (Pallas CompilerParams rename) and
``repro.launch.mesh.make_mesh_compat`` (``axis_types`` kwarg).
"""

from __future__ import annotations

import jax

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)
if _NEW_SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _OLD_SHARD_MAP
else:
    _OLD_SHARD_MAP = None

#: Partial-manual shard_map (axis_names a strict subset of the mesh axes)
#: only compiles reliably on the new API; old XLA hits a manual-subgroup
#: check failure when the surrounding graph reshards (see optim/compression).
shard_map_partial_ok = _NEW_SHARD_MAP is not None


def shard_map_compat(
    f,
    *,
    mesh,
    in_specs,
    out_specs,
    axis_names: set | None = None,
    check: bool = False,
):
    """``jax.shard_map`` across the new/old API split.

    New API: ``axis_names={...}`` marks the manual axes (others stay
    automatic) and ``check_vma`` toggles replication checking.  Old API
    spells those ``auto=<complement>`` and ``check_rep``.
    """
    if _NEW_SHARD_MAP is not None:
        kwargs = {"check_vma": check}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return _NEW_SHARD_MAP(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    auto = (
        frozenset(mesh.axis_names) - frozenset(axis_names)
        if axis_names is not None
        else frozenset()
    )
    return _OLD_SHARD_MAP(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check,
        auto=auto,
    )


def axis_size_compat(axis_name):
    """``jax.lax.axis_size`` (newer jax) or the classic ``psum(1, axis)``."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)
