import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count on first init).  512 placeholder host devices let
# jax.make_mesh build the production meshes; nothing is ever allocated —
# every input is a ShapeDtypeStruct and we stop at .lower().compile().

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:

1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
2. constructs ShapeDtypeStruct stand-ins for every input of the step
   function (params, optimizer state, batch, KV caches) with the baseline
   shardings (TP over ``model``, DP over ``data``/``pod``, FSDP for
   params+optimizer, split-KV decode);
3. ``jit(step).lower(...).compile()`` — sharding mismatches, unsupported
   collectives, or capacity blowups fail HERE, which is the point;
4. records ``memory_analysis()`` (proves it fits), ``cost_analysis()``,
   and the HLO-derived roofline terms (FLOPs / HBM bytes / collective wire
   bytes by axis) into a JSON consumed by EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    python -m repro.launch.dryrun --all [--multipod-only|--singlepod-only]
    python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.core.hlo_analysis import analyze_hlo_text
from repro.core.roofline import report_from_cost
from repro.launch.mesh import make_production_mesh, mesh_axes_dict
from repro.models.model_zoo import ModelBundle
from repro.models.sharding import (
    defs_to_shapes,
    defs_to_specs,
    spec_for,
    use_sharding,
)
from repro.train.train_step import TrainConfig, make_train_step
from repro.optim.adamw import init_opt_state

#: baseline rule overrides per mode (see DESIGN.md §4).
#: train/prefill: sequence-parallel activations at layer boundaries
#: (Megatron-SP; layer-boundary remat residency /16) — without it the
#: train cells hold 32+ layers x full-seq activations per chip.
TRAIN_RULES = {"seq": ("model",)}
PREFILL_RULES = {"seq": ("model",), "kv_seq": ("data", "model")}
DECODE_RULES = {"kv_seq": ("data", "model")}
FSDP_AXES = ("data",)

#: §Perf-winning configurations (EXPERIMENTS.md) — reproducible via
#: ``--optimized``.  Keys: (arch, shape) -> lower_cell overrides.
OPTIMIZED_CELLS = {
    # worst-fraction cell: idle TP axis (heads=14, vocab=151655 don't
    # divide 16) reassigned to batch; remat dots for the freed memory.
    ("internvl2-1b", "train_4k"): dict(
        rules={**TRAIN_RULES, "batch": ("pod", "data", "model")},
        remat="dots",
    ),
    # most collective-bound cell: drop gradient accumulation (collective
    # traffic repeats per microbatch) — frac 5.5% -> 10.2%.
    ("gemma3-27b", "train_4k"): dict(n_micro=1),
    # paper-representative cell: MLA storage-dtype streaming is already in
    # the model (models/attention.py); baseline == optimized here.
    ("deepseek-v2-236b", "decode_32k"): dict(),
}


def input_specs(arch: str, shape_name: str = "train_4k"):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns (bundle, inputs:dict) where inputs carries ``batch`` plus, per
    mode, params/opt_state (train) or params/caches (serve) structs.
    """
    bundle = ModelBundle(get_config(arch))
    shape = SHAPES[shape_name]
    dtype = bundle.cfg.dtype

    batch_defs = bundle.input_defs(shape)
    batch = defs_to_shapes(batch_defs, dtype)
    params = defs_to_shapes(bundle.param_defs(), dtype)
    out = {"batch": batch, "params": params, "mode": shape.mode}
    if shape.mode == "train":
        out["opt_state"] = {
            "master": defs_to_shapes(bundle.param_defs(), "float32"),
            "mu": defs_to_shapes(bundle.param_defs(), "float32"),
            "nu": defs_to_shapes(bundle.param_defs(), "float32"),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        out["ef"] = jax.tree.map(
            lambda _: jax.ShapeDtypeStruct((), jnp.float32), params
        )
    else:
        out["caches"] = defs_to_shapes(
            bundle.cache_defs(shape.global_batch, bundle.decode_cache_len(shape)),
            dtype,
        )
    return bundle, out


def _shardings_for(bundle, mesh, shape_name: str, rules, zero_stage: int = 3):
    shape = SHAPES[shape_name]
    defs = bundle.param_defs()
    param_s = defs_to_specs(
        defs, mesh, rules,
        fsdp_axes=FSDP_AXES if zero_stage >= 3 else (),
    )
    batch_defs = bundle.input_defs(shape)
    batch_s = defs_to_specs(batch_defs, mesh, rules)
    out = {"params": param_s, "batch": batch_s}
    if shape.mode == "train":
        member = defs_to_specs(defs, mesh, rules, fsdp_axes=FSDP_AXES)
        out["opt_state"] = {
            "master": member,
            "mu": member,
            "nu": member,
            "step": NamedSharding(mesh, P()),
        }
        out["ef"] = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), defs,
            is_leaf=lambda x: hasattr(x, "axes"),
        )
    else:
        cache_defs = bundle.cache_defs(
            shape.global_batch, bundle.decode_cache_len(shape)
        )
        out["caches"] = defs_to_specs(cache_defs, mesh, rules)
    return out


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    rules: dict | None = None,
    zero_stage: int = 3,
    n_micro: int | None = None,
    remat: str = "full",
    verbose: bool = True,
):
    """Lower + compile one cell. Returns (record, compiled)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    shape = SHAPES[shape_name]
    bundle, specs_in = input_specs(arch, shape_name)
    mode = shape.mode
    if rules is None:
        rules = {
            "train": TRAIN_RULES,
            "prefill": PREFILL_RULES,
            "decode": DECODE_RULES,
        }[mode]
    sh = _shardings_for(bundle, mesh, shape_name, rules,
                        zero_stage=zero_stage)

    scalar = NamedSharding(mesh, P())
    logits_spec = NamedSharding(
        mesh,
        spec_for(
            (SHAPES[shape_name].global_batch, bundle.cfg.vocab),
            ("batch", "vocab"), mesh, rules,
        ),
    )

    t0 = time.time()
    if mode == "train":
        # gradient accumulation for the wide archs: transient activation
        # buffers scale with the microbatch, grads accumulate sharded.
        if n_micro is None:
            n_micro = 4 if bundle.cfg.d_model >= 5000 else 1
        tcfg = TrainConfig(
            remat=remat, rules=rules, fsdp_axes=FSDP_AXES,
            n_microbatches=n_micro, zero_stage=zero_stage,
        )
        step = make_train_step(bundle, mesh, tcfg)
        metrics_s = {k: scalar for k in ("loss", "ce", "aux", "grad_norm", "lr")}
        jitted = jax.jit(
            step,
            in_shardings=(sh["params"], sh["opt_state"], sh["ef"], sh["batch"]),
            out_shardings=(sh["params"], sh["opt_state"], sh["ef"], metrics_s),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(
            specs_in["params"], specs_in["opt_state"], specs_in["ef"],
            specs_in["batch"],
        )
    else:
        fn = bundle.prefill if mode == "prefill" else bundle.decode_step

        def serve_step(params, batch, caches, _fn=fn):
            with use_sharding(mesh, rules):
                return _fn(params, batch, caches)

        jitted = jax.jit(
            serve_step,
            in_shardings=(sh["params"], sh["batch"], sh["caches"]),
            out_shardings=(logits_spec, sh["caches"]),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(
            specs_in["params"], specs_in["batch"], specs_in["caches"]
        )
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    mesh_axes = mesh_axes_dict(mesh)
    cost = analyze_hlo_text(compiled.as_text(), mesh_axes)
    report = report_from_cost(
        cost,
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        num_chips=math.prod(mesh_axes.values()),
        model_flops=bundle.model_flops(shape),
        model_bytes=bundle.model_bytes(shape),
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (
                ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes
            ),
        },
        "cost_analysis": {
            "xla_flops_per_device": ca.get("flops", 0.0),
            "xla_bytes_per_device": ca.get("bytes accessed", 0.0),
        },
        "roofline": report.to_json(),
        "collectives": [
            {
                "op": c.opcode,
                "wire_bytes": c.wire_bytes,
                "group_size": c.group_size,
                "axes": list(c.axes),
                "count": c.count,
            }
            for c in cost.collectives
        ],
    }
    if verbose:
        print(
            f"[{arch} × {shape_name} × {mesh_name}] compile {t_compile:.1f}s | "
            f"peak/dev {record['memory_analysis']['peak_bytes_per_device']/2**30:.2f} GiB | "
            f"flops/dev {cost.flops:.3g} | hbm/dev {cost.hbm_bytes:.3g} B | "
            f"coll/dev {cost.collective_wire_bytes:.3g} B | "
            f"dominant {report.dominant} | frac {report.roofline_fraction:.1%} "
            f"| bw-frac {report.bw_fraction:.1%}"
        )
        print("  memory_analysis:", ma)
    return record, compiled


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--singlepod-only", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf-winning per-cell configs")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if not args.multipod_only:
        meshes.append(False)
    if not args.singlepod_only:
        meshes.append(True)

    records = []
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            ok, why = shape_applicable(arch, shape_name)
            if not ok:
                for mp in meshes:
                    records.append({
                        "arch": arch, "shape": shape_name,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "skipped", "reason": why,
                    })
                print(f"[{arch} × {shape_name}] SKIP: {why}")
                continue
            for mp in meshes:
                try:
                    overrides = (
                        OPTIMIZED_CELLS.get((arch, shape_name), {})
                        if args.optimized else {}
                    )
                    rec, _ = lower_cell(
                        arch, shape_name, multi_pod=mp, **overrides
                    )
                    records.append(rec)
                except Exception as e:
                    failures += 1
                    traceback.print_exc()
                    records.append({
                        "arch": arch, "shape": shape_name,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "failed", "error": f"{type(e).__name__}: {e}",
                    })
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    print(f"\n{n_ok} ok, {n_skip} skipped, {failures} failed -> {args.out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
