"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires together the whole stack: mesh, model bundle, placement policy (from
the planner unless forced), data pipeline with prefetch, fault-tolerant
supervisor with async checkpoints and straggler monitoring.  On this CPU
container it runs the smoke-scale configs end-to-end; on a TPU fleet the
same file is the per-process entry point (jax.distributed handles the
process group; the mesh helper sizes itself from jax.device_count()).
"""

from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Runtime
from repro.checkpoint import Checkpointer
from repro.configs import get_config, smoke_config
from repro.core.placement import registered_policies
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.launch.mesh import make_mesh_for
from repro.models.model_zoo import ModelBundle
from repro.optim.adamw import AdamWConfig
from repro.runtime import Supervisor, SupervisorConfig
from repro.train import TrainConfig, init_train_state, make_train_step

log = logging.getLogger("repro.train")


def make_runtime(
    bundle: ModelBundle,
    mesh,
    policy_arg: str | None,
    *,
    batch: int = 8,
    seq: int = 128,
    remat: str = "full",
) -> Runtime:
    """The run's placement runtime: forced policy or planner-selected.

    A forced ``--policy`` accepts any :func:`repro.core.placement.
    parse_policy` spelling (registered name, ``role=tier:strategy``
    grammar, JSON) and is validated against the mesh up front.  The auto
    path runs the planner on the real run shape — including the gradient
    all-reduce terms for the mesh's data/pod axes — restricted to the
    tiers this runtime realizes, and logs the top-candidate table
    (:meth:`Runtime.explain`).
    """
    if policy_arg:
        rt = Runtime(bundle, mesh, policy_arg)
        log.info("placement policy forced: %s", rt.policy.name)
        return rt
    rt = Runtime.auto(
        bundle, mesh, phase="train",
        batch=batch, seq=seq, remat=remat != "none",
    )
    best = rt.plans["train"]
    if best.picked not in best.feasible:
        for name, p in best.predictions.items():
            log.warning("planner OOM: %s overflows pools %s",
                        name, ", ".join(p.overflow_pools) or "none")
    log.info("planner picked %s", rt.policy.name)
    return rt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1x1",
                    help="e.g. 2x2x2 -> (pod,data,model); 4x2 -> (data,model)")
    ap.add_argument("--donor", type=int, default=1,
                    help="prepend an ICI donor axis of this size (>=2 "
                         "unlocks the peer placement tiers)")
    ap.add_argument("--remote-donor", type=int, default=1,
                    help="prepend a DCN donor axis of this size (>=2 "
                         "unlocks kv_remote_hbm)")
    ap.add_argument(
        "--policy", default=None,
        help="force a placement policy: a registered name "
             f"({', '.join(registered_policies())}), the compact "
             "role=tier[:strategy][,...] grammar (e.g. "
             "'opt=host:stream'), or policy JSON; default: planner",
    )
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="price placements from a measurement-calibrated "
                         "hardware model: load this calibration.json, or "
                         "run the calibration microbenchmarks and save it "
                         "there when the file does not exist (spec-sheet "
                         "constants otherwise)")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    if args.calibration:
        from repro.core.calibration import load_or_calibrate

        cal = load_or_calibrate(args.calibration, activate=True)
        log.info("calibrated hardware model active:\n%s", cal.summary())

    dims = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("pod", "data", "model")[-len(dims):] if len(dims) > 1 else ("data",)
    if args.remote_donor > 1:
        dims, axes = (args.remote_donor, *dims), ("donor_pod", *axes)
    if args.donor > 1:
        dims, axes = (args.donor, *dims), ("donor", *axes)
    mesh = make_mesh_for(dims, axes)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    bundle = ModelBundle(cfg)
    rt = make_runtime(
        bundle, mesh, args.policy,
        batch=args.batch, seq=args.seq, remat=args.remat,
    )
    policy = rt.policy

    tcfg = TrainConfig(
        remat=args.remat,
        n_microbatches=args.microbatches,
        compress_pod_grads=args.compress_pod_grads,
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 5 + 1)),
    )
    params, opt_state, ef = init_train_state(
        bundle, mesh, jax.random.PRNGKey(0), tcfg, policy
    )
    step_fn = jax.jit(
        # sharding is re-constrained inside the step: output placement is
        # the input placement
        make_train_step(bundle, mesh, tcfg, policy),
        donate_argnums=(0, 1),  # repro: lint-disable=donate-without-out-shardings
    )

    data = SyntheticLM(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch),
        process_index=jax.process_index(),
        process_count=jax.process_count(),
    )
    it = Prefetcher(data)

    ckpt = Checkpointer(args.ckpt_dir)
    sup = Supervisor(ckpt, SupervisorConfig(checkpoint_every=args.ckpt_every))

    state = {"params": params, "opt": opt_state, "ef": ef}
    losses = []

    def one_step(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, e, metrics = step_fn(
            state["params"], state["opt"], state["ef"], batch
        )
        losses.append(float(metrics["loss"]))
        if len(losses) % args.log_every == 0:
            log.info(
                "step %d loss %.4f grad_norm %.3f",
                len(losses), losses[-1], float(metrics["grad_norm"]),
            )
        return {"params": p, "opt": o, "ef": e}, metrics

    state, step = sup.run(
        state, one_step, it, args.steps, extra_state=lambda: {"data": data.state()}
    )
    it.close()
    log.info(
        "done: %d steps, loss %.4f -> %.4f, straggler stats %s",
        step, losses[0] if losses else float("nan"),
        losses[-1] if losses else float("nan"), sup.monitor.summary(),
    )


if __name__ == "__main__":
    main()
