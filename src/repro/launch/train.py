"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires together the whole stack: mesh, model bundle, placement policy (from
the planner unless forced), data pipeline with prefetch, fault-tolerant
supervisor with async checkpoints and straggler monitoring.  On this CPU
container it runs the smoke-scale configs end-to-end; on a TPU fleet the
same file is the per-process entry point (jax.distributed handles the
process group; the mesh helper sizes itself from jax.device_count()).
"""

from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import ShapeSpec, get_config, smoke_config
from repro.core.placement import POLICIES, donor_allow_flags
from repro.core.planner import plan
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.launch.mesh import make_mesh_for
from repro.models.model_zoo import ModelBundle
from repro.optim.adamw import AdamWConfig
from repro.runtime import Supervisor, SupervisorConfig
from repro.train import TrainConfig, init_train_state, make_train_step

log = logging.getLogger("repro.train")


def pick_policy(
    bundle: ModelBundle,
    mesh,
    name: str | None,
    *,
    batch: int = 8,
    seq: int = 128,
    remat: str = "full",
):
    """Planner-selected policy for this training run (unless forced).

    Builds the per-chip :func:`train_profile` from the real run shape —
    including the gradient all-reduce terms for the mesh's data/pod axes —
    and only offers the planner tiers this runtime can reach.
    """
    if name:
        return POLICIES[name]
    axes = dict(mesh.shape)
    num_chips = int(mesh.devices.size)
    prof = bundle.train_workload(
        ShapeSpec("cli", seq, batch, "train"),
        num_chips=num_chips,
        data_axis_size=axes.get("data", 1),
        pod_axis_size=axes.get("pod", 1),
        remat=remat != "none",
    )
    # Offer exactly the tiers this mesh realizes: host tiers when the
    # backend has a host memory space, peer tiers when the mesh has a
    # 'donor' axis, remote tiers when it has a 'donor_pod' axis (the
    # donor-axis sharding in make_state_specs physically produces them).
    best, preds = plan(prof, **donor_allow_flags(mesh))
    for p in preds:
        log.info("planner: %s", p.explain())
    if not best.fits:
        for p in preds:
            log.warning("planner OOM: %s overflows pools %s",
                        p.policy, ", ".join(p.overflow_pools) or "none")
    log.info("planner picked %s", best.policy)
    return POLICIES[best.policy]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1x1",
                    help="e.g. 2x2x2 -> (pod,data,model); 4x2 -> (data,model)")
    ap.add_argument("--donor", type=int, default=1,
                    help="prepend an ICI donor axis of this size (>=2 "
                         "unlocks the peer placement tiers)")
    ap.add_argument("--remote-donor", type=int, default=1,
                    help="prepend a DCN donor axis of this size (>=2 "
                         "unlocks kv_remote_hbm)")
    ap.add_argument("--policy", default=None, choices=[None, *POLICIES])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(message)s")

    dims = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("pod", "data", "model")[-len(dims):] if len(dims) > 1 else ("data",)
    if args.remote_donor > 1:
        dims, axes = (args.remote_donor, *dims), ("donor_pod", *axes)
    if args.donor > 1:
        dims, axes = (args.donor, *dims), ("donor", *axes)
    mesh = make_mesh_for(dims, axes)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    bundle = ModelBundle(cfg)
    policy = pick_policy(
        bundle, mesh, args.policy,
        batch=args.batch, seq=args.seq, remat=args.remat,
    )

    tcfg = TrainConfig(
        remat=args.remat,
        n_microbatches=args.microbatches,
        compress_pod_grads=args.compress_pod_grads,
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 5 + 1)),
    )
    params, opt_state, ef = init_train_state(
        bundle, mesh, jax.random.PRNGKey(0), tcfg, policy
    )
    step_fn = jax.jit(
        make_train_step(bundle, mesh, tcfg, policy), donate_argnums=(0, 1)
    )

    data = SyntheticLM(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch),
        process_index=jax.process_index(),
        process_count=jax.process_count(),
    )
    it = Prefetcher(data)

    ckpt = Checkpointer(args.ckpt_dir)
    sup = Supervisor(ckpt, SupervisorConfig(checkpoint_every=args.ckpt_every))

    state = {"params": params, "opt": opt_state, "ef": ef}
    losses = []

    def one_step(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, e, metrics = step_fn(
            state["params"], state["opt"], state["ef"], batch
        )
        losses.append(float(metrics["loss"]))
        if len(losses) % args.log_every == 0:
            log.info(
                "step %d loss %.4f grad_norm %.3f",
                len(losses), losses[-1], float(metrics["grad_norm"]),
            )
        return {"params": p, "opt": o, "ef": e}, metrics

    state, step = sup.run(
        state, one_step, it, args.steps, extra_state=lambda: {"data": data.state()}
    )
    it.close()
    log.info(
        "done: %d steps, loss %.4f -> %.4f, straggler stats %s",
        step, losses[0] if losses else float("nan"),
        losses[-1] if losses else float("nan"), sup.monitor.summary(),
    )


if __name__ == "__main__":
    main()
