"""Launchers: production meshes, multi-pod dry-run, train/serve CLIs.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import — import it only in
a dedicated process (its __main__ / subprocess), never from tests.
"""

from repro.launch.mesh import make_mesh_for, make_production_mesh, mesh_axes_dict  # noqa: F401
