"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Continuous-batching server over the model zoo with a placement policy for
the KV cache (the paper's Fig. 17 knob).  Feeds a synthetic request stream
and reports tokens/s + per-phase latency.
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.placement import (
    PoolSplit,
    extract_pool_split,
    registered_policies,
)
from repro.launch.mesh import make_mesh_for
from repro.models.model_zoo import ModelBundle
from repro.serve import (
    Cluster,
    DisaggConfig,
    Request,
    SamplingParams,
    ServeConfig,
    Server,
)

log = logging.getLogger("repro.serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--donor", type=int, default=1,
                    help="prepend an ICI donor axis of this size (>=2 "
                         "unlocks kv_peer_hbm / weights_peer_hbm)")
    ap.add_argument("--remote-donor", type=int, default=1,
                    help="prepend a DCN donor axis of this size (>=2 "
                         "unlocks kv_remote_hbm)")
    ap.add_argument(
        "--policy", default="auto",
        help="'auto' consults the placement planner (datapath-bound "
             "model); otherwise a registered name "
             f"({', '.join(registered_policies())}), the compact "
             "role=tier[:strategy][,...] grammar (e.g. "
             "'kv=host:stream,params=peer_hbm'), or policy JSON",
    )
    ap.add_argument(
        "--pools", default=None, metavar="prefill:N,decode:M",
        help="serve disaggregated (repro.serve.disagg): split the "
             "device set into a prefill pool and a decode pool joined "
             "by the DCN handoff.  'auto' lets plan_pool_split pick the "
             "split; the directive may equivalently ride inside "
             "--policy as pools=prefill:N,decode:M.  Ignores --mesh/"
             "--donor (the cluster owns its device partition).",
    )
    ap.add_argument(
        "--auto-replan", action="store_true",
        help="re-run the planner as cache occupancy crosses band "
             "boundaries and migrate the live KV cache/params when the "
             "pick changes (planner-owned policies only)",
    )
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest-probability tokens "
                         "(0 = no top-k filter)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = no top-p filter)")
    ap.add_argument("--seed", type=int, default=0,
                    help="per-request sampling seed base (request rid is "
                         "added so rows draw independently)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound on waiting requests (backpressure); "
                         "default unbounded")
    ap.add_argument("--preempt", action="store_true",
                    help="enable planner-priced KV preemption: starved "
                         "waiters may evict a victim slot to the cheapest "
                         "realizable far tier")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="price placements from a measurement-calibrated "
                         "hardware model: load this calibration.json, or "
                         "run the calibration microbenchmarks and save it "
                         "there when the file does not exist (spec-sheet "
                         "constants otherwise)")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    if args.calibration:
        from repro.core.calibration import load_or_calibrate

        cal = load_or_calibrate(args.calibration, activate=True)
        log.info("calibrated hardware model active:\n%s", cal.summary())
    policy = None if args.policy == "auto" else args.policy
    # the pools= directive rides inside --policy (its value has commas,
    # so it is carved out before the role grammar parses) or arrives as
    # the explicit --pools flag; either selects the disaggregated path
    pool_split, policy = extract_pool_split(policy)
    if args.pools and args.pools != "auto":
        pool_split = PoolSplit.parse(args.pools)
    disaggregated = bool(args.pools) or pool_split is not None

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    bundle = ModelBundle(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))

    if disaggregated:
        if args.mesh != "1x1" or args.donor > 1 or args.remote_donor > 1:
            log.warning(
                "--pools ignores --mesh/--donor/--remote-donor: the "
                "cluster partitions the device set itself"
            )
        server = Cluster(
            bundle,
            DisaggConfig(
                batch_slots=args.slots,
                max_len=args.max_len,
                split=pool_split,
                policy=policy,
                max_queue=args.max_queue,
                preempt=args.preempt,
            ),
            params,
        )
        log.info(
            "serving disaggregated (%s) with placement policy %s",
            server.split.to_str(), server.decode.policy.name,
        )
    else:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[-len(dims):]
        if args.remote_donor > 1:
            dims, axes = (args.remote_donor, *dims), ("donor_pod", *axes)
        if args.donor > 1:
            dims, axes = (args.donor, *dims), ("donor", *axes)
        mesh = make_mesh_for(dims, axes) if np.prod(dims) > 1 else None
        server = Server(
            bundle,
            ServeConfig(
                batch_slots=args.slots,
                max_len=args.max_len,
                policy=policy,
                auto_replan=args.auto_replan,
                max_queue=args.max_queue,
                preempt=args.preempt,
            ),
            params,
            mesh=mesh,
        )
        log.info("serving with placement policy %s", server.policy.name)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        server.add_request(
            Request(
                rid=rid,
                prompt=rng.integers(
                    0, cfg.vocab, size=args.prompt_len
                ).astype(np.int32),
                max_new_tokens=args.max_new,
                sampling=SamplingParams(
                    temperature=args.temperature,
                    top_k=args.top_k,
                    top_p=args.top_p,
                    seed=args.seed + rid,
                ),
            )
        )
    t0 = time.perf_counter()
    server.run_until_done()
    dt = time.perf_counter() - t0
    total_tokens = args.requests * args.max_new
    tp = server.throughput()
    stats = server.stats()
    if disaggregated:
        led = stats["handoff"]
        log.info(
            "served %d requests, %d tokens in %.2fs -> %.1f tok/s "
            "(%s, policy %s) | prefill %.1f tok/s | decode %.1f tok/s "
            "| handoff: %d published / %d adopted / %d lost "
            "(%d bytes crossed donor_pod, %d replays)",
            args.requests, total_tokens, dt, total_tokens / dt,
            server.split.to_str(), server.decode.policy.name,
            tp["prefill_tps"], tp["decode_tps"],
            led["published"], led["adopted"], led["lost"],
            led["bytes_published"], stats["handoff_replays"],
        )
    else:
        log.info(
            "served %d requests, %d tokens in %.2fs -> %.1f tok/s "
            "(policy %s, %d replans / %d migrations, %d preemptions / "
            "%d promotions) | prefill %.1f tok/s | decode %.1f tok/s",
            args.requests, total_tokens, dt, total_tokens / dt,
            server.policy.name, stats["replans"], stats["migrations"],
            stats["preemptions"], stats["promotions"],
            tp["prefill_tps"], tp["decode_tps"],
        )


if __name__ == "__main__":
    main()
