"""Production meshes.

Axis semantics (see DESIGN.md §4): ``model`` = tensor/expert parallelism
(highest collective volume — lives on the fastest ICI axis), ``data`` =
data/FSDP parallelism, ``pod`` = the DCN axis (gradient all-reduce once per
step, or pipeline handoffs).  Functions, not module constants — importing
this module never touches jax device state.

All mesh construction goes through :func:`make_mesh_compat`, which papers
over the ``jax.sharding.AxisType`` API drift: newer jax wants explicit
``axis_types``; older installs (e.g. 0.4.x) have no such attribute and
``jax.make_mesh`` rejects the kwarg.
"""

from __future__ import annotations

import jax


def _axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where supported, ``{}`` otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh_compat(devices_shape: tuple[int, ...], axes: tuple[str, ...]):
    """Version-portable ``jax.make_mesh`` (omits axis_types when absent)."""
    return jax.make_mesh(
        devices_shape, axes, **_axis_types_kwargs(len(axes))
    )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh_for(devices_shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests, benchmarks, elastic rescale)."""
    return make_mesh_compat(devices_shape, axes)


def mesh_axes_dict(mesh) -> dict[str, int]:
    return dict(mesh.shape)
