"""Production meshes.

Axis semantics (see DESIGN.md §4): ``model`` = tensor/expert parallelism
(highest collective volume — lives on the fastest ICI axis), ``data`` =
data/FSDP parallelism, ``pod`` = the DCN axis (gradient all-reduce once per
step, or pipeline handoffs).  Functions, not module constants — importing
this module never touches jax device state.

**Donor axes** (the paper's peer-memory experiments, Figs. 15-17): an axis
named :data:`DONOR_AXIS` (``"donor"``, laid on ICI) or
:data:`REMOTE_DONOR_AXIS` (``"donor_pod"``, laid on DCN) marks a group of
chips whose memory is donated to the computation.  No sharding rule maps a
logical tensor axis onto a donor axis, so ordinary tensors are replicated
over it; only :mod:`repro.core.placement`'s peer/remote tiers shard across
it, putting their bytes a link-hop away in the donor slices' pools —
which is what makes ``kv_peer_hbm``/``weights_peer_hbm``/``opt_peer_host``
/``kv_remote_hbm`` executable instead of analysis-only.  Build one with
:func:`make_donor_mesh`, or pass any shape containing the axis name to
:func:`make_mesh_for`.

All mesh construction goes through :func:`make_mesh_compat`, which papers
over the ``jax.sharding.AxisType`` API drift: newer jax wants explicit
``axis_types``; older installs (e.g. 0.4.x) have no such attribute and
``jax.make_mesh`` rejects the kwarg.
"""

from __future__ import annotations

import jax

from repro.core.placement import DONOR_AXIS, REMOTE_DONOR_AXIS  # noqa: F401


def _axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where supported, ``{}`` otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh_compat(devices_shape: tuple[int, ...], axes: tuple[str, ...]):
    """Version-portable ``jax.make_mesh`` (omits axis_types when absent)."""
    return jax.make_mesh(
        devices_shape, axes, **_axis_types_kwargs(len(axes))
    )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh_for(devices_shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests, benchmarks, elastic rescale)."""
    return make_mesh_compat(devices_shape, axes)


def make_donor_mesh(
    compute_shape: tuple[int, ...] = (1,),
    compute_axes: tuple[str, ...] = ("data",),
    donor_size: int = 2,
    *,
    remote: bool = False,
):
    """Compute mesh with a leading donor axis of ``donor_size`` slices.

    The donor axis is the ICI :data:`DONOR_AXIS` by default or the DCN
    :data:`REMOTE_DONOR_AXIS` with ``remote=True``; total devices used =
    ``donor_size * prod(compute_shape)``.  Slice 0 is 'the' local slice
    only by convention — peer-tier tensors are sharded across all slices,
    so every slice is simultaneously accessor and donor (the symmetric
    form of the paper's accessor/donor pairing).
    """
    axis = REMOTE_DONOR_AXIS if remote else DONOR_AXIS
    if donor_size < 2:
        raise ValueError(f"donor axis needs >= 2 slices, got {donor_size}")
    return make_mesh_compat(
        (donor_size, *compute_shape), (axis, *compute_axes)
    )


def mesh_axes_dict(mesh) -> dict[str, int]:
    return dict(mesh.shape)
