"""Production meshes.

Axis semantics (see DESIGN.md §4): ``model`` = tensor/expert parallelism
(highest collective volume — lives on the fastest ICI axis), ``data`` =
data/FSDP parallelism, ``pod`` = the DCN axis (gradient all-reduce once per
step, or pipeline handoffs).  Functions, not module constants — importing
this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh_for(devices_shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests, benchmarks, elastic rescale)."""
    return jax.make_mesh(
        devices_shape,
        axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def mesh_axes_dict(mesh) -> dict[str, int]:
    return dict(mesh.shape)
