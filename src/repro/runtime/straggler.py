"""Straggler detection: step-time statistics + slow-step policy.

At thousand-node scale the synchronous step time is the max over workers
(the paper's own multi-worker timing rule, §III-B: total = max of final
timestamps).  A persistent straggler therefore sets the fleet's pace.  The
monitor keeps a rolling step-time distribution; a step exceeding
``threshold x median`` is flagged, and a configurable number of consecutive
flags triggers the mitigation callback (checkpoint-and-restart around the
slow host, the standard TPU-fleet response, wired up in supervisor.py).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from collections import deque
from typing import Callable


@dataclasses.dataclass
class StragglerConfig:
    window: int = 50             # steps in the rolling window
    threshold: float = 2.0       # flag if step > threshold * median
    patience: int = 3            # consecutive flags before mitigation
    warmup_steps: int = 5        # ignore compile/first steps


class StepTimeMonitor:
    def __init__(
        self,
        cfg: StragglerConfig = StragglerConfig(),
        on_straggler: Callable[[dict], None] | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.clock = clock
        self.times: deque[float] = deque(maxlen=cfg.window)
        self._start: float | None = None
        self._consecutive = 0
        self.flags: list[dict] = []
        self.steps = 0

    def __enter__(self):
        self._start = self.clock()
        return self

    def __exit__(self, *exc):
        self.record(self.clock() - self._start)
        return False

    def record(self, dt: float) -> bool:
        """Record one step; returns True if flagged as a straggler step.

        Flagged samples are kept OUT of the rolling window: appending them
        would inflate the median baseline, so a persistent straggler would
        stop exceeding ``threshold x median`` after a few flags and go
        undetected — the window holds only healthy steps, the flags list
        holds the stragglers, and ``summary()`` reports both.
        """
        self.steps += 1
        if self.steps <= self.cfg.warmup_steps:
            return False
        flagged = False
        if len(self.times) >= 5:
            med = statistics.median(self.times)
            if dt > self.cfg.threshold * med:
                flagged = True
                self._consecutive += 1
                info = {
                    "step": self.steps,
                    "dt": dt,
                    "median": med,
                    "ratio": dt / med,
                    "consecutive": self._consecutive,
                }
                self.flags.append(info)
                if (
                    self._consecutive >= self.cfg.patience
                    and self.on_straggler is not None
                ):
                    self.on_straggler(info)
                    self._consecutive = 0
            else:
                self._consecutive = 0
        if not flagged:
            self.times.append(dt)
        return flagged

    def summary(self) -> dict:
        """Healthy-window stats + straggler count.  ``median_s``/``p99_s``
        describe the clean baseline (flagged steps excluded, consistent
        with ``record``); ``flags`` counts the excluded stragglers."""
        if not self.times:
            return {"steps": self.steps, "flags": len(self.flags)}
        ts = sorted(self.times)
        return {
            "steps": self.steps,
            "median_s": statistics.median(ts),
            "p99_s": ts[min(len(ts) - 1, int(0.99 * len(ts)))],
            "flags": len(self.flags),
        }
