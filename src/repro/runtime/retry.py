"""Shared retry policy: capped exponential backoff + deterministic jitter.

One policy object prices every retryable operation in the repo — live
migrations and spill/promote copies (:mod:`repro.serve.engine`),
evacuations (:meth:`repro.api.Runtime.evacuate`), and checkpoint writes
(:class:`repro.checkpoint.checkpointer.Checkpointer`).  The knobs are the
standard ones (attempt cap, base/max delay, jitter fraction, total time
budget), but two choices are deliberate:

* **Deterministic jitter.**  The jitter draw is seeded from
  ``(seed, attempt)``, never from global randomness — a faulted run
  replays exactly, which the chaos soak and the bit-identity tests
  depend on.
* **Caller-declared retryability.**  ``retry_on`` has no default broad
  enough to catch real bugs: callers name the transient types
  (:class:`repro.core.faults.TransientFault` for injected link hiccups,
  ``OSError`` for checkpoint I/O).  A :class:`~repro.core.placement.
  DonorAxisError` is *deterministic* — retrying it would just burn the
  budget — so migration call sites exclude it.
"""

from __future__ import annotations

import dataclasses
import logging
import random
import time
from typing import Callable, TypeVar

log = logging.getLogger("repro.runtime.retry")

T = TypeVar("T")

__all__ = [
    "RetryPolicy",
    "RetryBudgetExceeded",
    "retry_call",
    "DEFAULT_RETRY",
    "MIGRATION_RETRY",
    "CHECKPOINT_RETRY",
]


class RetryBudgetExceeded(RuntimeError):
    """Every attempt failed (or the time budget ran out); carries the
    last underlying error as ``__cause__`` and ``.last``."""

    def __init__(self, label: str, attempts: int, last: BaseException):
        self.label = label
        self.attempts = attempts
        self.last = last
        super().__init__(
            f"{label or 'operation'} failed after {attempts} attempt(s): "
            f"{last!r}"
        )


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with seeded jitter and a time budget.

    Delay for attempt ``n`` (0-indexed) is
    ``min(base_delay_s * 2**n, max_delay_s)`` scaled by a deterministic
    jitter in ``[1 - jitter, 1 + jitter]``.  ``budget_s`` bounds the
    *total* time spent sleeping between attempts — a per-operation
    budget, so a retried migration cannot stall the serve loop longer
    than the watchdog's evacuation deadline.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    jitter: float = 0.1
    budget_s: float | None = None

    def delay_s(self, attempt: int, seed: int = 0) -> float:
        d = min(self.base_delay_s * (2.0 ** attempt), self.max_delay_s)
        if self.jitter > 0.0:
            # one int key per (seed, attempt): tuple seeding is hash-based
            # (deprecated, and not stable across processes)
            u = random.Random(int(seed) * 1_000_003 + attempt).random()
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return max(d, 0.0)

    def scaled(self, **overrides) -> "RetryPolicy":
        return dataclasses.replace(self, **overrides)


#: the repo-wide default: 3 attempts, 50ms doubling to 2s, 10% jitter.
DEFAULT_RETRY = RetryPolicy()

#: serve-path migrations get a tighter budget: backoff must stay well
#: under the watchdog's step deadline or the retry *is* the stall.
MIGRATION_RETRY = RetryPolicy(
    max_attempts=3, base_delay_s=0.01, max_delay_s=0.25, budget_s=2.0
)

#: checkpoint writes are off the hot path and may wait out a slow disk.
CHECKPOINT_RETRY = RetryPolicy(
    max_attempts=4, base_delay_s=0.1, max_delay_s=5.0, budget_s=30.0
)


def retry_call(
    fn: Callable[[], T],
    *,
    retry_on: tuple[type[BaseException], ...],
    policy: RetryPolicy = DEFAULT_RETRY,
    label: str = "",
    seed: int = 0,
    on_retry: Callable[[int, BaseException, float], None] | None = None,
    sleep: Callable[[float], None] = time.sleep,
) -> T:
    """Call ``fn`` under ``policy``, retrying only ``retry_on`` errors.

    ``on_retry(attempt, error, delay_s)`` fires before each backoff
    sleep (counters, logging).  Exhaustion raises
    :class:`RetryBudgetExceeded` chaining the last error; any exception
    outside ``retry_on`` propagates immediately (deterministic failures
    must not burn the budget).
    """
    if policy.max_attempts < 1:
        raise ValueError(f"max_attempts must be >= 1, got {policy}")
    slept = 0.0
    attempts = 0
    last: BaseException | None = None
    for attempt in range(policy.max_attempts):
        try:
            return fn()
        except retry_on as e:
            last = e
            attempts = attempt + 1
            if attempts >= policy.max_attempts:
                break
            d = policy.delay_s(attempt, seed)
            if policy.budget_s is not None and slept + d > policy.budget_s:
                log.warning(
                    "%s: retry budget %.3gs exhausted after %d attempt(s)",
                    label or "retry", policy.budget_s, attempts,
                )
                break
            if on_retry is not None:
                on_retry(attempt, e, d)
            log.info(
                "%s: attempt %d/%d failed (%r); retrying in %.3gs",
                label or "retry", attempts, policy.max_attempts, e, d,
            )
            sleep(d)
            slept += d
    assert last is not None
    raise RetryBudgetExceeded(label, attempts, last) from last
