"""Training supervisor: checkpoint/restart fault tolerance + elastic rescale.

The restart contract for 1000+ nodes: any worker failure kills the
synchronous step; the job restarts from the latest *atomic* checkpoint with
possibly fewer (or more) healthy devices.  ``Supervisor.run`` wraps the step
loop with:

* periodic async checkpoints (model + optimizer + data-iterator state);
* exception-triggered restore-and-resume with bounded restarts;
* straggler monitoring wired to a checkpoint-now callback;
* ``rescale(new_mesh)``: device_put the full state onto a different mesh
  (elastic scaling — exercised in tests by shrinking a host-device mesh).
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.straggler import StepTimeMonitor, StragglerConfig

log = logging.getLogger(__name__)


@dataclasses.dataclass
class SupervisorConfig:
    checkpoint_every: int = 50
    max_restarts: int = 3
    straggler: StragglerConfig = dataclasses.field(default_factory=StragglerConfig)


class Supervisor:
    def __init__(
        self,
        ckpt: Checkpointer,
        cfg: SupervisorConfig = SupervisorConfig(),
    ):
        self.ckpt = ckpt
        self.cfg = cfg
        self.restarts = 0
        self._ckpt_requested = False
        self.monitor = StepTimeMonitor(
            cfg.straggler, on_straggler=self._on_straggler
        )

    def _on_straggler(self, info: dict) -> None:
        log.warning("straggler detected: %s — requesting checkpoint", info)
        self._ckpt_requested = True

    def run(
        self,
        state: Any,                         # pytree (params, opt, ef, ...)
        step_fn: Callable[[Any, dict], tuple[Any, dict]],
        data_iter,
        n_steps: int,
        start_step: int = 0,
        extra_state: Callable[[], dict] | None = None,
    ) -> tuple[Any, int]:
        """Run ``n_steps`` with checkpoint/restart. Returns (state, step)."""
        step = start_step
        while step < n_steps:
            try:
                batch = next(data_iter)
                with self.monitor:
                    state, metrics = step_fn(state, batch)
                step += 1
                if (
                    step % self.cfg.checkpoint_every == 0
                    or self._ckpt_requested
                ):
                    self._ckpt_requested = False
                    self.ckpt.save(
                        step,
                        state,
                        extra=(extra_state() if extra_state else {})
                        | {"step": step},
                    )
            except StopIteration:
                break
            except Exception as e:  # node failure / preemption surrogate
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.cfg.max_restarts}"
                    ) from e
                log.warning("step failed (%s); restoring from checkpoint", e)
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    raise
                state, manifest = self.ckpt.restore(state)
                step = manifest["extra"].get("step", latest)
                if hasattr(data_iter, "restore") and "data" in manifest["extra"]:
                    data_iter.restore(manifest["extra"]["data"])
        self.ckpt.wait()
        return state, step

    # -- elastic -----------------------------------------------------------
    @staticmethod
    def rescale(state, shardings) -> Any:
        """Reshard the full training state onto a new mesh's shardings."""
        host = jax.tree.map(lambda x: jax.device_get(x), state)
        return jax.tree.map(jax.device_put, host, shardings)
