"""Runtime supervision: checkpoint/restart, elastic rescale, step watchdog.

The restart contract for 1000+ nodes: any worker failure kills the
synchronous step; the job restarts from the latest *atomic* checkpoint with
possibly fewer (or more) healthy devices.  ``Supervisor.run`` wraps the step
loop with:

* periodic async checkpoints (model + optimizer + data-iterator state);
* exception-triggered restore-and-resume with bounded restarts;
* straggler monitoring wired to a checkpoint-now callback;
* ``rescale(new_mesh)``: device_put the full state onto a different mesh
  (elastic scaling — exercised in tests by shrinking a host-device mesh).

:class:`Watchdog` is the serve-side counterpart: access-path faults on
tightly coupled systems usually surface as order-of-magnitude *slowdowns*
rather than errors (the GH200 system-memory first look, arxiv 2407.07850),
so the serve loop deadlines every decode step against a budget derived
from :meth:`repro.api.Runtime.decode_step_seconds` and escalates
consecutive breaches up a ladder — ``stall`` (log) → ``retry`` (rebuild
the dispatch path) → ``evacuate`` (migrate off the presumed-degraded far
tier) → ``hang`` (raise, with full queue/slot diagnostics).  The ladder is
pure policy: it returns actions; the :class:`repro.serve.scheduler.Server`
owns the side effects, so the escalation is unit-testable without a mesh.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import TYPE_CHECKING, Any, Callable

import jax

from repro.runtime.straggler import StepTimeMonitor, StragglerConfig

if TYPE_CHECKING:  # checkpointer imports runtime.retry: keep the cycle lazy
    from repro.checkpoint.checkpointer import Checkpointer

log = logging.getLogger(__name__)


# ---------------------------------------------------------------------------
# Serve-step watchdog: deadline + escalation ladder
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """Deadline and escalation thresholds for the serve-step watchdog.

    The deadline is ``max(min_deadline_s, budget_factor * expected step
    seconds)`` — the expected time is the runtime's measured-else-analytic
    decode-step price, so the budget tightens as real measurements land.
    ``*_after`` are *consecutive* deadline breaches before each rung; a
    healthy step resets the count.
    """

    budget_factor: float = 8.0
    min_deadline_s: float = 0.25
    stall_after: int = 1
    retry_after: int = 2
    evacuate_after: int = 3
    hang_after: int = 4

    def validate(self) -> None:
        rungs = (self.stall_after, self.retry_after, self.evacuate_after,
                 self.hang_after)
        if any(r < 1 for r in rungs) or list(rungs) != sorted(rungs):
            raise ValueError(
                "watchdog escalation thresholds must be >= 1 and "
                f"non-decreasing (stall <= retry <= evacuate <= hang), "
                f"got {rungs}"
            )


class Watchdog:
    """Deadline serve steps; escalate stall → retry → evacuate → hang.

    ``expected_s`` is a zero-arg callable returning the current expected
    step seconds (the Server passes a closure over
    ``Runtime.decode_step_seconds`` so the budget follows calibration and
    replan migrations).  :meth:`observe` feeds one measured step and
    returns the action this breach count has escalated to; ``"ok"``
    resets the ladder.
    """

    ACTIONS = ("ok", "stall", "retry", "evacuate", "hang")

    def __init__(
        self,
        expected_s: Callable[[], float],
        cfg: WatchdogConfig = WatchdogConfig(),
    ):
        cfg.validate()
        self.expected_s = expected_s
        self.cfg = cfg
        self.breaches = 0
        self.last_step_s = 0.0
        self.actions = {a: 0 for a in self.ACTIONS}

    def deadline_s(self) -> float:
        """The current per-step budget."""
        return max(
            self.cfg.min_deadline_s,
            self.cfg.budget_factor * float(self.expected_s()),
        )

    def observe(self, seconds: float) -> str:
        """Feed one measured step; return the escalation action."""
        self.last_step_s = float(seconds)
        if self.last_step_s <= self.deadline_s():
            self.breaches = 0
            self.actions["ok"] += 1
            return "ok"
        self.breaches += 1
        cfg = self.cfg
        if self.breaches >= cfg.hang_after:
            action = "hang"
        elif self.breaches >= cfg.evacuate_after:
            action = "evacuate"
        elif self.breaches >= cfg.retry_after:
            action = "retry"
        else:
            action = "stall"
        self.actions[action] += 1
        log.warning(
            "watchdog: step took %.3gs > deadline %.3gs (breach %d) -> %s",
            self.last_step_s, self.deadline_s(), self.breaches, action,
        )
        return action


@dataclasses.dataclass
class SupervisorConfig:
    checkpoint_every: int = 50
    max_restarts: int = 3
    straggler: StragglerConfig = dataclasses.field(default_factory=StragglerConfig)


class Supervisor:
    def __init__(
        self,
        ckpt: Checkpointer,
        cfg: SupervisorConfig = SupervisorConfig(),
    ):
        self.ckpt = ckpt
        self.cfg = cfg
        self.restarts = 0
        self._ckpt_requested = False
        self.monitor = StepTimeMonitor(
            cfg.straggler, on_straggler=self._on_straggler
        )

    def _on_straggler(self, info: dict) -> None:
        log.warning("straggler detected: %s — requesting checkpoint", info)
        self._ckpt_requested = True

    def run(
        self,
        state: Any,                         # pytree (params, opt, ef, ...)
        step_fn: Callable[[Any, dict], tuple[Any, dict]],
        data_iter,
        n_steps: int,
        start_step: int = 0,
        extra_state: Callable[[], dict] | None = None,
    ) -> tuple[Any, int]:
        """Run ``n_steps`` with checkpoint/restart. Returns (state, step)."""
        step = start_step
        while step < n_steps:
            try:
                batch = next(data_iter)
                with self.monitor:
                    state, metrics = step_fn(state, batch)
                step += 1
                if (
                    step % self.cfg.checkpoint_every == 0
                    or self._ckpt_requested
                ):
                    self._ckpt_requested = False
                    self.ckpt.save(
                        step,
                        state,
                        extra=(extra_state() if extra_state else {})
                        | {"step": step},
                    )
            except StopIteration:
                break
            except Exception as e:  # node failure / preemption surrogate
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.cfg.max_restarts}"
                    ) from e
                log.warning("step failed (%s); restoring from checkpoint", e)
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    raise
                state, manifest = self.ckpt.restore(state)
                step = manifest["extra"].get("step", latest)
                if hasattr(data_iter, "restore") and "data" in manifest["extra"]:
                    data_iter.restore(manifest["extra"]["data"])
        self.ckpt.wait()
        return state, step

    # -- elastic -----------------------------------------------------------
    @staticmethod
    def rescale(state, shardings) -> Any:
        """Reshard the full training state onto a new mesh's shardings."""
        host = jax.tree.map(lambda x: jax.device_get(x), state)
        return jax.tree.map(jax.device_put, host, shardings)
