from repro.runtime.retry import (  # noqa: F401
    CHECKPOINT_RETRY,
    DEFAULT_RETRY,
    MIGRATION_RETRY,
    RetryBudgetExceeded,
    RetryPolicy,
    retry_call,
)
from repro.runtime.straggler import StepTimeMonitor, StragglerConfig  # noqa: F401
from repro.runtime.supervisor import (  # noqa: F401
    Supervisor,
    SupervisorConfig,
    Watchdog,
    WatchdogConfig,
)
