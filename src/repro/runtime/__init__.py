from repro.runtime.straggler import StepTimeMonitor, StragglerConfig  # noqa: F401
from repro.runtime.supervisor import Supervisor, SupervisorConfig  # noqa: F401
