"""Gemma-3 27B [hf:google/gemma-3-27b-pt family; unverified].

62 layers, d_model 5376, 32 query heads / 16 KV heads (GQA), d_ff 21504,
vocab 262144.  5:1 local:global attention pattern — five sliding-window
(W=1024) layers per global layer, with distinct RoPE bases (10k local,
1M global) and QK-norm.
"""
from repro.configs import ArchConfig, AttentionSpec

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    d_ff=21504,
    vocab=262_144,
    layer_pattern="LLLLLG",
    norm="rmsnorm",
    attention=AttentionSpec(
        n_heads=32, n_kv_heads=16, d_head=128,
        qk_norm=True, rope_theta=10_000.0, rope_theta_global=1_000_000.0,
        window=1024,
    ),
    act="gelu",
    source="hf:google/gemma-3-27b-pt (family card); 5:1 local:global, 128k ctx",
)

SMOKE_CONFIG = ArchConfig(
    name="gemma3-27b-smoke",
    family="dense",
    n_layers=8,                      # one full period + tail, order preserved
    d_model=64,
    d_ff=256,
    vocab=512,
    layer_pattern="LLLLLG",
    norm="rmsnorm",
    attention=AttentionSpec(
        n_heads=4, n_kv_heads=2, d_head=16,
        qk_norm=True, rope_theta=10_000.0, rope_theta_global=1_000_000.0,
        window=32,
    ),
    act="gelu",
)
