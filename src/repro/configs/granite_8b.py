"""Granite 8B Code [arXiv:2405.04324; hf:ibm-granite/granite-8b-code-base].

36 layers, d_model 4096, 32 heads / 8 KV heads (GQA), d_ff 14336,
vocab 49152.  Llama-architecture, code-oriented; large RoPE base.
"""
from repro.configs import ArchConfig, AttentionSpec

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    d_ff=14336,
    vocab=49_152,
    layer_pattern="F",
    norm="rmsnorm",
    attention=AttentionSpec(n_heads=32, n_kv_heads=8, d_head=128,
                            rope_theta=10_000_000.0),
    act="silu",
    source="arXiv:2405.04324; hf:ibm-granite/granite-8b-code-base",
)

SMOKE_CONFIG = ArchConfig(
    name="granite-8b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=256,
    vocab=512,
    layer_pattern="F",
    norm="rmsnorm",
    attention=AttentionSpec(n_heads=4, n_kv_heads=2, d_head=16),
    act="silu",
)
