"""Architecture configs + input-shape registry.

One module per assigned architecture (exact public-literature config);
this package holds the shared dataclasses, the shape registry, and the
``get_config`` / ``list_archs`` entry points used by ``--arch <id>``.
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    n_heads: int
    n_kv_heads: int
    d_head: int
    kind: str = "gqa"              # "gqa" | "mla"
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # != 0 -> distinct theta for 'G' layers
    window: int = 0                # sliding-window size for 'L' layers
    chunk: int = 0                 # chunk size for 'C' layers
    # MLA (DeepSeek-V2):
    q_lora: int = 0
    kv_lora: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    first_k_dense: int = 0         # leading layers use the dense FFN
    dense_d_ff: int = 0            # FFN width of dense (non-MoE) layers
    moe_period: int = 1            # MoE every k-th layer (llama4: 2)
    capacity_factor: float = 1.25
    router_type: str = "softmax"   # softmax top-k (GShard-style)

    def is_moe_layer(self, idx: int) -> bool:
        if idx < self.first_k_dense:
            return False
        return (idx + 1) % self.moe_period == 0


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    layer_pattern: str = "F"       # cycled codes: F full, L sliding-local,
                                   # G global, C chunked-local, M mamba2,
                                   # S shared-attention (zamba)
    norm: str = "rmsnorm"          # rmsnorm | layernorm | nonparametric
    attention: AttentionSpec | None = None
    moe: MoESpec | None = None
    ssm: SSMSpec | None = None
    tie_embeddings: bool = True
    act: str = "silu"              # mlp activation (dense FFN is gated GLU)
    # enc-dec only:
    n_encoder_layers: int = 0
    # modality frontends are STUBS: input_specs provide embeddings directly.
    frontend: str = "none"         # none | vision_stub | audio_stub
    frontend_tokens: int = 0       # prepended embedding positions (stub)
    dtype: str = "bfloat16"
    # reference provenance
    source: str = ""

    # --- derived -----------------------------------------------------------
    def layer_codes(self) -> str:
        """Expand the cyclic pattern to exactly n_layers codes."""
        p = self.layer_pattern
        reps = math.ceil(self.n_layers / len(p))
        codes = (p * reps)[: self.n_layers]
        return codes

    def stages(self) -> list[tuple[str, int, int]]:
        """(codes, repeat, start_layer) stages; concatenation = layer_codes().

        A stage is scanned with stacked params: one `while` per stage in
        the lowered HLO, body = one pattern period.  Layers whose FFN kind
        differs from the rest of the period (``first_k_dense``) get their
        own leading stage so every scan body is homogeneous; within a
        stage, per-position MoE-ness is start-aligned (moe_period must
        divide the pattern length, asserted in the model builder).
        """
        codes = self.layer_codes()
        p = self.layer_pattern
        lead = self.moe.first_k_dense if self.moe else 0
        out: list[tuple[str, int, int]] = []
        if lead:
            out.append((codes[:lead], 1, 0))
            codes = codes[lead:]
        full, rem = divmod(len(codes), len(p))
        if full:
            out.append((p, full, lead))
        if rem:
            out.append((codes[-rem:], 1, lead + full * len(p)))
        return out

    def num_params(self) -> float:
        """Analytic parameter count (embedding + layers)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        total = float(v * d)                       # embedding
        if not self.tie_embeddings:
            total += v * d
        for i, code in enumerate(self.layer_codes()):
            total += self._layer_params(code, idx=i)
        total += self.shared_block_params()
        if self.n_encoder_layers:
            for _ in range(self.n_encoder_layers):
                total += self._layer_params("F")
            # decoder layers add cross-attention
            total += self.n_layers * self._attn_params()  # cross-attn
        return total

    def _attn_params(self) -> float:
        a = self.attention
        if a is None:
            return 0.0
        d = self.d_model
        if a.kind == "mla":
            qk_head = a.nope_head_dim + a.rope_head_dim
            q = (d * a.q_lora + a.q_lora * a.n_heads * qk_head) if a.q_lora \
                else d * a.n_heads * qk_head
            kv = d * (a.kv_lora + a.rope_head_dim)
            kv += a.kv_lora * a.n_heads * (a.nope_head_dim + a.v_head_dim)
            o = a.n_heads * a.v_head_dim * d
            return float(q + kv + o)
        q = d * a.n_heads * a.d_head
        kv = 2 * d * a.n_kv_heads * a.d_head
        o = a.n_heads * a.d_head * d
        return float(q + kv + o)

    def _ffn_params(self, idx: int) -> float:
        d = self.d_model
        if self.moe is not None and self.moe.is_moe_layer(idx):
            e = self.moe
            expert = 3 * d * e.d_ff_expert
            return float(
                (e.n_experts + e.n_shared) * expert + d * e.n_experts
            )
        ff = self.d_ff
        if self.moe is not None and self.moe.dense_d_ff:
            ff = self.moe.dense_d_ff
        return float(3 * d * ff)

    def _ssm_params(self) -> float:
        s = self.ssm
        d = self.d_model
        di = s.d_inner(d)
        h = s.n_heads(d)
        in_proj = d * (2 * di + 2 * s.d_state + h)
        conv = s.d_conv * (di + 2 * s.d_state)
        out = di * d
        return float(in_proj + conv + out + h + di)

    def _layer_params(self, code: str, idx: int = 0) -> float:
        if code == "M":
            return self._ssm_params()
        if code == "S":
            # zamba-style shared block: params counted ONCE globally; here
            # return only the per-application LoRA-free glue (proj in/out
            # are shared too) -> 0 marginal. Shared cost added below.
            return 0.0
        return self._attn_params() + self._ffn_params(idx)

    def shared_block_params(self) -> float:
        """Zamba-style shared attention block (counted once)."""
        if "S" not in self.layer_pattern or self.attention is None:
            return 0.0
        a = self.attention
        dc = 2 * self.d_model             # concat(hidden, emb0)
        attn = dc * a.n_heads * a.d_head * 2 \
            + 2 * dc * a.n_kv_heads * a.d_head
        out = a.n_heads * a.d_head * self.d_model
        return float(attn + out)

    def active_params(self) -> float:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.num_params()
        d = self.d_model
        e = self.moe
        total = float(self.vocab * d)
        for i, code in enumerate(self.layer_codes()):
            if code in ("M", "S"):
                total += self._layer_params(code, idx=i)
                continue
            total += self._attn_params()
            if not e.is_moe_layer(i):
                total += 3 * d * (e.dense_d_ff or self.d_ff)
            else:
                total += (e.top_k + e.n_shared) * 3 * d * e.d_ff_expert
                total += d * e.n_experts  # router
        return total


# ---------------------------------------------------------------------------
# Shapes (assigned): every arch runs the same 4 shapes, with documented skips
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

#: archs whose every layer is unwindowed full attention: long_500k skipped
#: (sub-quadratic requirement; see DESIGN.md §Arch-applicability).
PURE_FULL_ATTENTION = frozenset(
    {"olmo-1b", "granite-8b", "yi-6b", "deepseek-v2-236b",
     "seamless-m4t-medium", "internvl2-1b"}
)


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch in PURE_FULL_ATTENTION:
        return False, "pure full attention at 500k (see DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_MODULES = {
    "gemma3-27b": "gemma3_27b",
    "olmo-1b": "olmo_1b",
    "granite-8b": "granite_8b",
    "yi-6b": "yi_6b",
    "mamba2-780m": "mamba2_780m",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "zamba2-1.2b": "zamba2_1_2b",
    "internvl2-1b": "internvl2_1b",
}


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ArchConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def smoke_config(arch: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.SMOKE_CONFIG
