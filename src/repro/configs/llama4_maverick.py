"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family;
unverified].

48 layers, d_model 5120, 40 heads / 8 KV heads, MoE (every other
layer) with 128 routed experts top-1 + 1 shared expert, expert d_ff 8192;
dense layers d_ff 16384; vocab 202048.  iRoPE-style
3:1 chunked-local:global attention (chunk 8192; global layers NoPE-like
with large theta).  Early-fusion multimodal in the original; the modality
frontend here is the standard stub (text cells exercise the backbone).
"""
from repro.configs import ArchConfig, AttentionSpec, MoESpec

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab=202_048,
    layer_pattern="CCCG",
    norm="rmsnorm",
    attention=AttentionSpec(
        n_heads=40, n_kv_heads=8, d_head=128,
        rope_theta=500_000.0, chunk=8192,
    ),
    moe=MoESpec(n_experts=128, top_k=1, d_ff_expert=8192, n_shared=1,
                moe_period=2, dense_d_ff=16384),
    act="silu",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family); unverified",
)

SMOKE_CONFIG = ArchConfig(
    name="llama4-maverick-smoke",
    family="moe",
    n_layers=4,
    d_model=64,
    d_ff=128,
    vocab=512,
    layer_pattern="CCCG",
    norm="rmsnorm",
    attention=AttentionSpec(n_heads=4, n_kv_heads=2, d_head=16, chunk=64),
    moe=MoESpec(n_experts=4, top_k=1, d_ff_expert=128, n_shared=1,
                moe_period=2, dense_d_ff=256),
    act="silu",
)
