"""InternVL2 1B [arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B].

VLM: InternViT-300M vision encoder + Qwen2-0.5B language backbone.
The LM backbone (the assigned cells): 24 layers, d_model 896,
14 heads / 2 KV heads, d_ff 4864, vocab 151655.  The ViT frontend is a
STUB per the task: ``input_specs()`` provides precomputed patch
embeddings prepended to the token embeddings.
"""
from repro.configs import ArchConfig, AttentionSpec

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    d_ff=4864,
    vocab=151_655,
    layer_pattern="F",
    norm="rmsnorm",
    attention=AttentionSpec(n_heads=14, n_kv_heads=2, d_head=64,
                            rope_theta=1_000_000.0),
    act="silu",
    frontend="vision_stub",
    frontend_tokens=256,         # ViT patch embeddings per image (stub)
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B",
)

SMOKE_CONFIG = ArchConfig(
    name="internvl2-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    d_ff=128,
    vocab=512,
    layer_pattern="F",
    norm="rmsnorm",
    attention=AttentionSpec(n_heads=4, n_kv_heads=2, d_head=16),
    act="silu",
    frontend="vision_stub",
    frontend_tokens=16,
)
