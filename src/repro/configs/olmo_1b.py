"""OLMo 1B [arXiv:2402.00838; hf:allenai/OLMo-1B].

16 layers, d_model 2048, 16 heads (MHA: kv=16), d_ff 8192, vocab 50304.
Distinctive: non-parametric LayerNorm (no learned scale/bias).
"""
from repro.configs import ArchConfig, AttentionSpec

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    d_ff=8192,
    vocab=50_304,
    layer_pattern="F",
    norm="nonparametric",
    attention=AttentionSpec(n_heads=16, n_kv_heads=16, d_head=128,
                            rope_theta=10_000.0),
    act="silu",
    source="arXiv:2402.00838; hf:allenai/OLMo-1B",
)

SMOKE_CONFIG = ArchConfig(
    name="olmo-1b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=256,
    vocab=512,
    layer_pattern="F",
    norm="nonparametric",
    attention=AttentionSpec(n_heads=4, n_kv_heads=4, d_head=16),
    act="silu",
)
