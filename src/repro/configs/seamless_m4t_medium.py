"""SeamlessM4T Medium [arXiv:2308.11596; hf:facebook/seamless-m4t-medium].

Encoder-decoder transformer backbone: 12 encoder + 12 decoder layers,
d_model 1024, 16 heads (MHA), d_ff 4096, vocab 256206, LayerNorm.
The audio frontend (w2v-BERT conformer stack) is a STUB per the task:
``input_specs()`` provides precomputed frame embeddings to the encoder.
"""
from repro.configs import ArchConfig, AttentionSpec

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                 # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab=256_206,
    layer_pattern="F",
    norm="layernorm",
    attention=AttentionSpec(n_heads=16, n_kv_heads=16, d_head=64,
                            rope_theta=10_000.0),
    act="relu",
    frontend="audio_stub",
    frontend_tokens=1024,        # encoder frame positions (stubbed)
    tie_embeddings=True,
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-medium",
)

SMOKE_CONFIG = ArchConfig(
    name="seamless-m4t-smoke",
    family="audio",
    n_layers=2,
    n_encoder_layers=2,
    d_model=64,
    d_ff=128,
    vocab=512,
    layer_pattern="F",
    norm="layernorm",
    attention=AttentionSpec(n_heads=4, n_kv_heads=4, d_head=16),
    act="relu",
    frontend="audio_stub",
    frontend_tokens=32,
)
