"""Yi 6B [arXiv:2403.04652; hf:01-ai/Yi-6B].

32 layers, d_model 4096, 32 heads / 4 KV heads (GQA), d_ff 11008,
vocab 64000.  Llama-architecture with aggressive GQA (8:1).
"""
from repro.configs import ArchConfig, AttentionSpec

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    d_ff=11008,
    vocab=64_000,
    layer_pattern="F",
    norm="rmsnorm",
    attention=AttentionSpec(n_heads=32, n_kv_heads=4, d_head=128,
                            rope_theta=5_000_000.0),
    act="silu",
    source="arXiv:2403.04652; hf:01-ai/Yi-6B",
)

SMOKE_CONFIG = ArchConfig(
    name="yi-6b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=256,
    vocab=512,
    layer_pattern="F",
    norm="rmsnorm",
    attention=AttentionSpec(n_heads=8, n_kv_heads=1, d_head=16),
    act="silu",
)
