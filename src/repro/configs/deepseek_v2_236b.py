"""DeepSeek-V2 236B [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2].

60 layers, d_model 5120, 128 heads with Multi-head Latent Attention
(kv_lora 512, q_lora 1536, 128 nope + 64 rope qk dims, v 128),
MoE: 2 shared + 160 routed experts, top-6, expert d_ff 1536; first layer
dense (d_ff 12288).  vocab 102400.

MLA is itself a data-movement optimization (the paper's theme): the decode
KV cache is the 512-dim latent + 64-dim rope key instead of
128 heads x 256 dims — 110x smaller reads per token.
"""
from repro.configs import ArchConfig, AttentionSpec, MoESpec

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    d_ff=1536,                # routed-expert FFN width (assignment value)
    vocab=102_400,
    layer_pattern="F",
    norm="rmsnorm",
    attention=AttentionSpec(
        n_heads=128, n_kv_heads=128, d_head=192, kind="mla",
        q_lora=1536, kv_lora=512,
        rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
        rope_theta=10_000.0,
    ),
    moe=MoESpec(
        n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
        first_k_dense=1, dense_d_ff=12288,
    ),
    act="silu",
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2",
)

SMOKE_CONFIG = ArchConfig(
    name="deepseek-v2-236b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    d_ff=32,
    vocab=512,
    layer_pattern="F",
    norm="rmsnorm",
    attention=AttentionSpec(
        n_heads=4, n_kv_heads=4, d_head=24, kind="mla",
        q_lora=32, kv_lora=32, rope_head_dim=8, nope_head_dim=16,
        v_head_dim=16,
    ),
    moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                first_k_dense=1, dense_d_ff=128),
    act="silu",
)
