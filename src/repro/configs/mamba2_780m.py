"""Mamba-2 780M [arXiv:2405.21060; hf:state-spaces/mamba2-780m].

48 layers, d_model 1536, attention-free SSD (state-space duality),
ssm_state 128, vocab 50280.  expand=2 -> d_inner 3072, head_dim 64
-> 48 SSD heads.
"""
from repro.configs import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    d_ff=0,
    vocab=50_280,
    layer_pattern="M",
    norm="rmsnorm",
    ssm=SSMSpec(d_state=128, d_conv=4, expand=2, head_dim=64),
    source="arXiv:2405.21060; hf:state-spaces/mamba2-780m",
)

SMOKE_CONFIG = ArchConfig(
    name="mamba2-780m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    d_ff=0,
    vocab=512,
    layer_pattern="M",
    norm="rmsnorm",
    ssm=SSMSpec(d_state=16, d_conv=4, expand=2, head_dim=32),
)
