"""Zamba2 1.2B [arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B].

38 layers, d_model 2048, Mamba-2 backbone (ssm_state 64) with a SHARED
attention block applied periodically (every 6th position here): the shared
block's parameters are reused at every application (the Zamba trick), and
its input is concat(hidden, original embedding) -> 2*d_model attention.
32 heads of d_head 128 over the 4096 concat width.
"""
from repro.configs import ArchConfig, AttentionSpec, SSMSpec

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    d_ff=8192,
    vocab=32_000,
    layer_pattern="MMMMMS",
    norm="rmsnorm",
    attention=AttentionSpec(n_heads=32, n_kv_heads=32, d_head=128,
                            rope_theta=10_000.0),
    ssm=SSMSpec(d_state=64, d_conv=4, expand=2, head_dim=64),
    act="gelu",
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B",
)

SMOKE_CONFIG = ArchConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    d_ff=128,
    vocab=512,
    layer_pattern="MMMMMS",
    norm="rmsnorm",
    attention=AttentionSpec(n_heads=4, n_kv_heads=4, d_head=32),
    ssm=SSMSpec(d_state=16, d_conv=4, expand=2, head_dim=32),
    act="gelu",
)
