"""HLO data-movement audit tests: synthetic fixtures + compiled modules.

The synthetic HLO strings exist because CPU CI cannot *generate* ``S(5)``
host-memory-space layouts (the CPU backend only has ``unpinned_host``);
the parser and the audit are exercised on hand-written post-SPMD text,
while donation/aliasing — which CPU does materialize — is audited on real
compiled modules, for every registered placement policy (the donor-mesh
policies run on the forced-4-device CI leg).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_audit import (
    AuditViolation,
    DonationAliasError,
    ERROR_KINDS,
    ExpectedMovement,
    RoleExpectation,
    audit_compiled,
    audit_hlo_text,
)
from repro.core.placement import Role, registered_policies

# -- synthetic post-SPMD modules -------------------------------------------

CLEAN_DONATED = """\
HloModule clean, input_output_alias={ {0}: (1, {}, may-alias) }

ENTRY %main (p0: f32[16], p1: f32[64]) -> (f32[64]) {
  %p0 = f32[16]{0} parameter(0), metadata={op_name="p[\\'w\\']"}
  %p1 = f32[64]{0} parameter(1), metadata={op_name="caches[0]"}
  ROOT %t = (f32[64]{0}) tuple(%p1)
}
"""

NO_ALIAS = """\
HloModule no_alias

ENTRY %main (p0: f32[16], p1: f32[64]) -> (f32[64]) {
  %p0 = f32[16]{0} parameter(0), metadata={op_name="p[\\'w\\']"}
  %p1 = f32[64]{0} parameter(1), metadata={op_name="caches[0]"}
  ROOT %t = (f32[64]{0}) tuple(%p1)
}
"""

HOST_TRAFFIC = """\
HloModule host_traffic

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0), metadata={op_name="caches[0]"}
  %cs = (f32[1024]{0:S(5)}, f32[1024]{0}, u32[]) copy-start(%p0)
  ROOT %cd = f32[1024]{0:S(5)} copy-done(%cs)
}
"""


def _kv(donate, **kw):
    return ExpectedMovement(
        roles=(RoleExpectation("kv_cache", "caches", donate=donate),),
        label="test",
        **kw,
    )


class TestAuditHloText:
    def test_clean_module_passes(self):
        rep = audit_hlo_text(CLEAN_DONATED, _kv(donate=True))
        assert rep.ok and rep.violations == []
        assert rep.donation_expected == rep.donation_materialized == 1
        assert rep.donation_coverage == 1.0
        assert rep.role_bytes == {"kv_cache": 64 * 4}

    def test_missed_donation(self):
        rep = audit_hlo_text(NO_ALIAS, _kv(donate=True))
        assert not rep.ok
        (v,) = rep.violations
        assert v.kind == "missed-donation" and v.severity == "error"
        assert v.nbytes == 64 * 4 and "caches[0]" in v.op
        assert rep.donation_coverage == 0.0
        with pytest.raises(DonationAliasError, match="missed-donation"):
            rep.raise_on_donation_errors()

    def test_forbidden_donation(self):
        rep = audit_hlo_text(CLEAN_DONATED, _kv(donate=False))
        assert not rep.ok
        (v,) = rep.violations
        assert v.kind == "forbidden-donation"
        with pytest.raises(DonationAliasError, match="forbidden-donation"):
            rep.raise_on_donation_errors()

    def test_stray_host_transfer(self):
        rep = audit_hlo_text(HOST_TRAFFIC, _kv(donate=False))
        assert not rep.ok
        (v,) = rep.violations
        assert v.kind == "stray-host-transfer"
        assert v.tier_edge == "host<->hbm" and v.planner_term == "pcie"
        assert rep.host_transfer_bytes == 1024 * 4
        # stray transfers are not donation violations: this raise is about
        # aliasing only
        rep.raise_on_donation_errors()

    def test_host_allowance_admits_budgeted_traffic(self):
        rep = audit_hlo_text(
            HOST_TRAFFIC, _kv(donate=False, host_bytes_allowed=1024 * 4)
        )
        assert rep.ok and rep.host_transfer_bytes == 1024 * 4

    def test_byte_plan_mismatch_is_warning(self):
        exp = ExpectedMovement(
            roles=(RoleExpectation(
                "kv_cache", "caches", donate=True,
                plan_bytes=64 * 4 * 10, tolerance=0.5,
            ),),
            label="test",
        )
        rep = audit_hlo_text(CLEAN_DONATED, exp)
        (v,) = rep.violations
        assert v.kind == "byte-plan-mismatch" and v.severity == "warning"
        assert rep.ok  # warnings never fail the gate

    def test_byte_plan_within_tolerance_is_silent(self):
        exp = ExpectedMovement(
            roles=(RoleExpectation(
                "kv_cache", "caches", donate=True,
                plan_bytes=64 * 4 * 1.2, tolerance=0.5,
            ),),
            label="test",
        )
        assert audit_hlo_text(CLEAN_DONATED, exp).violations == []

    def test_unmentioned_roles_ignored(self):
        # p (params) has no expectation: its missing alias is not an error
        exp = ExpectedMovement(roles=(), label="test")
        rep = audit_hlo_text(NO_ALIAS, exp)
        assert rep.ok and rep.donation_expected == 0
        assert rep.donation_coverage == 1.0

    def test_to_json_round_trips(self):
        import json

        rep = audit_hlo_text(NO_ALIAS, _kv(donate=True))
        blob = json.loads(json.dumps(rep.to_json()))
        assert blob["ok"] is False and blob["donation_coverage"] == 0.0
        assert blob["violations"][0]["kind"] == "missed-donation"
        assert set(ERROR_KINDS) == {
            "missed-donation", "forbidden-donation", "stray-host-transfer"
        }
        assert isinstance(
            AuditViolation(**blob["violations"][0]).to_json(), dict
        )


class TestAuditCompiled:
    def test_real_donated_jit(self):
        step = jax.jit(
            lambda caches, x: (caches + x, x),
            donate_argnums=(0,),  # repro: lint-disable=donate-without-out-shardings
        )
        compiled = step.lower(
            jax.ShapeDtypeStruct((128,), jnp.float32),
            jax.ShapeDtypeStruct((128,), jnp.float32),
        ).compile()
        exp = ExpectedMovement(
            roles=(RoleExpectation("kv_cache", "caches", donate=True),),
            label="real",
        )
        rep = audit_compiled(compiled, exp)
        assert rep.ok and rep.donation_coverage == 1.0
        assert rep.role_bytes["kv_cache"] == 128 * 4

    def test_real_undonated_jit_trips(self):
        step = jax.jit(lambda caches, x: (caches + x, x))
        compiled = step.lower(
            jax.ShapeDtypeStruct((128,), jnp.float32),
            jax.ShapeDtypeStruct((128,), jnp.float32),
        ).compile()
        exp = ExpectedMovement(
            roles=(RoleExpectation("kv_cache", "caches", donate=True),),
            label="real",
        )
        rep = audit_compiled(compiled, exp)
        assert not rep.ok
        assert rep.violations[0].kind == "missed-donation"


# ---------------------------------------------------------------------------
# Runtime.audit + the full Executor, for EVERY registered policy
# ---------------------------------------------------------------------------

def _policy_tiers(policy):
    return {p.tier.value for p in policy.placements.values()}


def _needs_donor(policy) -> bool:
    return bool(_policy_tiers(policy) & {"hbm_p", "host_p", "hbm_r"})


def _mesh_for(policy):
    """A mesh this policy validates on, or pytest.skip."""
    from repro.launch.mesh import make_donor_mesh

    if not _needs_donor(policy):
        return None  # single-device semantics; no realization needed
    if len(jax.devices()) < 4:
        pytest.skip("donor-tier policy needs the forced-4-device leg")
    remote = "hbm_r" in _policy_tiers(policy)
    return make_donor_mesh((2,), ("data",), donor_size=2, remote=remote)


@pytest.fixture(scope="module")
def smoke():
    from repro.models import get_smoke_bundle

    bundle = get_smoke_bundle("olmo-1b")
    params = bundle.init_params(jax.random.PRNGKey(0), "float32")
    return bundle, params


@pytest.mark.parametrize("policy_name", sorted(registered_policies()))
class TestEveryRegisteredPolicy:
    def test_decode_step_movement_matches_plan(self, smoke, policy_name):
        """The acceptance sweep: build the serve Executor under each
        policy and diff the compiled decode step against the planner.

        * donation contract honored (coverage 1.0; STREAM never aliased);
        * zero host<->device bytes beyond the (B,) token-vector allowance;
        * observed KV bytes match ``decode_workload``'s byte plan within
          tolerance (exactly, on the 1-device mesh);
        * f32 test params are 2x the planner's bf16 pricing — flagged as
          a byte-plan-mismatch *warning*, never a gate failure.
        """
        from repro.models.model_zoo import ShapeSpec
        from repro.serve import Executor, ServeConfig

        bundle, params = smoke
        policy = registered_policies()[policy_name]
        mesh = _mesh_for(policy)
        cfg = ServeConfig(
            batch_slots=2, max_len=32, prefill_chunk=4, policy=policy_name
        )
        ex = Executor(bundle, cfg, params, mesh)

        # build-time audit ran (satellite: donation asserted at build,
        # not first dispatch) and found no movement-contract violations
        assert set(ex.audit_reports) >= {"decode", "prefill"}
        for name, rep in ex.audit_reports.items():
            assert rep.ok, (policy_name, name, rep.violations)
            assert rep.donation_coverage == 1.0

        donate = {"caches"} if ex.donates_cache else set()
        num_chips = 1 if mesh is None else mesh.devices.size
        wl = bundle.decode_workload(
            ShapeSpec(bundle.cfg.name, cfg.max_len, cfg.batch_slots,
                      "decode"),
            num_chips=num_chips,
        )
        rep = ex.rt.audit(
            ex._decode,
            {"p": Role.PARAMS, "caches": Role.KV_CACHE},
            donated=donate,
            host_bytes_allowed=3 * cfg.batch_slots * 4,
            workload=None if mesh is not None else wl,
        )
        assert rep.ok, (policy_name, rep.violations)
        assert rep.donation_coverage == 1.0
        assert rep.role_bytes["kv_cache"] > 0

        if mesh is None:
            # byte plan: KV exact; params 2x (f32 vs the planner's bf16
            # pricing) -> exactly one warning, for the params role
            plan = {r.value: v for r, v in wl.bytes_per_role.items()}
            assert rep.role_bytes["kv_cache"] == pytest.approx(
                plan["kv_cache"], rel=0.5
            )
            assert rep.role_bytes["params"] == pytest.approx(
                2 * plan["params"], rel=0.01
            )
            warns = [v for v in rep.violations
                     if v.kind == "byte-plan-mismatch"]
            assert [v.op for v in warns] == ["role:params"]

    def test_stream_policies_never_alias(self, smoke, policy_name):
        """STREAM placements must not donate — the compiled module's
        alias header must not cover the streamed role's parameters."""
        from repro.serve import Executor, ServeConfig

        bundle, params = smoke
        policy = registered_policies()[policy_name]
        if policy.placement(Role.KV_CACHE).strategy.value != "stream":
            pytest.skip("policy keeps KV resident")
        mesh = _mesh_for(policy)
        cfg = ServeConfig(
            batch_slots=2, max_len=32, prefill_chunk=4, policy=policy_name
        )
        ex = Executor(bundle, cfg, params, mesh)
        assert not ex.donates_cache
        rep = ex.audit_reports["decode"]
        # no alias entry may touch a caches[...] parameter
        from repro.core.hlo_analysis import entry_parameters

        text = ex._decode.as_text()
        aliased = {a.param_number for a in rep.aliases}
        cache_nums = {
            p.number for p in entry_parameters(text)
            if p.arg_root == "caches"
        }
        assert not (aliased & cache_nums)
