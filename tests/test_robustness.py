"""Fault injection and graceful degradation: the self-healing serve
runtime.

The load-bearing invariants: an injected failure adopts nothing (a
failed migration leaves policy, jits, and tokens untouched), every
recovery path replays bit-identically (prefill ≡ decode replay +
(seed, position)-deterministic sampling), and the serve loop never
hangs silently — it drains, degrades, or raises ``ServeHangError`` with
diagnostics.  Multi-device paths (tier loss, evacuation, migration
rollback) run in subprocesses with a forced device count, same pattern
as ``test_distributed.py``.
"""

import asyncio
import os
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np
import pytest

from repro.core.faults import (
    NO_FAULTS,
    FaultEvent,
    FaultKind,
    FaultPlan,
    InjectedFault,
    MigrationFault,
    SpillCorruptionError,
    TierLossError,
    TransientFault,
    checksum_tree,
    corrupt_tree,
    verify_spill,
)
from repro.core.hardware import MemoryTier
from repro.models import get_smoke_bundle
from repro.runtime.retry import (
    DEFAULT_RETRY,
    RetryBudgetExceeded,
    RetryPolicy,
    retry_call,
)
from repro.runtime.supervisor import Watchdog, WatchdogConfig
from repro.serve import (
    Request,
    Scheduler,
    SchedulerClosed,
    ServeConfig,
    ServeHangError,
    Server,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n: int = 4, timeout: int = 600):
    script = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={n}"\n'
        + textwrap.dedent(body)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, (
        f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    )
    return r.stdout


@pytest.fixture(scope="module")
def bundle():
    return get_smoke_bundle("olmo-1b")


@pytest.fixture(scope="module")
def params(bundle):
    return bundle.init_params(jax.random.PRNGKey(0), "float32")


# ---------------------------------------------------------------------------
# FaultPlan semantics
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_no_faults_is_falsy_and_inert(self):
        assert not NO_FAULTS
        assert NO_FAULTS.check("decode") is None
        assert bool(FaultPlan([FaultEvent("decode", 0, FaultKind.STALL)]))

    def test_window_indexing_per_site(self):
        plan = FaultPlan([
            FaultEvent("decode", at=2, kind=FaultKind.STALL,
                       seconds=0.0, times=2),
        ])
        fired = [plan.check("decode") is not None for _ in range(5)]
        assert fired == [False, False, True, True, False]
        # counters are per site: another site never fires this event
        assert plan.check("migrate") is None
        assert plan.site_count("decode") == 5
        assert plan.site_count("migrate") == 1

    def test_tier_loss_raises_with_parsed_tier(self):
        plan = FaultPlan([
            FaultEvent("decode", 0, FaultKind.TIER_LOSS, tier="peer_hbm"),
        ])
        with pytest.raises(TierLossError) as ei:
            plan.check("decode")
        assert ei.value.tier is MemoryTier.PEER_HBM
        assert isinstance(ei.value, InjectedFault)

    def test_migrate_fail_flavors(self):
        from repro.core.placement import DonorAxisError
        transient = FaultPlan([
            FaultEvent("migrate", 0, FaultKind.MIGRATE_FAIL),
        ])
        with pytest.raises(MigrationFault):
            transient.check("migrate")
        assert issubclass(MigrationFault, TransientFault)
        donor = FaultPlan([
            FaultEvent("migrate", 0, FaultKind.MIGRATE_FAIL,
                       error="donor"),
        ])
        with pytest.raises(DonorAxisError):
            donor.check("migrate")

    def test_stall_sleeps_and_returns_event(self):
        plan = FaultPlan([
            FaultEvent("decode", 0, FaultKind.STALL, seconds=0.05),
        ])
        t0 = time.perf_counter()
        ev = plan.check("decode")
        assert ev is not None and ev.kind is FaultKind.STALL
        assert time.perf_counter() - t0 >= 0.05

    def test_firing_record_serializes(self):
        plan = FaultPlan([
            FaultEvent("spill", 0, FaultKind.SPILL_CORRUPT),
        ], seed=7)
        plan.check("spill")
        d = plan.to_json()
        assert d["seed"] == 7
        assert d["fired"][0]["site"] == "spill"
        assert d["fired"][0]["kind"] == "spill_corrupt"


class TestSpillIntegrity:
    def test_checksum_detects_corruption(self):
        tree = {"a": jax.numpy.arange(12, dtype=jax.numpy.float32)
                .reshape(3, 4)}
        good = checksum_tree(tree)
        verify_spill(tree, good, rid=1)            # clean passes
        verify_spill(tree, None, rid=1)            # None skips
        bad = corrupt_tree(tree)
        assert checksum_tree(bad) != good
        with pytest.raises(SpillCorruptionError) as ei:
            verify_spill(bad, good, rid=3)
        assert ei.value.rid == 3


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------

class Flaky(Exception):
    """A test-local transient error (injected fault types may only be
    raised by the harness — the lint rule enforces it)."""


class TestRetry:
    def test_jitter_is_deterministic_per_seed(self):
        p = RetryPolicy(base_delay_s=0.1, max_delay_s=1.0, jitter=0.5)
        assert p.delay_s(2, seed=3) == p.delay_s(2, seed=3)
        assert p.delay_s(2, seed=3) != p.delay_s(2, seed=4)
        # capped exponential under the jitter band
        assert p.delay_s(5, seed=0) <= 1.0 * 1.5

    def test_succeeds_after_transient_failures(self):
        calls = {"n": 0}
        retried = []

        def fn():
            calls["n"] += 1
            if calls["n"] < 3:
                raise Flaky(f"attempt {calls['n']}")
            return "done"

        out = retry_call(
            fn, retry_on=(Flaky,), policy=RetryPolicy(max_attempts=3),
            on_retry=lambda a, e, d: retried.append(a), sleep=lambda d: None,
        )
        assert out == "done" and calls["n"] == 3 and retried == [0, 1]

    def test_non_retryable_propagates_immediately(self):
        calls = {"n": 0}

        def fn():
            calls["n"] += 1
            raise ValueError("deterministic bug")

        with pytest.raises(ValueError):
            retry_call(fn, retry_on=(Flaky,), sleep=lambda d: None)
        assert calls["n"] == 1

    def test_exhaustion_raises_budget_exceeded_with_cause(self):
        def fn():
            raise Flaky("always")

        with pytest.raises(RetryBudgetExceeded) as ei:
            retry_call(fn, retry_on=(Flaky,), label="op",
                       policy=RetryPolicy(max_attempts=2),
                       sleep=lambda d: None)
        assert ei.value.attempts == 2
        assert isinstance(ei.value.__cause__, Flaky)

    def test_time_budget_cuts_retries_short(self):
        calls = {"n": 0}

        def fn():
            calls["n"] += 1
            raise Flaky("always")

        with pytest.raises(RetryBudgetExceeded):
            retry_call(
                fn, retry_on=(Flaky,),
                policy=RetryPolicy(max_attempts=10, base_delay_s=1.0,
                                   jitter=0.0, budget_s=0.5),
                sleep=lambda d: None,
            )
        assert calls["n"] == 1   # first backoff would already overrun

    def test_default_policy_is_sane(self):
        assert DEFAULT_RETRY.max_attempts >= 2


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------

class TestWatchdog:
    def test_escalation_ladder_and_reset(self):
        wd = Watchdog(lambda: 0.01,
                      WatchdogConfig(budget_factor=10.0,
                                     min_deadline_s=0.1))
        assert wd.deadline_s() == pytest.approx(0.1)   # floored
        assert wd.observe(0.05) == "ok"
        assert [wd.observe(1.0) for _ in range(4)] == \
            ["stall", "retry", "evacuate", "hang"]
        assert wd.observe(0.05) == "ok" and wd.breaches == 0
        assert wd.observe(1.0) == "stall"              # ladder restarts
        assert wd.actions["hang"] == 1

    def test_deadline_follows_expected(self):
        t = {"s": 1.0}
        wd = Watchdog(lambda: t["s"], WatchdogConfig(budget_factor=2.0))
        assert wd.deadline_s() == pytest.approx(2.0)
        t["s"] = 4.0
        assert wd.deadline_s() == pytest.approx(8.0)

    def test_config_validates_thresholds(self):
        with pytest.raises(ValueError):
            WatchdogConfig(stall_after=3, retry_after=2).validate()
        with pytest.raises(ValueError):
            WatchdogConfig(stall_after=0).validate()


# ---------------------------------------------------------------------------
# Request lifecycle: deadlines and cancellation
# ---------------------------------------------------------------------------

class TestCancelAndDeadline:
    def test_cancel_mid_generation_frees_slot(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32),
                     params)
        seen = []
        req = Request(rid=0, prompt=np.arange(1, 6, dtype=np.int32),
                      max_new_tokens=20,
                      on_token=lambda r, t: seen.append(t))
        srv.add_request(req)
        srv.step()
        srv.step()
        n = len(req.out_tokens)
        assert n >= 1 and not req.done
        req.cancel()
        srv.step()
        assert req.done and req.finished_s is not None
        assert len(req.out_tokens) == n          # nothing decoded after
        assert seen[-1] == -1                    # terminal sentinel
        assert srv.stats()["cancelled"] == 1
        assert not srv.has_work()

    def test_deadline_expires_queued_request(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32),
                     params)
        seen = []
        req = Request(rid=0, prompt=np.arange(1, 6, dtype=np.int32),
                      max_new_tokens=4, deadline_s=0.0,
                      on_token=lambda r, t: seen.append(t))
        srv.add_request(req)
        time.sleep(0.01)
        srv.step()
        assert req.done and req.out_tokens == []
        assert seen == [-1]
        assert srv.stats()["expired"] == 1
        assert not srv.has_work()

    def test_unbounded_requests_unaffected(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32),
                     params)
        req = Request(rid=0, prompt=np.arange(1, 6, dtype=np.int32),
                      max_new_tokens=4)
        srv.add_request(req)
        srv.run_until_done(200)
        assert req.done and len(req.out_tokens) == 4
        assert srv.stats()["cancelled"] == 0
        assert srv.stats()["expired"] == 0


# ---------------------------------------------------------------------------
# Hang diagnostics
# ---------------------------------------------------------------------------

class TestRunUntilDone:
    def test_exhausted_steps_raise_serve_hang_error(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32),
                     params)
        srv.add_request(Request(rid=0,
                                prompt=np.arange(1, 6, dtype=np.int32),
                                max_new_tokens=25))
        with pytest.raises(ServeHangError) as ei:
            srv.run_until_done(max_steps=2)
        assert ei.value.live_rids == (0,)
        assert "max_steps=2" in str(ei.value)
        assert "decode_tokens" in ei.value.stats
        srv.run_until_done(200)                  # still drainable after

    def test_drained_loop_returns_cleanly(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32),
                     params)
        srv.run_until_done(max_steps=1)          # no work: no raise


class TestSchedulerClose:
    def test_close_cancels_pending_submit(self, bundle, params):
        server = Server(
            bundle,
            ServeConfig(batch_slots=1, max_len=32, max_queue=1),
            params,
        )
        sched = Scheduler(server)

        async def main():
            # fill the bounded queue so the next submit blocks on space
            await sched.submit(np.arange(1, 6), max_new_tokens=8)
            blocked = asyncio.ensure_future(
                sched.submit(np.arange(1, 6), max_new_tokens=4)
            )
            await asyncio.sleep(0)     # let it hit QueueFullError + wait
            assert not blocked.done()
            sched.close()
            with pytest.raises(SchedulerClosed):
                await blocked
            # drain what was admitted so run() exits
            await sched.run()

        asyncio.run(main())

    def test_close_after_submit_raises_immediately(self, bundle, params):
        server = Server(bundle, ServeConfig(batch_slots=1, max_len=32),
                        params)
        sched = Scheduler(server)

        async def main():
            sched.close()
            with pytest.raises(SchedulerClosed):
                await sched.submit(np.arange(1, 6), max_new_tokens=4)

        asyncio.run(main())

    def test_step_timeout_configurable(self, bundle, params):
        server = Server(bundle, ServeConfig(batch_slots=1, max_len=32),
                        params)
        assert Scheduler(server).step_timeout_s == 60.0
        assert Scheduler(server,
                         step_timeout_s=None).step_timeout_s is None


# ---------------------------------------------------------------------------
# Checkpoint writes: retry + background error capture
# ---------------------------------------------------------------------------

class TestCheckpointRetry:
    def test_transient_write_failure_retries(self, tmp_path, monkeypatch):
        from repro.checkpoint.checkpointer import Checkpointer
        real_rename = os.rename
        fails = {"n": 1}

        def flaky_rename(src, dst):
            if fails["n"] > 0:
                fails["n"] -= 1
                raise OSError("transient mount hiccup")
            return real_rename(src, dst)

        monkeypatch.setattr(os, "rename", flaky_rename)
        ck = Checkpointer(str(tmp_path / "ck"))
        ck.save(3, {"w": np.ones(4, np.float32)}, blocking=True)
        assert ck.latest_step() == 3

    def test_background_failure_surfaces_on_wait(self, tmp_path,
                                                 monkeypatch):
        from repro.checkpoint.checkpointer import Checkpointer

        def always_fail(src, dst):
            raise OSError("disk gone")

        monkeypatch.setattr(os, "rename", always_fail)
        monkeypatch.setattr(
            "repro.checkpoint.checkpointer.CHECKPOINT_RETRY",
            RetryPolicy(max_attempts=2, base_delay_s=0.0, jitter=0.0),
        )
        ck = Checkpointer(str(tmp_path / "ck"))
        ck.save(1, {"w": np.ones(2, np.float32)}, blocking=False)
        with pytest.raises(RetryBudgetExceeded):
            ck.wait()


# ---------------------------------------------------------------------------
# Multi-device: migration rollback, tier loss -> evacuation
# ---------------------------------------------------------------------------

class TestMigrationRollback:
    def test_failed_replan_adopts_nothing(self):
        """An injected donor-axis failure mid-replan leaves the policy
        object, the compiled jits, and the greedy tokens untouched."""
        run_with_devices("""
        import jax, numpy as np
        from repro.core.faults import FaultEvent, FaultKind, FaultPlan
        from repro.core.placement import DonorAxisError
        from repro.launch.mesh import make_donor_mesh
        from repro.models import get_smoke_bundle
        from repro.serve import Request, ServeConfig, Server

        bundle = get_smoke_bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        mesh = make_donor_mesh((2,), ("data",), 2)

        def serve(faults=None, interrupt=False):
            srv = Server(
                bundle,
                ServeConfig(batch_slots=2, max_len=32,
                            policy="kv_peer_hbm", faults=faults),
                params, mesh=mesh,
            )
            req = Request(rid=0, prompt=np.arange(1, 6, dtype=np.int32),
                          max_new_tokens=8)
            srv.add_request(req)
            srv.step(); srv.step()
            if interrupt:
                old_policy = srv.policy
                decode_fn = srv.engine._decode
                try:
                    srv.replan("hbm_resident")
                    raise SystemExit("expected DonorAxisError")
                except DonorAxisError:
                    pass
                assert srv.policy is old_policy, srv.policy.name
                assert srv.engine._decode is decode_fn, "jits rebuilt"
                assert srv.stats()["migrations"] == 0
            srv.run_until_done(400)
            assert req.done
            return req.out_tokens

        plan = FaultPlan([FaultEvent("migrate", at=0,
                                     kind=FaultKind.MIGRATE_FAIL,
                                     error="donor")])
        faulted = serve(faults=plan, interrupt=True)
        assert len(plan.fired) == 1
        reference = serve()
        assert faulted == reference, (faulted, reference)
        print("OK")
        """)


class TestTierLossRecovery:
    def test_tier_loss_evacuates_and_tokens_match(self):
        """Losing the donor tier mid-decode (with a corrupted spill for
        good measure): the server evacuates KV off peer HBM, replays
        what was parked, finishes every request, and the greedy tokens
        match a fault-free run."""
        run_with_devices("""
        import jax, numpy as np
        from repro.core.faults import FaultEvent, FaultKind, FaultPlan
        from repro.core.hardware import MemoryTier
        from repro.launch.mesh import make_donor_mesh
        from repro.models import get_smoke_bundle
        from repro.serve import Request, ServeConfig, Server

        bundle = get_smoke_bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        mesh = make_donor_mesh((2,), ("data",), 2)

        def reqs():
            return [Request(rid=i,
                            prompt=np.arange(1, 6 + i % 3, dtype=np.int32),
                            max_new_tokens=6 + i % 4)
                    for i in range(8)]

        def serve(faults=None, preempt=True):
            rs = reqs()
            srv = Server(
                bundle,
                ServeConfig(batch_slots=2, max_len=32,
                            policy="kv_peer_hbm", preempt=preempt,
                            preempt_wait=3, faults=faults,
                            verify_spills=True),
                params, mesh=mesh,
            )
            srv.add_requests(rs)
            srv.run_until_done(2000)
            assert all(r.done for r in rs)
            return [r.out_tokens for r in rs], srv

        plan = FaultPlan([
            FaultEvent("decode", at=6, kind=FaultKind.TIER_LOSS,
                       tier="peer_hbm"),
            FaultEvent("spill", at=0, kind=FaultKind.SPILL_CORRUPT),
        ])
        faulted, srv = serve(faults=plan)
        stats = srv.stats()
        assert stats["tier_losses"] == 1, stats
        assert stats["evacuations"] >= 1, stats
        assert MemoryTier.PEER_HBM in srv.rt.lost_tiers
        assert MemoryTier.PEER_HOST in srv.rt.lost_tiers  # same axis
        from repro.core.placement import Role
        assert srv.policy.placement(Role.KV_CACHE).tier \\
            not in srv.rt.lost_tiers
        # spill tier re-picked off the lost axis too
        assert srv.rt.spill_placement().tier not in srv.rt.lost_tiers

        reference, _ = serve(preempt=False)
        assert faulted == reference
        print("OK")
        """)
