"""Disaggregated prefill/decode serving: pools, handoff, bit-identity.

The load-bearing invariants: the ``pools=prefill:N,decode:M`` grammar
round-trips and composes with the policy string, a published ticket's
KV survives the donor_pod round trip bit-for-bit, a faulted handoff
adopts **nothing** (decode-side state untouched, the loss on the
ledger), and a disaggregated cluster's greedy tokens are bit-identical
to a colocated Server on a decode-pool-shaped mesh — across GQA, MLA,
and SSM cache layouts.  Multi-device paths run in subprocesses with a
forced device count, same pattern as ``test_distributed.py``.
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.placement import (
    PoolSplit,
    extract_pool_split,
    parse_policy,
)
from repro.serve.handoff import HandoffLedger

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n: int = 4, timeout: int = 600):
    script = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={n}"\n'
        + textwrap.dedent(body)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, (
        f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    )
    return r.stdout


# ---------------------------------------------------------------------------
# Pool-split grammar
# ---------------------------------------------------------------------------

class TestPoolSplitGrammar:
    def test_parse_round_trips(self):
        s = PoolSplit.parse("prefill:2,decode:2")
        assert (s.prefill, s.decode, s.total) == (2, 2, 4)
        assert s.to_str() == "pools=prefill:2,decode:2"
        assert PoolSplit.parse(s.to_str()) == s
        # either pool order, idempotent on an already-parsed split
        assert PoolSplit.parse("decode:2,prefill:2") == s
        assert PoolSplit.parse(s) is s

    def test_parse_rejects_malformed(self):
        with pytest.raises(ValueError, match="bad pool fragment"):
            PoolSplit.parse("prefill:2,decoed:2")
        with pytest.raises(ValueError, match="both pools"):
            PoolSplit.parse("prefill:2")
        with pytest.raises(ValueError, match="duplicate pool"):
            PoolSplit.parse("prefill:1,prefill:3")
        with pytest.raises(ValueError, match=">= 1 device"):
            PoolSplit(prefill=0, decode=4)

    def test_extract_from_policy_string(self):
        # the directive's value contains a comma, so it must be carved
        # out of the surrounding role grammar before parse_policy splits
        split, rest = extract_pool_split(
            "kv=remote_hbm,pools=prefill:1,decode:3"
        )
        assert split == PoolSplit(1, 3)
        assert rest == "kv=remote_hbm"
        # directive-only spec leaves no remainder
        split, rest = extract_pool_split("pools=prefill:2,decode:2")
        assert split == PoolSplit(2, 2)
        assert rest is None
        # directive in the middle: both neighbours survive
        split, rest = extract_pool_split(
            "kv=hbm,pools=prefill:2,decode:2,params=host"
        )
        assert split == PoolSplit(2, 2)
        assert rest == "kv=hbm,params=host"

    def test_extract_passes_through_non_directives(self):
        for spec in (None, "kv=hbm", {"kv_cache": "hbm"}):
            split, rest = extract_pool_split(spec)
            assert split is None
            assert rest is spec or rest == spec

    def test_parse_policy_rejects_unstripped_directive(self):
        with pytest.raises(ValueError, match="extract_pool_split"):
            parse_policy("kv=hbm,pools=prefill:1,decode:1")


class TestResolveSplit:
    def test_conflicting_splits_raise(self):
        from repro.serve.disagg import Cluster, DisaggConfig

        cfg = DisaggConfig(
            split="prefill:1,decode:3",
            policy="kv=hbm,pools=prefill:2,decode:2",
        )
        with pytest.raises(ValueError, match="conflicting pool splits"):
            Cluster._resolve_split(None, cfg, 4)

    def test_agreeing_directive_is_deduplicated(self):
        from repro.serve.disagg import Cluster, DisaggConfig

        cfg = DisaggConfig(
            split=PoolSplit(2, 2),
            policy="kv=hbm,pools=prefill:2,decode:2",
        )
        split, policy = Cluster._resolve_split(None, cfg, 4)
        assert split == PoolSplit(2, 2)
        assert policy == "kv=hbm"


# ---------------------------------------------------------------------------
# Crossing ledger
# ---------------------------------------------------------------------------

class TestHandoffLedger:
    def test_crossings_count_completed_round_trips(self):
        led = HandoffLedger()
        led.record("publish", 7, 1024, 0.5, 0.1)
        assert led.crossings(7) == 0          # published, not yet adopted
        led.record("adopt", 7, 1024, 0.25, 0.1)
        assert led.crossings(7) == 1
        assert led.crossings(8) == 0
        assert led.total_bytes("publish") == 1024
        assert led.total_bytes("adopt") == 1024

    def test_fault_replay_accounting(self):
        # the soak's invariant: a fault-recovered rid republishes but
        # still crosses exactly once
        led = HandoffLedger()
        led.record("publish", 3, 512, 0.1, 0.05)
        led.record("lost", 3, 512, 0.0, 0.05)
        led.record("publish", 3, 512, 0.1, 0.05)
        led.record("adopt", 3, 512, 0.1, 0.05)
        assert led.crossings(3) == 1
        j = led.to_json()
        assert (j["published"], j["adopted"], j["lost"]) == (2, 1, 1)
        assert j["bytes_published"] == 1024
        assert j["bytes_adopted"] == 512


# ---------------------------------------------------------------------------
# donor_pod realization: ticket round trip under a forced 4-device mesh
# ---------------------------------------------------------------------------

class TestHandoffRoundTrip:
    def test_ticket_round_trip_is_bit_identical(self):
        """publish → adopt → finalize returns the exact bytes that went
        in, having crossed the donor_pod tier (the published rows are
        committed to the bridge mesh spanning both pools)."""
        run_with_devices("""
        import jax
        import numpy as np

        from repro.models import get_smoke_bundle
        from repro.serve.disagg import make_pool_mesh
        from repro.serve.handoff import Handoff, make_bridge_mesh
        from repro.serve.sampling import GREEDY

        devs = jax.devices()
        pre, dec = devs[:2], devs[2:4]
        bundle = get_smoke_bundle("olmo-1b")
        handoff = Handoff(bundle, make_bridge_mesh(pre, dec))

        # one slot row per cache leaf, filled with non-trivial bytes
        cache = bundle.init_cache(batch=4, max_len=32, dtype="float32")
        leaves, treedef = jax.tree.flatten(cache)
        key = jax.random.PRNGKey(7)
        rows = []
        for i, leaf in enumerate(leaves):
            row_shape = (leaf.shape[0], 1) + leaf.shape[2:]
            rows.append(jax.random.normal(
                jax.random.fold_in(key, i), row_shape
            ).astype(leaf.dtype))
        rows = jax.tree.unflatten(treedef, rows)
        want = [np.asarray(l) for l in jax.tree.leaves(rows)]

        ticket = handoff.publish(11, rows, length=5, last_token=42,
                                 sampling=GREEDY)
        # the published rows live on the bridge mesh: their device set
        # spans BOTH pools, so the bytes physically left the prefill
        # pool (the donor_pod crossing)
        for leaf in jax.tree.leaves(ticket.rows):
            held = set(leaf.sharding.device_set)
            assert held & set(pre) and held & set(dec), held
        assert ticket.nbytes == sum(w.nbytes for w in want)
        assert ticket.publish_s > 0 and ticket.bound_s > 0

        handoff.adopt(ticket, make_pool_mesh(dec))
        assert handoff.staged == 1
        spilled = handoff.finalize(11)
        assert handoff.staged == 0
        assert (spilled.rid, spilled.length, spilled.last_token) \\
            == (11, 5, 42)

        got = [np.asarray(l) for l in jax.tree.leaves(spilled.rows)]
        assert len(got) == len(want)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
        # adopted rows are pinned to the decode pool alone
        for leaf in jax.tree.leaves(spilled.rows):
            assert set(leaf.sharding.device_set) <= set(dec)

        led = handoff.ledger
        assert led.crossings(11) == 1
        assert led.total_bytes("publish") == ticket.nbytes
        print("round trip OK:", ticket.nbytes, "bytes")
        """)

    def test_partial_handoff_adopts_nothing(self):
        """Both handoff fault kinds leave the decode side untouched: a
        lost ticket fails before any transfer, a corrupted transfer
        fails checksum verification at finalize.  Neither counts as a
        crossing; a clean retry afterwards does."""
        run_with_devices("""
        import jax
        import numpy as np
        import pytest

        from repro.core.faults import (
            FaultEvent, FaultKind, FaultPlan,
            SpillCorruptionError, TicketLossError,
        )
        from repro.models import get_smoke_bundle
        from repro.serve.disagg import make_pool_mesh
        from repro.serve.handoff import Handoff, make_bridge_mesh
        from repro.serve.sampling import GREEDY

        devs = jax.devices()
        pre, dec = devs[:2], devs[2:4]
        bundle = get_smoke_bundle("olmo-1b")
        plan = FaultPlan([
            FaultEvent(site="handoff", at=0, kind=FaultKind.TICKET_LOSS),
            FaultEvent(site="handoff", at=1,
                       kind=FaultKind.SPILL_CORRUPT),
        ])
        handoff = Handoff(bundle, make_bridge_mesh(pre, dec),
                          faults=plan)
        decode_mesh = make_pool_mesh(dec)

        cache = bundle.init_cache(batch=2, max_len=16, dtype="float32")
        rows = jax.tree.map(
            lambda l: jax.numpy.ones(
                (l.shape[0], 1) + l.shape[2:], l.dtype
            ),
            cache,
        )

        # fault 1: the ticket vanishes on the DCN path before any
        # transfer — nothing staged, nothing adopted, loss on the ledger
        t0 = handoff.publish(0, rows, length=3, last_token=9,
                             sampling=GREEDY)
        with pytest.raises(TicketLossError):
            handoff.adopt(t0, decode_mesh)
        assert handoff.staged == 0
        assert handoff.ledger.crossings(0) == 0
        assert handoff.ledger.lost.get(0) == 1

        # fault 2: bytes perturbed in flight — the adopt stages, but
        # finalize's publish-time checksum catches it and drops the rows
        t1 = handoff.publish(1, rows, length=3, last_token=9,
                             sampling=GREEDY)
        handoff.adopt(t1, decode_mesh)
        assert handoff.staged == 1
        with pytest.raises(SpillCorruptionError):
            handoff.finalize(1)
        assert handoff.staged == 0
        assert handoff.ledger.crossings(1) == 0
        assert handoff.ledger.lost.get(1) == 1

        # the plan is exhausted: a replayed publish of the same rid now
        # completes, and the rid still crosses exactly once
        t2 = handoff.publish(1, rows, length=3, last_token=9,
                             sampling=GREEDY)
        handoff.adopt(t2, decode_mesh)
        spilled = handoff.finalize(1)
        np.testing.assert_array_equal(
            np.asarray(jax.tree.leaves(spilled.rows)[0]),
            np.asarray(jax.tree.leaves(rows)[0]),
        )
        assert handoff.ledger.crossings(1) == 1
        assert len(plan.fired) == 2
        print("faulted handoffs adopted nothing")
        """)

    def test_staging_bound_is_enforced(self):
        """max_staged bounds in-flight adopts (the DonorStream depth
        discipline applied across tickets)."""
        run_with_devices("""
        import jax

        from repro.models import get_smoke_bundle
        from repro.serve.disagg import make_pool_mesh
        from repro.serve.handoff import Handoff, make_bridge_mesh
        from repro.serve.sampling import GREEDY

        devs = jax.devices()
        bundle = get_smoke_bundle("olmo-1b")
        handoff = Handoff(bundle, make_bridge_mesh(devs[:2], devs[2:4]),
                          max_staged=2)
        decode_mesh = make_pool_mesh(devs[2:4])
        cache = bundle.init_cache(batch=2, max_len=16, dtype="float32")
        rows = jax.tree.map(
            lambda l: jax.numpy.zeros(
                (l.shape[0], 1) + l.shape[2:], l.dtype
            ),
            cache,
        )
        for rid in range(2):
            handoff.adopt(
                handoff.publish(rid, rows, length=1, last_token=1,
                                sampling=GREEDY),
                decode_mesh,
            )
        t = handoff.publish(2, rows, length=1, last_token=1,
                            sampling=GREEDY)
        try:
            handoff.adopt(t, decode_mesh)
        except RuntimeError as e:
            assert "staging full" in str(e)
        else:
            raise AssertionError("third adopt should have been refused")
        handoff.finalize(0)
        handoff.adopt(t, decode_mesh)       # slot freed -> admitted
        handoff.finalize(1)
        handoff.finalize(2)
        print("staging bound enforced")
        """)


# ---------------------------------------------------------------------------
# disagg vs colocated: greedy token equality across cache layouts
# ---------------------------------------------------------------------------

#: one representative per KV layout the handoff must round-trip: grouped
#:-query attention, multi-head latent attention, and a state-space model
#: whose "KV" is a recurrent state + conv window, not a token axis
SWEEP_ARCHS = ["yi-6b", "deepseek-v2-236b", "mamba2-780m"]

_EQUALITY_BODY = """
import jax
import numpy as np

from repro.models import get_smoke_bundle
from repro.serve import Cluster, DisaggConfig, Server, ServeConfig
from repro.serve.disagg import make_pool_mesh

bundle = get_smoke_bundle({arch!r})
params = bundle.init_params(jax.random.PRNGKey(0), "float32")
rng = np.random.default_rng(0)
prompts = [
    rng.integers(1, bundle.cfg.vocab, 4 + (i % 5)).astype(np.int32)
    for i in range(6)
]

cluster = Cluster(
    bundle,
    DisaggConfig(batch_slots=4, max_len=32, prefill_chunk=8,
                 split="prefill:2,decode:2"),
    params,
)
reqs = [cluster.submit(p, max_new_tokens=5) for p in prompts]
cluster.run_until_done(400)
disagg = {{r.rid: list(r.out_tokens) for r in reqs}}

# colocated baseline on a mesh shaped like the decode pool: same device
# count -> same compiled steps -> greedy tokens must match exactly
ref = Server(
    bundle,
    ServeConfig(batch_slots=4, max_len=32, prefill_chunk=8),
    params, mesh=make_pool_mesh(jax.devices()[2:4]),
)
ref_reqs = [ref.submit(p, max_new_tokens=5) for p in prompts]
ref.run_until_done(200)
colocated = {{r.rid: list(r.out_tokens) for r in ref_reqs}}

assert disagg == colocated, (disagg, colocated)
assert all(len(t) == 5 for t in disagg.values())
for r in reqs:
    assert cluster.ledger.crossings(r.rid) == 1, r.rid
led = cluster.stats()["handoff"]
assert led["published"] == 6 and led["adopted"] == 6 and led["lost"] == 0
print({arch!r}, "disagg == colocated:", disagg)
"""


class TestDisaggEquality:
    @pytest.mark.parametrize("arch", SWEEP_ARCHS)
    def test_greedy_tokens_match_colocated(self, arch):
        run_with_devices(_EQUALITY_BODY.format(arch=arch))

    def test_fault_recovery_preserves_tokens(self):
        """A lost ticket and a corrupted transfer both replay as fresh
        through the prefill pool — and the final greedy tokens are still
        bit-identical to the colocated baseline."""
        run_with_devices("""
        import jax
        import numpy as np

        from repro.core.faults import FaultEvent, FaultKind, FaultPlan
        from repro.models import get_smoke_bundle
        from repro.serve import Cluster, DisaggConfig, Server, ServeConfig
        from repro.serve.disagg import make_pool_mesh

        bundle = get_smoke_bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        rng = np.random.default_rng(0)
        prompts = [
            rng.integers(1, bundle.cfg.vocab, 4 + (i % 5)).astype(np.int32)
            for i in range(6)
        ]

        plan = FaultPlan([
            FaultEvent(site="handoff", at=1,
                       kind=FaultKind.TICKET_LOSS),
            FaultEvent(site="handoff", at=3,
                       kind=FaultKind.SPILL_CORRUPT),
        ])
        cluster = Cluster(
            bundle,
            DisaggConfig(batch_slots=4, max_len=32, prefill_chunk=8,
                         split="prefill:2,decode:2", faults=plan),
            params,
        )
        reqs = [cluster.submit(p, max_new_tokens=5) for p in prompts]
        cluster.run_until_done(400)

        ref = Server(
            bundle,
            ServeConfig(batch_slots=4, max_len=32, prefill_chunk=8),
            params, mesh=make_pool_mesh(jax.devices()[2:4]),
        )
        ref_reqs = [ref.submit(p, max_new_tokens=5) for p in prompts]
        ref.run_until_done(200)

        assert {r.rid: list(r.out_tokens) for r in reqs} \\
            == {r.rid: list(r.out_tokens) for r in ref_reqs}
        st = cluster.stats()
        led = st["handoff"]
        assert len(plan.fired) == 2
        assert st["handoff_replays"] == 2
        assert led["lost"] == 2
        assert led["published"] == 8      # 6 + 2 fault republishes
        assert led["adopted"] == 6        # every rid still adopts once
        for r in reqs:
            assert cluster.ledger.crossings(r.rid) == 1
        print("fault recovery token-identical")
        """)
