"""The scheduler layer: oversubscription, backpressure, planner-priced
preemption/promotion, streaming, and the asyncio front end.

The load-bearing invariant throughout: greedy tokens are **bit-identical
under any scheduling history** — admission order, queueing, preemption to
an off-cache tier and promotion back never change a single token.  That
is what makes oversubscription a first-class serving regime instead of a
correctness hazard.
"""

import asyncio

import jax
import numpy as np
import pytest

from repro.models import get_smoke_bundle
from repro.serve import (
    QueueFullError,
    Request,
    SamplingParams,
    Scheduler,
    ServeConfig,
    Server,
)


@pytest.fixture(scope="module")
def bundle():
    return get_smoke_bundle("olmo-1b")


@pytest.fixture(scope="module")
def params(bundle):
    return bundle.init_params(jax.random.PRNGKey(0), "float32")


def _req(i, *, n=6, extra=0, sampling=None):
    return Request(
        rid=i, prompt=np.arange(1, 6 + extra, dtype=np.int32),
        max_new_tokens=n,
        **({"sampling": sampling} if sampling else {}),
    )


def _solo_tokens(bundle, params, req_proto):
    """Reference: the same request served alone on a fresh server."""
    srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32), params)
    req = Request(rid=0, prompt=req_proto.prompt,
                  max_new_tokens=req_proto.max_new_tokens,
                  sampling=req_proto.sampling)
    srv.add_request(req)
    srv.run_until_done(200)
    return req.out_tokens


class TestOversubscription:
    def test_excess_requests_queue_and_drain(self, bundle, params):
        """More requests than slots: the overflow waits in the queue (no
        error) and every request completes in admission order."""
        srv = Server(bundle, ServeConfig(batch_slots=2, max_len=32), params)
        reqs = [_req(i, extra=i) for i in range(6)]
        srv.add_requests(reqs)
        assert srv.queue_depth == 6     # nothing admitted before a step
        srv.run_until_done(500)
        assert all(r.done and len(r.out_tokens) == 6 for r in reqs)
        assert not srv.has_work()
        assert srv.stats()["peak_queue"] == 6

    def test_bounded_queue_backpressure(self, bundle, params):
        """cfg.max_queue bounds *waiting* requests: the add that would
        exceed it raises QueueFullError, and draining reopens intake."""
        srv = Server(
            bundle,
            ServeConfig(batch_slots=1, max_len=32, max_queue=2),
            params,
        )
        srv.add_request(_req(0))
        srv.add_request(_req(1))
        with pytest.raises(QueueFullError, match="wait queue is full"):
            srv.add_request(_req(2))
        # the rejected request left no trace
        assert 2 not in srv.live_rids
        srv.run_until_done(200)
        srv.add_request(_req(2))        # intake reopened
        srv.run_until_done(200)
        assert not srv.has_work()

    def test_queued_tokens_match_solo_runs(self, bundle, params):
        """Queueing through a 1-slot server never changes greedy
        tokens."""
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32), params)
        reqs = [_req(i, extra=i) for i in range(3)]
        srv.add_requests(reqs)
        srv.run_until_done(300)
        for r in reqs:
            assert r.out_tokens == _solo_tokens(bundle, params, r)


class TestPreemption:
    def test_preempt_promote_keeps_greedy_tokens(self, bundle, params):
        """The acceptance criterion: a preemption-heavy oversubscribed
        run produces exactly the solo-run tokens for every greedy
        request, with >= 1 spill and >= 1 promotion actually exercised."""
        srv = Server(
            bundle,
            ServeConfig(batch_slots=2, max_len=32, preempt=True,
                        preempt_wait=2),
            params,
        )
        reqs = [_req(i, n=8 + 4 * i, extra=i) for i in range(4)]
        srv.add_requests(reqs)
        srv.run_until_done(500)
        stats = srv.stats()
        assert stats["preemptions"] >= 1, stats
        assert stats["promotions"] >= 1, stats
        assert stats["preemptions"] == stats["promotions"]  # all came back
        assert stats["spill_s"] > 0 and stats["restore_s"] > 0
        for r in reqs:
            assert r.done
            assert r.out_tokens == _solo_tokens(bundle, params, r), r.rid
        preempted = [r for r in reqs if r.preemptions]
        assert preempted, "no request recorded a preemption"

    def test_sampled_requests_survive_preemption(self, bundle, params):
        """Seeded sampling is (seed, position)-deterministic, so spills
        and promotions cannot move a sampled request's tokens either."""
        mk = lambda i: Request(
            rid=i, prompt=np.arange(1, 6 + i, dtype=np.int32),
            max_new_tokens=8 + 4 * i,
            sampling=SamplingParams(temperature=0.8, top_k=12, seed=i),
        )
        srv = Server(
            bundle,
            ServeConfig(batch_slots=2, max_len=32, preempt=True,
                        preempt_wait=2),
            params,
        )
        reqs = [mk(i) for i in range(4)]
        srv.add_requests(reqs)
        srv.run_until_done(500)
        assert srv.stats()["preemptions"] >= 1
        for i, r in enumerate(reqs):
            assert r.out_tokens == _solo_tokens(bundle, params, mk(i)), i

    def test_no_preemption_when_disabled(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32), params)
        srv.add_requests([_req(i, n=10) for i in range(3)])
        srv.run_until_done(300)
        assert srv.stats()["preemptions"] == 0

    def test_thrash_guard_respects_preempt_wait(self, bundle, params):
        """A slot (re)occupied within preempt_wait ticks is not a
        victim: with a long window and short requests, natural drain
        wins and nothing spills."""
        srv = Server(
            bundle,
            ServeConfig(batch_slots=1, max_len=32, preempt=True,
                        preempt_wait=64),
            params,
        )
        srv.add_requests([_req(i, n=4) for i in range(3)])
        srv.run_until_done(300)
        assert srv.stats()["preemptions"] == 0

    def test_runtime_prices_the_spill(self, bundle, params):
        """The pricing hook surface: a placement plus a positive
        round-trip time, consistent with the datapath copy bounds."""
        srv = Server(bundle, ServeConfig(batch_slots=2, max_len=32), params)
        nbytes = srv.engine.slot_bytes()
        assert nbytes > 0
        place, price = srv.rt.preemption_price(nbytes)
        assert price >= 0.0
        assert place.tier is not None
        step_s = srv.rt.decode_step_seconds(2, 32)
        assert step_s > 0.0


class TestStreaming:
    def test_on_token_streams_in_decode_order(self, bundle, params):
        got = []
        req = Request(
            rid=0, prompt=np.arange(1, 7, dtype=np.int32),
            max_new_tokens=5,
            on_token=lambda r, t: got.append((t, r.done)),
        )
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32), params)
        srv.add_request(req)
        srv.run_until_done(100)
        assert [t for t, _ in got] == req.out_tokens
        # done flag visible exactly on the final token's callback
        assert [d for _, d in got] == [False] * 4 + [True]

    def test_latency_stamps_monotonic(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32), params)
        reqs = [_req(i) for i in range(2)]
        srv.add_requests(reqs)
        srv.run_until_done(200)
        for r in reqs:
            assert r.submitted_s <= r.first_token_s <= r.finished_s


class _NoChunkBundle:
    """Proxy bundle whose ``prefill_at`` is genuinely unimplemented —
    the only kind of bundle left on the decode-replay fallback now that
    encoder-decoder bundles chunk-prefill."""

    def __init__(self, inner):
        self._inner = inner

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def prefill_at(self, *args, **kwargs):
        raise NotImplementedError


class TestReplayFallback:
    def test_encdec_bundle_chunk_prefills(self, caplog):
        """Encoder-decoder bundles chunk-prefill like everything else
        (their cross KV is read-only during generation) — no replay
        fallback, no warning."""
        enc = get_smoke_bundle("seamless-m4t-medium")
        eparams = enc.init_params(jax.random.PRNGKey(0), "float32")
        srv = Server(enc, ServeConfig(batch_slots=2, max_len=32), eparams)
        assert srv.engine.supports_chunked_prefill
        with caplog.at_level("WARNING", logger="repro.serve.engine"):
            reqs = [_req(i, n=3, extra=i) for i in range(3)]
            srv.add_requests(reqs)
            srv.run_until_done(300)
        assert all(r.done for r in reqs)
        assert srv.stats()["decode_replay_prefills"] == 0
        assert not [r for r in caplog.records
                    if "decode-step replay" in r.getMessage()]

    def test_unchunkable_admission_warns_once_and_counts(self, bundle,
                                                         params, caplog):
        """The O(B*L) decode-replay prefill fallback (bundles without
        ``prefill_at``) is visible: one warning ever, a counter per
        admission."""
        srv = Server(_NoChunkBundle(bundle),
                     ServeConfig(batch_slots=2, max_len=32), params)
        assert not srv.engine.supports_chunked_prefill
        with caplog.at_level("WARNING", logger="repro.serve.engine"):
            reqs = [_req(i, n=3, extra=i) for i in range(3)]
            srv.add_requests(reqs)
            srv.run_until_done(300)
        assert all(r.done for r in reqs)
        assert srv.stats()["decode_replay_prefills"] == 3
        warns = [r for r in caplog.records
                 if "decode-step replay" in r.getMessage()]
        assert len(warns) == 1, "replay warning must fire exactly once"

    def test_chunked_bundle_never_counts_replay(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32), params)
        assert srv.engine.supports_chunked_prefill
        srv.add_request(_req(0))
        srv.run_until_done(100)
        assert srv.stats()["decode_replay_prefills"] == 0


class TestStatsSurface:
    def test_stats_is_a_method_with_all_layers(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32), params)
        srv.add_request(_req(0))
        srv.run_until_done(100)
        stats = srv.stats()
        for key in ("prefill_tokens", "decode_tokens", "replans",
                    "migrations", "decode_replay_prefills", "preemptions",
                    "promotions", "peak_queue", "queued", "spilled",
                    "spill_s", "restore_s"):
            assert key in stats, key
        assert stats["decode_tokens"] == 6
        tp = srv.throughput()
        assert tp["decode_tps"] > 0


class TestCalibrationObservations:
    """The Executor's decode-step timings as calibration observations:
    the EWMA lives on the Runtime (keyed by batch/len/policy), the first
    step after every executor (re)build is warm-up and never observed,
    and pricing falls back to the analytic prediction until a real
    measurement lands."""

    def test_analytic_fallback_before_any_observation(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=2, max_len=32), params)
        assert srv.rt.measured_step_s(2, 32) is None
        assert srv.engine.measured_step_s is None
        step_s = srv.rt.decode_step_seconds(2, 32)
        assert step_s > 0.0
        assert step_s == srv.rt._analytic_step_seconds(2, 32)

    def test_first_step_after_build_is_warmup(self, bundle, params):
        """The compile-laden first decode step never pollutes the EWMA:
        no observation lands until the executor's second step."""
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32), params)
        srv.add_request(_req(0, n=6))
        while srv.has_work() and srv.engine._steps_since_build < 1:
            srv.step()
        assert srv.engine._steps_since_build == 1
        assert srv.rt.measured_step_s(1, 32) is None
        while srv.has_work() and srv.engine._steps_since_build < 2:
            srv.step()
        assert srv.rt.measured_step_s(1, 32) is not None
        assert srv.engine.measured_step_s == srv.rt.measured_step_s(1, 32)

    def test_ewma_converges_and_prices_preemption(self, bundle, params):
        """Feeding a constant measured step time converges the EWMA to
        it, and decode_step_seconds — the scheduler's preemption-ledger
        wait price — returns the measured value, not the analytic one."""
        srv = Server(bundle, ServeConfig(batch_slots=2, max_len=32), params)
        analytic = srv.rt.decode_step_seconds(2, 32)
        first = srv.rt.observe_decode_step(2, 32, 0.025)
        assert first == pytest.approx(0.025)    # first observation seeds
        for _ in range(60):
            srv.rt.observe_decode_step(2, 32, 0.025)
        assert srv.rt.decode_step_seconds(2, 32) == pytest.approx(
            0.025, rel=1e-6)
        assert srv.rt.decode_step_seconds(2, 32) != analytic
        # observations land in the replay log under the decode_step term
        err = srv.rt.replay.per_term_error().get("decode_step")
        assert err is not None and err.count == 61
        # other shapes still fall back to the analytic prediction
        assert srv.rt.measured_step_s(1, 16) is None

    def test_nonpositive_observation_is_ignored(self, bundle, params):
        srv = Server(bundle, ServeConfig(batch_slots=2, max_len=32), params)
        srv.rt.observe_decode_step(2, 32, 0.0)
        srv.rt.observe_decode_step(2, 32, -1.0)
        assert srv.rt.measured_step_s(2, 32) is None

    def test_serve_run_feeds_the_runtime(self, bundle, params):
        """End to end: a real serve run leaves a measured EWMA and
        replay records on the runtime."""
        srv = Server(bundle, ServeConfig(batch_slots=1, max_len=32), params)
        srv.add_request(_req(0, n=8))
        srv.run_until_done(200)
        measured = srv.rt.measured_step_s(1, 32)
        assert measured is not None and measured > 0
        assert "decode_step" in srv.rt.replay.per_term_error()

    def test_tokens_bit_identical_under_calibration(self, bundle, params):
        """The acceptance criterion: activating a measurement-calibrated
        system re-prices scheduling but cannot move a single greedy
        token, even through a preemption-heavy oversubscribed run."""
        from repro.core.hardware import get_active_system, set_active_system

        cfg = lambda: ServeConfig(batch_slots=2, max_len=32, preempt=True,
                                  preempt_wait=2)
        reqs = lambda: [_req(i, n=8 + 4 * i, extra=i) for i in range(4)]

        baseline = Server(bundle, cfg(), params)
        base_reqs = reqs()
        baseline.add_requests(base_reqs)
        baseline.run_until_done(500)

        spec = get_active_system()
        calibrated = spec.with_measurements(
            hbm_bandwidth=8e9, ici_link_bandwidth=1e9, pcie_bandwidth=2e9)
        prev = set_active_system(calibrated)
        try:
            srv = Server(bundle, cfg(), params)
            assert srv.rt.system is calibrated   # runtime adopted it
            assert srv.rt.system.provenance_of("hbm_bandwidth") == "measured"
            cal_reqs = reqs()
            srv.add_requests(cal_reqs)
            srv.run_until_done(500)
        finally:
            set_active_system(prev)
        for b, c in zip(base_reqs, cal_reqs):
            assert b.done and c.done
            assert c.out_tokens == b.out_tokens, c.rid


class TestAsyncScheduler:
    def test_submit_stream_drain(self, bundle, params):
        """The asyncio front end: concurrent clients submit (absorbing
        backpressure), stream their tokens, and the driver drains —
        tokens identical to the sync path."""
        server = Server(
            bundle,
            ServeConfig(batch_slots=2, max_len=32, max_queue=2),
            params,
        )
        sched = Scheduler(server)
        prompts = [np.arange(1, 6 + i, dtype=np.int32) for i in range(5)]

        async def client(i):
            req = await sched.submit(prompts[i], max_new_tokens=4)
            return [tok async for tok in sched.stream(req)]

        async def main():
            async def clients():
                outs = await asyncio.gather(
                    *(client(i) for i in range(5)))
                sched.close()
                return outs
            _, outs = await asyncio.gather(sched.run(), clients())
            return outs

        outs = asyncio.run(main())
        assert all(len(o) == 4 for o in outs)
        assert not server.has_work()
        # async scheduling is still just the sync engine underneath
        for prompt, out in zip(prompts, outs):
            proto = Request(rid=0, prompt=prompt, max_new_tokens=4)
            assert out == _solo_tokens(bundle, params, proto)

    def test_backpressure_never_raises_through_submit(self, bundle, params):
        """max_queue=1 with many clients: submissions wait rather than
        surface QueueFullError."""
        server = Server(
            bundle,
            ServeConfig(batch_slots=1, max_len=32, max_queue=1),
            params,
        )
        sched = Scheduler(server)

        async def main():
            async def client(i):
                req = await sched.submit(
                    np.arange(1, 5, dtype=np.int32), max_new_tokens=2)
                async for _ in sched.stream(req):
                    pass
                return req

            async def clients():
                reqs = await asyncio.gather(*(client(i) for i in range(4)))
                sched.close()
                return reqs
            _, reqs = await asyncio.gather(sched.run(), clients())
            return reqs

        reqs = asyncio.run(main())
        assert all(r.done for r in reqs)
        assert server.stats()["peak_queue"] <= 1
