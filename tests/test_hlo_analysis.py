"""HLO analyzer tests: synthetic text fixtures + a real compiled module."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_analysis import (
    HOST_MEMORY_SPACE,
    analyze_hlo_text,
    decode_replica_groups,
    entry_parameters,
    group_axes,
    parse_hlo,
    parse_input_output_alias,
    parse_shapes,
    total_bytes,
)

SYNTHETIC = """\
HloModule test, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main () -> f32[] {
  %c = s32[] constant(0)
  %x0 = f32[8,8]{1,0} constant({...})
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%c, %x0)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
  %cp = f32[8,8]{1,0} collective-permute(%r), channel_id=2, source_target_pairs={{0,4},{4,0}}
  ROOT %s = f32[] reduce(%cp, %c), dimensions={0,1}, to_apply=%add2
}
"""


class TestShapeParsing:
    def test_simple(self):
        (s,) = parse_shapes("bf16[4,64,128]{2,1,0}")
        assert s.dims == (4, 64, 128) and s.nbytes == 4 * 64 * 128 * 2

    def test_tuple_with_comments(self):
        shapes = parse_shapes(
            "(s32[], bf16[2,2]{1,0}, /*index=5*/f32[3]{0})"
        )
        assert [x.dims for x in shapes] == [(), (2, 2), (3,)]
        assert total_bytes("(s32[], f32[4]{0})") == 4 + 16

    def test_scalar(self):
        (s,) = parse_shapes("pred[]")
        assert s.numel == 1 and s.nbytes == 1


class TestSyntheticModule:
    def test_trip_count_multiplies(self):
        cost = analyze_hlo_text(SYNTHETIC, {"data": 2, "model": 4})
        # dot: 2*8*8*8 flops, x10 trips
        assert cost.flops == pytest.approx(2 * 8 * 8 * 8 * 10)

    def test_collectives_attributed(self):
        cost = analyze_hlo_text(SYNTHETIC, {"data": 2, "model": 4})
        ops = {c.opcode: c for c in cost.collectives}
        ar = ops["all-reduce"]
        assert ar.count == 10
        assert ar.group_size == 4 and ar.axes == ("model",)
        # ring all-reduce wire: 2*(n-1)/n * payload
        assert ar.wire_bytes == pytest.approx(
            2 * 3 / 4 * 8 * 8 * 4 * 10
        )
        cp = ops["collective-permute"]
        assert cp.axes == ("data",) and cp.count == 1

    def test_replica_group_decoding(self):
        g = decode_replica_groups("replica_groups=[2,4]<=[8]")
        assert g == [[0, 1, 2, 3], [4, 5, 6, 7]]
        g = decode_replica_groups("replica_groups=[4,2]<=[2,4]T(1,0)")
        assert g == [[0, 4], [1, 5], [2, 6], [3, 7]]
        g = decode_replica_groups("replica_groups={{0,2},{1,3}}")
        assert g == [[0, 2], [1, 3]]

    def test_group_axes(self):
        axes = {"pod": 2, "data": 2, "model": 2}
        assert group_axes([[0, 1]], axes) == ("model",)
        assert group_axes([[0, 2]], axes) == ("data",)
        assert group_axes([[0, 4]], axes) == ("pod",)
        assert group_axes([[0, 1, 2, 3]], axes) == ("data", "model")


TRANSFER_MODULE = """\
HloModule xfer, input_output_alias={ {0}: (1, {}, may-alias), {1,0}: (2, {0}, must-alias) }

ENTRY %main (p0: f32[16], p1: f32[1024], p2: (f32[8], f32[8])) -> (f32[1024], f32[8]) {
  %p0 = f32[16]{0} parameter(0), metadata={op_name="p[\\'blocks\\'][0][\\'w\\']"}
  %p1 = f32[1024]{0} parameter(1), metadata={op_name="caches[0]"}
  %p2 = (f32[8]{0}, f32[8]{0}) parameter(2), metadata={op_name="state.tokens"}
  %cs = (f32[1024]{0:S(5)}, f32[1024]{0}, u32[]) copy-start(%p1)
  %cd = f32[1024]{0:S(5)} copy-done(%cs)
  %g = f32[8]{0} get-tuple-element(%p2), index=0
  %c = f32[8]{0} copy(%g)
  ROOT %t = (f32[1024]{0:S(5)}, f32[8]{0}) tuple(%cd, %c)
}
"""


class TestMemorySpaces:
    def test_space_suffix_parsed(self):
        (s,) = parse_shapes("f32[1024]{0:S(5)}")
        assert s.space == HOST_MEMORY_SPACE and s.on_host
        (d,) = parse_shapes("f32[1024]{0}")
        assert d.space == 0 and not d.on_host

    def test_paren_tuple_instruction_parsed(self):
        # the copy-start tuple type contains parens (S(5)) — the
        # instruction regex must not stop at the first ')'
        comps = parse_hlo(TRANSFER_MODULE)
        ins = comps["main"].instructions["cs"]
        assert ins.opcode == "copy-start"
        assert [s.space for s in ins.shapes] == [5, 0, 0]


class TestTransferAccounting:
    def test_copy_start_done_not_double_counted(self):
        cost = analyze_hlo_text(TRANSFER_MODULE)
        # copy-start: 1 read + 1 write of the 4096 B payload; copy-done:
        # handle resolution, zero; small copy: 2 x 32 B
        assert cost.hbm_bytes == pytest.approx(2 * 4096 + 0 + 2 * 32)

    def test_transfer_stats_and_host_bytes(self):
        cost = analyze_hlo_text(TRANSFER_MODULE)
        by_op = {t.name: t for t in cost.transfers}
        assert set(by_op) == {"cs", "c"}
        cs = by_op["cs"]
        assert cs.opcode == "copy-start" and cs.nbytes == 4096
        assert cs.src_space == 0 and cs.dst_space == HOST_MEMORY_SPACE
        assert cs.crosses_host
        c = by_op["c"]
        assert c.nbytes == 32 and not c.crosses_host
        # only the host-crossing transfer counts toward the budget
        assert cost.host_transfer_bytes == 4096


class TestAliasHeader:
    def test_alias_entries_parsed(self):
        pairs = parse_input_output_alias(TRANSFER_MODULE)
        assert len(pairs) == 2
        flat, nested = pairs
        assert flat.output_index == (0,) and flat.param_number == 1
        assert flat.param_index == () and flat.kind == "may-alias"
        assert nested.output_index == (1, 0) and nested.param_number == 2
        assert nested.param_index == (0,) and nested.kind == "must-alias"

    def test_no_header_is_empty(self):
        assert parse_input_output_alias(SYNTHETIC) == []

    def test_entry_parameters(self):
        params = entry_parameters(TRANSFER_MODULE)
        assert [p.number for p in params] == [0, 1, 2]
        p0, p1, p2 = params
        # \\' escapes unquoted; arg_root splits at the first [ or .
        assert p0.op_name == "p['blocks'][0]['w']" and p0.arg_root == "p"
        assert p1.arg_root == "caches" and p1.nbytes == 4096
        assert p2.arg_root == "state" and p2.nbytes == 64


class TestRealModule:
    def test_scan_matmul_exact_flops(self):
        D, L = 64, 6

        def f(x, ws):
            y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
            return y

        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        compiled = jax.jit(f).lower(x, ws).compile()
        cost = analyze_hlo_text(compiled.as_text())
        assert cost.flops == pytest.approx(2 * D**3 * L)
        # XLA's own cost_analysis counts the body ONCE — document the gap
        # (+ a couple of scalar loop-counter flops); older jax returns a
        # one-element list of dicts rather than a dict
        xla = compiled.cost_analysis()
        if isinstance(xla, (list, tuple)):
            xla = xla[0]
        xla = xla["flops"]
        assert xla == pytest.approx(2 * D**3, abs=16)

    def test_bytes_positive_and_bounded(self):
        def f(a, b):
            return jnp.dot(a, b)

        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        compiled = jax.jit(f).lower(a, a).compile()
        cost = analyze_hlo_text(compiled.as_text())
        nbytes = 128 * 128 * 4
        assert cost.hbm_bytes >= 3 * nbytes  # 2 reads + 1 write minimum
        assert cost.hbm_bytes <= 10 * nbytes
