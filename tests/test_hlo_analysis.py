"""HLO analyzer tests: synthetic text fixtures + a real compiled module."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_analysis import (
    analyze_hlo_text,
    decode_replica_groups,
    group_axes,
    parse_hlo,
    parse_shapes,
    total_bytes,
)

SYNTHETIC = """\
HloModule test, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main () -> f32[] {
  %c = s32[] constant(0)
  %x0 = f32[8,8]{1,0} constant({...})
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%c, %x0)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
  %cp = f32[8,8]{1,0} collective-permute(%r), channel_id=2, source_target_pairs={{0,4},{4,0}}
  ROOT %s = f32[] reduce(%cp, %c), dimensions={0,1}, to_apply=%add2
}
"""


class TestShapeParsing:
    def test_simple(self):
        (s,) = parse_shapes("bf16[4,64,128]{2,1,0}")
        assert s.dims == (4, 64, 128) and s.nbytes == 4 * 64 * 128 * 2

    def test_tuple_with_comments(self):
        shapes = parse_shapes(
            "(s32[], bf16[2,2]{1,0}, /*index=5*/f32[3]{0})"
        )
        assert [x.dims for x in shapes] == [(), (2, 2), (3,)]
        assert total_bytes("(s32[], f32[4]{0})") == 4 + 16

    def test_scalar(self):
        (s,) = parse_shapes("pred[]")
        assert s.numel == 1 and s.nbytes == 1


class TestSyntheticModule:
    def test_trip_count_multiplies(self):
        cost = analyze_hlo_text(SYNTHETIC, {"data": 2, "model": 4})
        # dot: 2*8*8*8 flops, x10 trips
        assert cost.flops == pytest.approx(2 * 8 * 8 * 8 * 10)

    def test_collectives_attributed(self):
        cost = analyze_hlo_text(SYNTHETIC, {"data": 2, "model": 4})
        ops = {c.opcode: c for c in cost.collectives}
        ar = ops["all-reduce"]
        assert ar.count == 10
        assert ar.group_size == 4 and ar.axes == ("model",)
        # ring all-reduce wire: 2*(n-1)/n * payload
        assert ar.wire_bytes == pytest.approx(
            2 * 3 / 4 * 8 * 8 * 4 * 10
        )
        cp = ops["collective-permute"]
        assert cp.axes == ("data",) and cp.count == 1

    def test_replica_group_decoding(self):
        g = decode_replica_groups("replica_groups=[2,4]<=[8]")
        assert g == [[0, 1, 2, 3], [4, 5, 6, 7]]
        g = decode_replica_groups("replica_groups=[4,2]<=[2,4]T(1,0)")
        assert g == [[0, 4], [1, 5], [2, 6], [3, 7]]
        g = decode_replica_groups("replica_groups={{0,2},{1,3}}")
        assert g == [[0, 2], [1, 3]]

    def test_group_axes(self):
        axes = {"pod": 2, "data": 2, "model": 2}
        assert group_axes([[0, 1]], axes) == ("model",)
        assert group_axes([[0, 2]], axes) == ("data",)
        assert group_axes([[0, 4]], axes) == ("pod",)
        assert group_axes([[0, 1, 2, 3]], axes) == ("data", "model")


class TestRealModule:
    def test_scan_matmul_exact_flops(self):
        D, L = 64, 6

        def f(x, ws):
            y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
            return y

        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        compiled = jax.jit(f).lower(x, ws).compile()
        cost = analyze_hlo_text(compiled.as_text())
        assert cost.flops == pytest.approx(2 * D**3 * L)
        # XLA's own cost_analysis counts the body ONCE — document the gap
        # (+ a couple of scalar loop-counter flops); older jax returns a
        # one-element list of dicts rather than a dict
        xla = compiled.cost_analysis()
        if isinstance(xla, (list, tuple)):
            xla = xla[0]
        xla = xla["flops"]
        assert xla == pytest.approx(2 * D**3, abs=16)

    def test_bytes_positive_and_bounded(self):
        def f(a, b):
            return jnp.dot(a, b)

        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        compiled = jax.jit(f).lower(a, a).compile()
        cost = analyze_hlo_text(compiled.as_text())
        nbytes = 128 * 128 * 4
        assert cost.hbm_bytes >= 3 * nbytes  # 2 reads + 1 write minimum
        assert cost.hbm_bytes <= 10 * nbytes
