"""Hypothesis property tests on the model-math invariants."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.kernels import ref

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")

dims = st.sampled_from([16, 32, 64])


class TestAttentionProperties:
    @given(dims, st.integers(0, 2**31 - 1))
    def test_output_in_value_hull(self, S, seed):
        """Attention output is a convex combination of V rows: every output
        coordinate lies within [min_k v, max_k v]."""
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (1, 2, S, 8))
        k = jax.random.normal(ks[1], (1, 2, S, 8))
        v = jax.random.normal(ks[2], (1, 2, S, 8))
        out = np.asarray(ref.attention(q, k, v, kind="bidirectional"))
        vmin = np.asarray(v).min(axis=2, keepdims=True)
        vmax = np.asarray(v).max(axis=2, keepdims=True)
        assert (out >= vmin - 1e-4).all() and (out <= vmax + 1e-4).all()

    @given(dims, st.integers(0, 2**31 - 1))
    def test_window_ge_seq_equals_causal(self, S, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, k, v = (jax.random.normal(kk, (1, 2, S, 8)) for kk in ks)
        a = ref.attention(q, k, v, kind="sliding", window=S)
        b = ref.attention(q, k, v, kind="causal")
        np.testing.assert_allclose(a, b, atol=1e-6)

    @given(dims, st.integers(0, 2**31 - 1))
    def test_chunk_ge_seq_equals_causal(self, S, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, k, v = (jax.random.normal(kk, (1, 2, S, 8)) for kk in ks)
        a = ref.attention(q, k, v, kind="chunked", chunk=S)
        b = ref.attention(q, k, v, kind="causal")
        np.testing.assert_allclose(a, b, atol=1e-6)

    @given(st.integers(0, 2**31 - 1))
    def test_first_token_attends_only_itself(self, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, k, v = (jax.random.normal(kk, (1, 1, 8, 4)) for kk in ks)
        out = ref.attention(q, k, v, kind="causal")
        np.testing.assert_allclose(
            np.asarray(out)[:, :, 0], np.asarray(v)[:, :, 0], atol=1e-6
        )

    @given(st.integers(1, 16), st.integers(0, 2**31 - 1))
    def test_decode_respects_lengths(self, L, seed):
        """Cache entries beyond `lengths` must not influence the output."""
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        B, H, Smax, D = 1, 2, 16, 4
        q = jax.random.normal(ks[0], (B, H, D))
        kc = jax.random.normal(ks[1], (B, H, Smax, D))
        vc = jax.random.normal(ks[2], (B, H, Smax, D))
        lengths = jnp.asarray([L], jnp.int32)
        out1 = ref.decode_attention(q, kc, vc, lengths)
        garbage = kc.at[:, :, L:].set(999.0)
        vg = vc.at[:, :, L:].set(-999.0)
        out2 = ref.decode_attention(q, garbage, vg, lengths)
        np.testing.assert_allclose(out1, out2, atol=1e-5)


class TestSSDProperties:
    @given(st.integers(0, 2**31 - 1))
    def test_zero_dt_zero_output(self, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        B, T, H, P, N = 1, 32, 2, 8, 4
        x = jax.random.normal(ks[0], (B, T, H, P))
        dt = jnp.zeros((B, T, H))
        A = -jnp.ones((H,))
        Bm = jax.random.normal(ks[1], (B, T, N))
        Cm = jax.random.normal(ks[2], (B, T, N))
        y = ref.ssd_scan(x, dt, A, Bm, Cm, chunk=16)
        np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)

    @given(st.integers(0, 2**31 - 1))
    def test_linearity_in_x(self, seed):
        """The SSD map is linear in x for fixed (dt, A, B, C)."""
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        B, T, H, P, N = 1, 32, 2, 8, 4
        x1 = jax.random.normal(ks[0], (B, T, H, P))
        x2 = jax.random.normal(ks[1], (B, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[2], (B, T, H))) * 0.1
        A = -jnp.exp(jax.random.normal(ks[3], (H,)) * 0.3)
        Bm = jax.random.normal(ks[4], (B, T, N)) * 0.5
        Cm = jnp.ones((B, T, N)) * 0.5
        f = lambda x: ref.ssd_scan(x, dt, A, Bm, Cm, chunk=16)
        lhs = f(2.0 * x1 + 3.0 * x2)
        rhs = 2.0 * f(x1) + 3.0 * f(x2)
        np.testing.assert_allclose(lhs, rhs, atol=1e-4, rtol=1e-4)

    @given(st.sampled_from([8, 16, 32]), st.integers(0, 2**31 - 1))
    def test_chunk_size_invariance(self, chunk, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        B, T, H, P, N = 1, 64, 2, 8, 4
        x = jax.random.normal(ks[0], (B, T, H, P)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H))) * 0.1
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, T, N)) * 0.5
        Cm = jax.random.normal(ks[4], (B, T, N)) * 0.5
        a = ref.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
        b = ref.ssd_scan(x, dt, A, Bm, Cm, chunk=T)
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


class TestCompressionProperties:
    @given(
        st.floats(1e-4, 1e4),
        st.integers(0, 2**31 - 1),
    )
    def test_quantize_roundtrip_error_bound(self, scale, seed):
        from repro.optim.compression import dequantize, quantize

        x = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * scale
        q, s = quantize(x)
        err = jnp.abs(dequantize(q, s) - x).max()
        # max error <= half a quantization step
        assert float(err) <= float(s) * 0.5 + 1e-9

    @given(st.integers(0, 2**31 - 1))
    def test_quantize_preserves_sign_and_zero(self, seed):
        from repro.optim.compression import dequantize, quantize

        x = jnp.asarray([0.0, 1.0, -1.0, 0.5])
        q, s = quantize(x)
        deq = dequantize(q, s)
        assert float(deq[0]) == 0.0
        assert float(deq[1]) > 0 and float(deq[2]) < 0
