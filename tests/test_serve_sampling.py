"""The sampler layer: in-jit filtering vs the NumPy oracle, seeded
determinism, stop tokens, and the greedy bit-identity anchor.

The contract under test (see ``repro/serve/sampling.py``):

* ``filter_logits`` (traced, per-row params) computes exactly what
  ``filter_logits_ref`` (NumPy float64, one row at a time) specifies —
  same kept set, same scaled values.
* a draw is a function of *(seed, position)* only: admission order, slot
  assignment, and batch composition never change a sampled request's
  tokens (this is what lets preemption/promotion keep token equality).
* ``temperature == 0`` rows take the literal ``argmax`` op — the greedy
  engine's output, bit for bit.
* a matching stop token is still emitted, then the request retires.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_smoke_bundle
from repro.serve import Request, SamplingParams, ServeConfig, Server
from repro.serve.sampling import (
    STOP_WIDTH,
    filter_logits,
    filter_logits_ref,
    hit_stop,
    sample_tokens,
)


def _state(B, *, temp=0.0, top_k=0, top_p=1.0, seed=0, lengths=0):
    """A minimal device state dict for sample_tokens."""
    as_row = lambda v, dt: jnp.full((B,), v, dt) if np.isscalar(v) \
        else jnp.asarray(v, dt)
    return {
        "temp": as_row(temp, jnp.float32),
        "top_k": as_row(top_k, jnp.int32),
        "top_p": as_row(top_p, jnp.float32),
        "seed": as_row(seed, jnp.uint32),
        "lengths": as_row(lengths, jnp.int32),
    }


class TestFilterOracle:
    """jit filter == NumPy oracle across the parameter grid."""

    @pytest.mark.parametrize("temp", [1e-3, 0.5, 1.0, 2.5])
    @pytest.mark.parametrize("top_k", [0, 1, 3, 17, 64, 1000])
    @pytest.mark.parametrize("top_p", [1e-6, 0.3, 0.9, 1.0])
    def test_matches_reference(self, temp, top_k, top_p):
        B, V = 4, 64
        rng = np.random.default_rng(hash((top_k, int(temp * 10))) % 2**32)
        logits = rng.normal(size=(B, V)).astype(np.float32) * 3.0
        t = np.full(B, temp, np.float32)
        k = np.full(B, top_k, np.int32)
        p = np.full(B, top_p, np.float32)
        got = np.asarray(jax.jit(filter_logits)(
            jnp.asarray(logits), jnp.asarray(t), jnp.asarray(k),
            jnp.asarray(p),
        ))
        want = filter_logits_ref(logits, t, k, p)
        # identical kept sets...
        np.testing.assert_array_equal(np.isfinite(got), np.isfinite(want))
        # ...and matching scaled values on the kept entries
        m = np.isfinite(want)
        np.testing.assert_allclose(got[m], want[m], rtol=2e-5, atol=2e-5)

    def test_per_row_params_in_one_batch(self):
        """Rows carry independent params — the traced (B,) path."""
        B, V = 5, 32
        rng = np.random.default_rng(0)
        logits = rng.normal(size=(B, V)).astype(np.float32)
        t = np.asarray([1e-3, 0.7, 1.0, 2.0, 0.9], np.float32)
        k = np.asarray([0, 1, 5, 0, 31], np.int32)
        p = np.asarray([1.0, 0.5, 1.0, 0.2, 0.99], np.float32)
        got = np.asarray(filter_logits(
            jnp.asarray(logits), jnp.asarray(t), jnp.asarray(k),
            jnp.asarray(p),
        ))
        want = filter_logits_ref(logits, t, k, p)
        np.testing.assert_array_equal(np.isfinite(got), np.isfinite(want))

    def test_topk_ties_all_kept(self):
        """Ties at the k-th threshold all survive (>= semantics)."""
        logits = np.asarray([[3.0, 1.0, 3.0, 0.0, 3.0]], np.float32)
        got = np.asarray(filter_logits(
            jnp.asarray(logits),
            jnp.asarray([1.0], np.float32),
            jnp.asarray([1], np.int32),
            jnp.asarray([1.0], np.float32),
        ))
        assert np.isfinite(got[0, [0, 2, 4]]).all()
        assert not np.isfinite(got[0, [1, 3]]).any()

    def test_argmax_always_survives_tiny_top_p(self):
        """top_p -> 0 still keeps the argmax (strictly-before rule)."""
        rng = np.random.default_rng(3)
        logits = rng.normal(size=(3, 40)).astype(np.float32)
        got = np.asarray(filter_logits(
            jnp.asarray(logits),
            jnp.asarray([0.8] * 3, np.float32),
            jnp.asarray([0] * 3, np.int32),
            jnp.asarray([1e-9] * 3, np.float32),
        ))
        assert np.isfinite(got).sum(axis=-1).min() >= 1
        for b in range(3):
            assert np.isfinite(got[b, logits[b].argmax()])


class TestSampleTokens:
    def test_temperature_zero_is_argmax(self):
        rng = np.random.default_rng(1)
        logits = jnp.asarray(rng.normal(size=(6, 50)), jnp.float32)
        toks = sample_tokens(logits, _state(6))
        np.testing.assert_array_equal(
            np.asarray(toks), np.asarray(jnp.argmax(logits, -1))
        )

    def test_top_k_one_is_argmax(self):
        """A categorical over a single surviving token is deterministic:
        the filter+draw path collapses to argmax at top_k=1."""
        rng = np.random.default_rng(2)
        logits = jnp.asarray(rng.normal(size=(4, 30)), jnp.float32)
        toks = sample_tokens(
            logits, _state(4, temp=1.3, top_k=1, seed=[1, 2, 3, 4])
        )
        np.testing.assert_array_equal(
            np.asarray(toks), np.asarray(jnp.argmax(logits, -1))
        )

    def test_draw_depends_only_on_seed_and_position(self):
        """The same (seed, position, logits-row) draws the same token no
        matter where the row sits in the batch or what rides alongside —
        the invariant that makes preemption token-transparent."""
        rng = np.random.default_rng(4)
        row = rng.normal(size=(1, 64)).astype(np.float32)
        noise = rng.normal(size=(3, 64)).astype(np.float32)
        a = sample_tokens(
            jnp.asarray(np.concatenate([row, noise])),
            _state(4, temp=0.9, seed=[7, 1, 2, 3], lengths=[11, 5, 9, 2]),
        )
        b = sample_tokens(
            jnp.asarray(np.concatenate([noise, row])),
            _state(4, temp=0.9, seed=[4, 5, 6, 7], lengths=[8, 1, 3, 11]),
        )
        assert int(a[0]) == int(b[3])
        # and under jit, identically
        c = jax.jit(sample_tokens)(
            jnp.asarray(np.concatenate([row, noise])),
            _state(4, temp=0.9, seed=[7, 1, 2, 3], lengths=[11, 5, 9, 2]),
        )
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    def test_seeds_decorrelate_rows(self):
        """Identical logits rows with different seeds should not all
        draw the same token (temperature high enough to spread mass)."""
        logits = jnp.zeros((16, 256), jnp.float32)  # uniform
        toks = np.asarray(sample_tokens(
            logits, _state(16, temp=1.0, seed=np.arange(16), lengths=3)
        ))
        assert len(set(toks.tolist())) > 1


class TestStopTokens:
    def test_hit_stop_matches_padded_table(self):
        table = jnp.asarray(
            [[5, 9, -1, -1], [2, -1, -1, -1], [-1, -1, -1, -1]], jnp.int32
        )
        got = np.asarray(hit_stop(jnp.asarray([9, 3, 0], jnp.int32), table))
        np.testing.assert_array_equal(got, [True, False, False])

    def test_negative_pad_never_matches(self):
        table = jnp.full((2, STOP_WIDTH), -1, jnp.int32)
        toks = jnp.asarray([0, 7], jnp.int32)
        assert not np.asarray(hit_stop(toks, table)).any()

    def test_server_truncates_at_stop_token_inclusive(self):
        """End to end: the matching stop token is emitted, then the
        request retires — the documented inclusive-stop convention."""
        bundle = get_smoke_bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        cfg = ServeConfig(batch_slots=1, max_len=48)
        prompt = np.arange(1, 9, dtype=np.int32)

        free = Server(bundle, cfg, params)
        ref = Request(rid=0, prompt=prompt, max_new_tokens=10)
        free.add_request(ref)
        free.run_until_done(100)
        stop_tok = ref.out_tokens[3]
        want = ref.out_tokens[: ref.out_tokens.index(stop_tok) + 1]

        srv = Server(bundle, cfg, params)
        req = Request(
            rid=0, prompt=prompt, max_new_tokens=10,
            sampling=SamplingParams(stop_tokens=(stop_tok,)),
        )
        srv.add_request(req)
        srv.run_until_done(100)
        assert req.done
        assert req.out_tokens == want
        assert req.out_tokens[-1] == stop_tok


class TestValidation:
    @pytest.mark.parametrize("bad", [
        dict(temperature=-0.1),
        dict(top_p=0.0),
        dict(top_p=1.5),
        dict(top_k=-1),
        dict(seed=-1),
        dict(seed=2**32),
        dict(stop_tokens=(1, 2, 3, 4, 5)),
        dict(stop_tokens=(-2,)),
    ])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            SamplingParams(**bad).validate()

    def test_stop_row_padding(self):
        row = SamplingParams(stop_tokens=(3, 8)).stop_row()
        np.testing.assert_array_equal(row, [3, 8, -1, -1])


class TestServeGreedyAnchor:
    def test_mixed_sampling_batch_keeps_greedy_rows_bit_identical(self):
        """A greedy request co-batched with sampled requests produces
        exactly the tokens of a solo greedy run: the sampler layer only
        redirects rows with temperature > 0."""
        bundle = get_smoke_bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        cfg = ServeConfig(batch_slots=3, max_len=32)
        prompt = np.arange(1, 7, dtype=np.int32)

        solo = Server(bundle, cfg, params)
        ref = Request(rid=0, prompt=prompt, max_new_tokens=6)
        solo.add_request(ref)
        solo.run_until_done(100)

        mixed = Server(bundle, cfg, params)
        greedy = Request(rid=0, prompt=prompt, max_new_tokens=6)
        mixed.add_requests([
            greedy,
            Request(rid=1, prompt=prompt + 1, max_new_tokens=6,
                    sampling=SamplingParams(temperature=1.1, seed=5)),
            Request(rid=2, prompt=prompt + 2, max_new_tokens=6,
                    sampling=SamplingParams(temperature=0.6, top_k=9,
                                            top_p=0.8, seed=9)),
        ])
        mixed.run_until_done(100)
        assert greedy.out_tokens == ref.out_tokens

    def test_sampled_tokens_invariant_to_admission_order(self):
        """Submission order permutes slot assignment and batch
        composition; sampled rows' tokens must not move (seed+position
        determinism)."""
        bundle = get_smoke_bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        cfg = ServeConfig(batch_slots=2, max_len=32)
        mk = lambda i: Request(
            rid=i, prompt=np.arange(1, 6 + i, dtype=np.int32),
            max_new_tokens=5,
            sampling=SamplingParams(temperature=0.9, top_k=16, seed=40 + i),
        )
        runs = {}
        for order in ((0, 1, 2), (2, 0, 1)):
            srv = Server(bundle, cfg, params)
            reqs = {i: mk(i) for i in order}
            srv.add_requests(reqs.values())
            srv.run_until_done(200)
            runs[order] = {i: r.out_tokens for i, r in reqs.items()}
        assert runs[(0, 1, 2)] == runs[(2, 0, 1)]
