"""Test-collection guards + per-test warn-once reset.

The property-test modules need ``hypothesis`` (see requirements-dev.txt).
When it is absent — e.g. a minimal container image — skip those modules
cleanly at collection instead of erroring the whole run: the tier-1
command must always be able to collect and run everything else.
"""

import importlib.util

import pytest


@pytest.fixture(autouse=True)
def _reset_warn_once_registry():
    """Every warn-once in the repo fires per-*test*, not per-process, so
    warn-once assertions don't depend on test execution order."""
    from repro.analysis.warnings_registry import reset_warnings

    reset_warnings()
    yield

#: test modules whose import requires hypothesis
_HYPOTHESIS_MODULES = [
    "test_datapath.py",
    "test_properties.py",
    "test_sharding.py",
]

collect_ignore = (
    [] if importlib.util.find_spec("hypothesis") else list(_HYPOTHESIS_MODULES)
)
