"""Test-collection guards.

The property-test modules need ``hypothesis`` (see requirements-dev.txt).
When it is absent — e.g. a minimal container image — skip those modules
cleanly at collection instead of erroring the whole run: the tier-1
command must always be able to collect and run everything else.
"""

import importlib.util

#: test modules whose import requires hypothesis
_HYPOTHESIS_MODULES = [
    "test_datapath.py",
    "test_properties.py",
    "test_sharding.py",
]

collect_ignore = (
    [] if importlib.util.find_spec("hypothesis") else list(_HYPOTHESIS_MODULES)
)
