"""Zero-copy serve hot path: chunked prefill, donation, on-device state.

Three contracts from the rework of the Fig. 17 serving loop:

1. **Chunked batched prefill ≡ token-by-token decode replay** — writing a
   prompt in ``prefill_chunk``-sized batched dispatches produces the same
   cache and the same greedy continuation as replaying it through
   full-batch decode steps (exact on f32 caches; one storage-dtype ulp on
   bf16, where f32 summation-order noise may cross a rounding boundary).
2. **Donated caches** — on RESIDENT placements the decode step donates the
   KV cache: the previous cache buffer is consumed (deleted), no second
   cache-sized allocation appears, and the pinned placement survives
   steps.  STREAM placements must not donate.
3. **Host↔device discipline** — uploads hand the device a buffer that is
   never mutated afterwards (the engine's ``_upload``); the equivalence
   harness here does the same, which is itself a regression guard for the
   deferred-upload race this PR fixed.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ArchConfig, AttentionSpec
from repro.core.placement import Role, get_policy
from repro.core.planner import predict, prefill_profile
from repro.kernels import ops
from repro.models import get_smoke_bundle
from repro.models.model_zoo import ModelBundle
from repro.models.sharding import (
    assert_donation_compatible,
    donation_compatible,
)
from repro.serve import Request, ServeConfig, Server

jax.config.update("jax_platform_name", "cpu")


def up(a, dt=np.int32):
    """Race-safe host->device upload: hand over a never-mutated copy."""
    return jnp.asarray(np.array(a, dtype=dt, copy=True))


#: MoE-free MLA config: deepseek-style attention without the router, so
#: chunk-vs-replay equivalence is not confounded by batch-size-dependent
#: expert capacity.  f32 storage -> exact comparisons.
MLA_CONFIG = ArchConfig(
    name="mla-fastpath-test",
    family="dense",
    n_layers=2,
    d_model=48,
    d_ff=64,
    vocab=256,
    layer_pattern="F",
    attention=AttentionSpec(
        n_heads=4, n_kv_heads=4, d_head=24, kind="mla",
        kv_lora=16, rope_head_dim=8, nope_head_dim=16, v_head_dim=16,
    ),
    dtype="float32",
)


def _bundle(arch):
    if arch == "mla":
        return ModelBundle(MLA_CONFIG)
    # f32 storage: on bf16 the f32 summation-order noise of the two
    # dispatch shapes crosses storage-rounding boundaries and cascades
    # through layers, which would test float chaos, not semantics.
    b = get_smoke_bundle(arch)
    return ModelBundle(dataclasses.replace(b.cfg, dtype="float32"))


def _replay(bundle, params, prompts, max_len):
    """Row-isolated token-by-token prefill through full-batch decode steps.

    The full-batch decode dispatch also runs the *idle* rows on padding
    tokens; for KV caches that garbage lands in an overwritable slot, but
    recurrent SSM state would integrate it.  The reference masks each
    step's cache update down to the row actually being replayed, giving
    the clean per-row semantics chunked prefill implements directly.
    """
    B = len(prompts)
    step = jax.jit(lambda p, b, c: bundle.decode_step(p, b, c))
    cache = bundle.init_cache(B, max_len)
    lengths = np.zeros(B, np.int32)
    for i, pr in enumerate(prompts):
        keep = np.zeros(B, bool)
        keep[i] = True
        keep_dev = up(keep, bool)
        for t in range(len(pr) - 1):
            toks = np.zeros((B, 1), np.int32)
            toks[i, 0] = pr[t]
            _, new_cache = step(
                params,
                {"tokens": up(toks), "lengths": up(lengths)},
                cache,
            )
            cache = jax.tree.map(
                lambda n, o: jnp.where(
                    keep_dev.reshape((1, B) + (1,) * (n.ndim - 2)), n, o
                ),
                new_cache, cache,
            )
            lengths[i] += 1
    return cache, lengths


def _chunked(bundle, params, prompts, max_len, chunk):
    """The new path: batched ``prefill_at`` dispatches over prompt chunks."""
    B = len(prompts)
    pf = jax.jit(lambda p, b, c, o: bundle.prefill_at(p, b, c, o))
    cache = bundle.init_cache(B, max_len)
    offs = np.zeros(B, np.int32)
    lens = [len(p) - 1 for p in prompts]
    n_dispatch = 0
    for lo in range(0, max(lens) or 1, chunk):
        toks = np.zeros((B, chunk), np.int32)
        nl = np.zeros(B, np.int32)
        for i, pr in enumerate(prompts):
            n = int(np.clip(lens[i] - lo, 0, chunk))
            if n:
                toks[i, :n] = pr[lo : lo + n]
                nl[i] = n
        if nl.sum() == 0:
            break
        _, cache = pf(
            params,
            {"tokens": up(toks), "new_lens": up(nl)},
            cache,
            up(offs),
        )
        offs += nl
        n_dispatch += 1
    return cache, offs, n_dispatch


class TestChunkedPrefillEquivalence:
    @pytest.mark.parametrize("arch", ["olmo-1b", "mla", "zamba2-1.2b"])
    def test_matches_decode_replay(self, arch):
        bundle = _bundle(arch)
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        rng = np.random.default_rng(0)
        prompts = [
            rng.integers(0, bundle.cfg.vocab, n).astype(np.int32)
            for n in (13, 7, 1)
        ]
        max_len, chunk = 64, 4
        cache_r, lengths = _replay(bundle, params, prompts, max_len)
        cache_c, offs, n_dispatch = _chunked(
            bundle, params, prompts, max_len, chunk
        )
        np.testing.assert_array_equal(lengths, offs)
        # O(L / chunk) dispatches, not O(B * L)
        assert n_dispatch == -(-max(len(p) - 1 for p in prompts) // chunk)

        # cache equality over each row's VALID region.  Replay writes
        # garbage into idle rows at their fill slot (the full-batch decode
        # dispatch touches every row); chunked prefill leaves those slots
        # untouched — so only slots < lengths are contract-covered.
        for path, leaf_r in jax.tree_util.tree_leaves_with_path(cache_r):
            leaf_c = cache_c
            for k in path:
                leaf_c = (
                    leaf_c[k.idx]
                    if hasattr(k, "idx")
                    else leaf_c[k.key]
                )
            name = path[-1].key
            for b, pr in enumerate(prompts):
                L = len(pr) - 1
                if name in ("k", "v"):
                    a = leaf_r[:, b, :, :L]
                    c = leaf_c[:, b, :, :L]
                elif name in ("ckv", "krope"):
                    a = leaf_r[:, b, :L]
                    c = leaf_c[:, b, :L]
                else:          # ssm/conv state carries no seq axis
                    a = leaf_r[:, b]
                    c = leaf_c[:, b]
                a = np.asarray(a, np.float32)
                c = np.asarray(c, np.float32)
                if a.size == 0:       # L == 0 row of a seq-sliced leaf
                    continue
                # scale-aware bound: SSM states of the random-init smoke
                # models reach 1e3 magnitudes, so absolute tolerances are
                # meaningless across leaves
                scale = max(float(np.max(np.abs(a))), 1.0)
                np.testing.assert_allclose(
                    a, c, atol=1e-4 * scale, rtol=1e-4,
                    err_msg=f"{arch} leaf {name} row {b}",
                )

        # greedy continuation from both caches must agree token-for-token
        step = jax.jit(lambda p, b, c: bundle.decode_step(p, b, c))
        last = np.zeros((len(prompts), 1), np.int32)
        for i, pr in enumerate(prompts):
            last[i, 0] = pr[-1]
        toks_r, toks_c = [], []
        tok_r = tok_c = up(last)
        len_r, len_c = up(lengths), up(offs)
        c_r, c_c = cache_r, cache_c
        for _ in range(4):
            lg_r, c_r = step(params, {"tokens": tok_r, "lengths": len_r}, c_r)
            lg_c, c_c = step(params, {"tokens": tok_c, "lengths": len_c}, c_c)
            tok_r = jnp.argmax(lg_r, -1)[:, None].astype(jnp.int32)
            tok_c = jnp.argmax(lg_c, -1)[:, None].astype(jnp.int32)
            len_r, len_c = len_r + 1, len_c + 1
            toks_r.append(np.asarray(tok_r)[:, 0].tolist())
            toks_c.append(np.asarray(tok_c)[:, 0].tolist())
        assert toks_r == toks_c

    def test_f32_cache_equivalence_is_ulp_tight(self):
        """On an f32-storage model the two paths agree to the last few
        ulp.  (Bitwise equality is out of reach on principle: XLA blocks
        the (B,1,D) decode matmuls and the (B,S,D) chunk matmuls
        differently, so f32 reduction order differs — the contract is
        identical *semantics*, float-noise-bounded numerics.)"""
        bundle = _bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(1), "float32")
        rng = np.random.default_rng(1)
        prompts = [
            rng.integers(0, bundle.cfg.vocab, n).astype(np.int32)
            for n in (11, 5)
        ]
        cache_r, lengths = _replay(bundle, params, prompts, 32)
        cache_c, offs, _ = _chunked(bundle, params, prompts, 32, 4)
        for leaf_r, leaf_c in zip(
            jax.tree.leaves(cache_r), jax.tree.leaves(cache_c)
        ):
            for b, pr in enumerate(prompts):
                L = len(pr) - 1
                np.testing.assert_allclose(
                    np.asarray(leaf_r[:, b, :, :L]),
                    np.asarray(leaf_c[:, b, :, :L]),
                    rtol=1e-4, atol=1e-5,
                )

    def test_server_matches_direct_decode_multirow(self):
        """End-to-end: the chunk-prefilling server reproduces per-request
        direct prefill+decode greedy tokens, across slot reuse."""
        bundle = _bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        rng = np.random.default_rng(2)
        prompts = [
            rng.integers(1, bundle.cfg.vocab, n).astype(np.int32)
            for n in (9, 14, 3, 6)
        ]
        server = Server(
            bundle,
            ServeConfig(batch_slots=2, max_len=64, prefill_chunk=4),
            params,
        )
        reqs = [
            Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)
        ]
        server.add_requests(reqs)
        server.run_until_done(max_steps=300)
        for req, prompt in zip(reqs, prompts):
            cache = bundle.init_cache(1, 64)
            logits, cache = bundle.prefill(
                params, {"tokens": jnp.asarray(prompt)[None]}, cache
            )
            lengths = jnp.asarray([len(prompt)], jnp.int32)
            tok = jnp.argmax(logits, -1)[:, None]
            want = [int(tok[0, 0])]
            for _ in range(4):
                logits, cache = bundle.decode_step(
                    params, {"tokens": tok, "lengths": lengths}, cache
                )
                lengths = lengths + 1
                tok = jnp.argmax(logits, -1)[:, None]
                want.append(int(tok[0, 0]))
            assert req.done and req.out_tokens == want, req.rid


class TestPrefillAttentionKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "kind,kw",
        [
            ("causal", {}),
            ("sliding", {"window": 16}),
            ("chunked", {"chunk": 16}),
        ],
    )
    def test_pallas_matches_ref(self, kind, kw, dtype):
        B, Hq, Hkv, Sq, Sk, D = 2, 4, 2, 8, 72, 32
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
        k = jax.random.normal(ks[1], (B, Hkv, Sk, D), dtype)
        v = jax.random.normal(ks[2], (B, Hkv, Sk, D), dtype)
        offs = jnp.asarray([5, 23], jnp.int32)
        q_pos = offs[:, None] + jnp.arange(Sq)[None, :]
        r = jnp.arange(Sk - Sq)[None, :]
        kpos_cache = jnp.where(r < offs[:, None], r, -1)
        # last two chunk entries are per-row padding holes
        kpos_new = jnp.where(jnp.arange(Sq)[None, :] < Sq - 2, q_pos, -1)
        k_pos = jnp.concatenate([kpos_cache, kpos_new], axis=1)
        out = ops.prefill_attention(
            q, k, v, q_pos, k_pos, kind=kind, backend="pallas", **kw
        )
        want = ops.prefill_attention(
            q, k, v, q_pos, k_pos, kind=kind, backend="ref", **kw
        )
        tol = (
            dict(atol=5e-2, rtol=5e-2)
            if dtype == jnp.bfloat16
            else dict(atol=3e-5, rtol=1e-5)
        )
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32), **tol
        )


class TestCacheDonation:
    def _server(self, **cfg):
        bundle = _bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        server = Server(
            bundle, ServeConfig(batch_slots=2, max_len=32, **cfg), params
        )
        server.add_request(Request(
            rid=0, prompt=np.arange(1, 7, dtype=np.int32), max_new_tokens=10
        ))
        return server

    def test_decode_step_donates_cache(self):
        """Default (resident) policy: each step consumes the previous
        cache buffer — no second cache-sized allocation ever exists."""
        server = self._server()
        assert server.engine.donates_cache
        server.step()
        cache_nbytes = {
            leaf.nbytes for leaf in jax.tree.leaves(server.engine.caches)
        }

        def live_cache_arrays():
            return [
                a for a in jax.live_arrays()
                if not a.is_deleted() and a.nbytes in cache_nbytes
            ]

        before = len(live_cache_arrays())
        old_leaves = jax.tree.leaves(server.engine.caches)
        shardings = [leaf.sharding for leaf in old_leaves]
        for _ in range(3):
            server.step()
        # donation consumed the old buffers outright
        assert all(leaf.is_deleted() for leaf in old_leaves)
        # and the population of cache-sized buffers did not grow: the
        # steady state holds exactly one live copy of the cache
        jax.block_until_ready(jax.tree.leaves(server.engine.caches))
        assert len(live_cache_arrays()) <= before
        # placements hold across steps
        for leaf, sh in zip(jax.tree.leaves(server.engine.caches), shardings):
            assert leaf.sharding == sh
            assert leaf.sharding.memory_kind == sh.memory_kind

    def test_stream_policy_keeps_cache_undonated(self):
        """kv_host streams the cache: the resident buffer must survive
        the step (it is the source of the next migration)."""
        server = self._server(policy=get_policy("kv_host"))
        assert not server.engine.donates_cache
        server.step()
        old_leaves = jax.tree.leaves(server.engine.caches)
        server.step()
        assert not any(leaf.is_deleted() for leaf in old_leaves)

    def test_donation_compatibility_helper(self):
        assert donation_compatible(get_policy("hbm_resident"), Role.KV_CACHE)
        assert donation_compatible(get_policy("kv_peer_hbm"), Role.KV_CACHE)
        assert not donation_compatible(get_policy("kv_host"), Role.KV_CACHE)
        assert not donation_compatible(
            get_policy("weights_stream"), Role.PARAMS
        )
        assert_donation_compatible(get_policy("hbm_resident"), Role.KV_CACHE)
        with pytest.raises(ValueError, match="undonated"):
            assert_donation_compatible(get_policy("kv_host"), Role.KV_CACHE)


class TestRequestValidation:
    def _server(self):
        bundle = _bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        return Server(
            bundle, ServeConfig(batch_slots=1, max_len=16), params
        )

    def test_duplicate_rid_rejected(self):
        server = self._server()
        server.add_request(Request(
            rid=7, prompt=np.arange(1, 4, dtype=np.int32), max_new_tokens=2
        ))
        with pytest.raises(ValueError, match="unique"):
            server.add_request(Request(
                rid=7, prompt=np.arange(1, 4, dtype=np.int32),
                max_new_tokens=2,
            ))
        assert server.queue_depth == 1

    def test_rid_reusable_after_completion(self):
        """Finished rids are evicted from the request table: reuse is
        legal and the table stays bounded by live requests."""
        server = self._server()
        for round_ in range(3):
            req = Request(
                rid=7, prompt=np.arange(1, 5, dtype=np.int32),
                max_new_tokens=2,
            )
            server.add_request(req)
            server.run_until_done(max_steps=100)
            assert req.done, round_
            assert not server.live_rids   # table holds live requests only

    def test_negative_rid_rejected(self):
        server = self._server()
        with pytest.raises(ValueError, match=">= 0"):
            server.add_request(Request(
                rid=-1, prompt=np.arange(1, 4, dtype=np.int32),
                max_new_tokens=2,
            ))

    def test_nonpositive_max_new_tokens_rejected(self):
        server = self._server()
        for bad in (0, -3):
            with pytest.raises(ValueError, match="max_new_tokens"):
                server.add_request(Request(
                    rid=1, prompt=np.arange(1, 4, dtype=np.int32),
                    max_new_tokens=bad,
                ))
        assert not server.has_work()


class TestRecurrentStateReset:
    def test_single_token_prompt_after_slot_reuse_matches_fresh(self):
        """A 1-token prompt (zero prefill tokens) must still reset the
        slot's recurrent SSM state: the admission dispatch runs even with
        nothing to write, zeroing offsets==0 rows.  Without it, the new
        request decodes on the previous occupant's accumulated state."""
        bundle = _bundle("mamba2-780m")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        prompt1 = np.asarray([5], np.int32)

        def serve(server, rid, prompt, n):
            req = Request(rid=rid, prompt=prompt, max_new_tokens=n)
            server.add_request(req)
            server.run_until_done(max_steps=200)
            return req.out_tokens

        cfg = ServeConfig(batch_slots=1, max_len=32, prefill_chunk=4)
        dirty = Server(bundle, cfg, params)
        # occupy and free the slot, leaving residual recurrent state
        serve(dirty, 0, np.arange(1, 9, dtype=np.int32), 6)
        got = serve(dirty, 1, prompt1, 5)
        fresh = Server(bundle, cfg, params)
        want = serve(fresh, 0, prompt1, 5)
        assert got == want


class TestPrefillPlanning:
    def test_prefill_profile_accounts_cache_and_activations(self):
        prof = prefill_profile(
            name="p", param_bytes=2e9, kv_bytes=1e9,
            chunk_flops=1e12, activation_bytes=1e8,
        )
        pred = predict(prof, get_policy("hbm_resident"))
        assert pred.step_s > 0 and pred.fits
        # KV behind the host link must surface as PCIe/stream time
        pred_host = predict(prof, get_policy("kv_host"))
        assert pred_host.pcie_s > 0

    def test_bundle_prefill_workload(self):
        from repro.configs import ShapeSpec

        bundle = get_smoke_bundle("olmo-1b")
        shape = ShapeSpec("serve", 64, 4, "decode")
        prof = bundle.prefill_workload(shape, chunk_tokens=16)
        dec = bundle.decode_workload(shape)
        # a chunk ingests 16 tokens/row vs decode's 1 -> more flops
        assert prof.flops > dec.flops
        assert prof.bytes_per_role[Role.KV_CACHE] == \
            dec.bytes_per_role[Role.KV_CACHE]

    def test_runtime_serve_plan_smoke(self):
        from repro.api import Runtime

        bundle = get_smoke_bundle("olmo-1b")
        rt = Runtime.auto(
            bundle, None, phase="serve",
            batch_slots=2, max_len=32, prefill_chunk=8,
        )
        # with no mesh nothing is re-placeable: the pick must be the
        # default placement, and the explain table must surface it
        assert rt.policy.name == "hbm_resident"
        assert "hbm_resident" in rt.explain("serve")
