"""Multi-device distribution tests.

These need >1 XLA host device, and the device count must NOT be forced
globally (smoke tests/benches see 1 device) — so each test runs a small
script in a subprocess with ``--xla_force_host_platform_device_count=8``.
The scripts assert internally; the test checks the exit code.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n: int = 8, timeout: int = 600):
    script = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"\n'
        + textwrap.dedent(body)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


class TestQuantizedAllReduce:
    def test_matches_mean_within_quantization(self):
        run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import quantized_all_reduce
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        f = shard_map(lambda v: quantized_all_reduce(v[0], "pod")[None],
                      mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                      check_rep=False)
        out = f(x)
        want = jnp.mean(x, axis=0)
        for row in np.asarray(out):
            np.testing.assert_allclose(row, np.asarray(want), atol=3e-2)
        print("OK")
        """)

    def test_error_feedback_reduces_bias(self):
        run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.optim.compression import quantize, dequantize
        # error feedback: accumulated quantization error is re-injected; the
        # RUNNING SUM of compressed values tracks the running sum of true
        # values much better than independent quantization.
        rng = np.random.default_rng(0)
        g = rng.normal(size=(100, 64)).astype(np.float32) * 0.01
        g[:, 0] += 5.0  # large coordinate dominates the scale
        ef = np.zeros(64, np.float32)
        sum_q_ef, sum_q_naive, sum_true = 0.0, 0.0, 0.0
        for t in range(100):
            q, s = quantize(jnp.asarray(g[t] + ef))
            deq = np.asarray(dequantize(q, s))
            ef = g[t] + ef - deq
            sum_q_ef += deq
            qn, sn = quantize(jnp.asarray(g[t]))
            sum_q_naive += np.asarray(dequantize(qn, sn))
            sum_true += g[t]
        err_ef = np.abs(sum_q_ef - sum_true).max()
        err_naive = np.abs(sum_q_naive - sum_true).max()
        assert err_ef <= err_naive + 1e-6, (err_ef, err_naive)
        assert err_ef < 0.1
        print("OK", err_ef, err_naive)
        """)


class TestPipelineParallel:
    def test_matches_sequential(self):
        run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline_parallel import pipelined_forward
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4,), ("pod",))
        n_stages, n_micro, B, D = 4, 8, 2, 16
        ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, D, D)) * 0.3
        xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, B, D))
        def stage_fn(w, x):
            return jnp.tanh(x @ w)
        # sequential reference
        def seq(x):
            for i in range(n_stages):
                x = stage_fn(ws[i], x)
            return x
        want = jax.vmap(seq)(xs)
        got = pipelined_forward(mesh, stage_fn, ws, xs, axis_name="pod")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        print("OK")
        """)

    def test_differentiable(self):
        run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline_parallel import pipelined_forward
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2,), ("pod",))
        ws = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8)) * 0.3
        xs = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 8))
        def stage_fn(w, x):
            return jnp.tanh(x @ w)
        def loss_pipe(ws):
            return jnp.sum(pipelined_forward(mesh, stage_fn, ws, xs, "pod") ** 2)
        def loss_seq(ws):
            def seq(x):
                for i in range(2):
                    x = jnp.tanh(x @ ws[i])
                return x
            return jnp.sum(jax.vmap(seq)(xs) ** 2)
        g1 = jax.grad(loss_pipe)(ws)
        g2 = jax.grad(loss_seq)(ws)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)
        print("OK")
        """)


class TestParallelConsistency:
    def test_sharded_train_matches_single_device(self):
        """The same train step on a (2,2,2) mesh and on a 1-device mesh
        produces the same loss trajectory — the distribution layer is
        numerically transparent."""
        run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import get_smoke_bundle
        from repro.train import TrainConfig, init_train_state, make_train_step
        from repro.optim import AdamWConfig
        from repro.data import DataConfig, SyntheticLM

        def run(mesh_dims, axes):
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat(mesh_dims, axes)
            b = get_smoke_bundle("granite-8b")
            tcfg = TrainConfig(remat="none",
                optimizer=AdamWConfig(lr=1e-3, warmup_steps=1))
            params, opt, ef = init_train_state(b, mesh, jax.random.PRNGKey(0), tcfg)
            step = jax.jit(make_train_step(b, mesh, tcfg))
            data = SyntheticLM(DataConfig(vocab=b.cfg.vocab, seq_len=32,
                                          global_batch=8))
            losses = []
            for i, batch in zip(range(4), data):
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, ef, m = step(params, opt, ef, batch)
                losses.append(float(m["loss"]))
            return losses
        l_multi = run((2, 2, 2), ("pod", "data", "model"))
        l_single = run((1,), ("data",))
        np.testing.assert_allclose(l_multi, l_single, rtol=2e-3, atol=2e-3)
        print("OK", l_multi, l_single)
        """)

    def test_compressed_pod_grads_still_learns(self):
        run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.models import get_smoke_bundle
        from repro.train import TrainConfig, init_train_state, make_train_step
        from repro.optim import AdamWConfig
        from repro.data import DataConfig, SyntheticLM
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
        b = get_smoke_bundle("olmo-1b")
        tcfg = TrainConfig(remat="none", compress_pod_grads=True,
            optimizer=AdamWConfig(lr=3e-3, warmup_steps=5, weight_decay=0.0))
        params, opt, ef = init_train_state(b, mesh, jax.random.PRNGKey(0), tcfg)
        step = jax.jit(make_train_step(b, mesh, tcfg))
        data = SyntheticLM(DataConfig(vocab=b.cfg.vocab, seq_len=32,
                                      global_batch=8, structure=1.0))
        losses = []
        for i, batch in zip(range(30), data):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, ef, m = step(params, opt, ef, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])
        print("OK", losses[0], losses[-1])
        """, timeout=900)


class TestDonorMeshRealization:
    """Peer/remote placement policies executed on a donor mesh axis: the
    bytes must land sharded across the donor slices (sharding + memory
    kind asserted), survive decode steps, and the planner's pick under a
    donor mesh must be the policy the engine then realizes."""

    def test_kv_peer_hbm_realized_on_donor_slice(self):
        run_with_devices("""
        import jax, numpy as np
        from repro.core.placement import resolve_memory_kind
        from repro.launch.mesh import make_donor_mesh
        from repro.models import get_smoke_bundle
        from repro.serve import Request, ServeConfig, Server

        mesh = make_donor_mesh((2,), ("data",), 2)   # (donor=2, data=2)
        b = get_smoke_bundle("olmo-1b")
        params = b.init_params(jax.random.PRNGKey(0), "float32")
        srv = Server(
            b,
            ServeConfig(batch_slots=4, max_len=32, policy="kv_peer_hbm"),
            params, mesh=mesh,
        )
        donor_devs = set(mesh.devices[1].ravel())  # donor slice 1
        want_kind = resolve_memory_kind("device") or \\
            jax.devices()[0].default_memory().kind
        from repro.models.sharding import spec_axes

        for leaf in jax.tree.leaves(srv.engine.caches):
            assert "donor" in spec_axes(leaf.sharding.spec), leaf.sharding
            assert leaf.sharding.memory_kind == want_kind, leaf.sharding
            devs = {s.device for s in leaf.addressable_shards}
            assert devs & donor_devs, (devs, donor_devs)
        # params stay local under kv_peer_hbm
        for leaf in jax.tree.leaves(srv.params):
            assert "donor" not in spec_axes(leaf.sharding.spec)
        # serving works and the placement survives the decode steps
        req = Request(rid=0, prompt=np.arange(1, 6, dtype=np.int32),
                      max_new_tokens=3)
        srv.add_request(req)
        srv.run_until_done(200)
        assert req.done
        for leaf in jax.tree.leaves(srv.engine.caches):
            assert "donor" in spec_axes(leaf.sharding.spec), leaf.sharding
        print("OK")
        """)

    def test_weights_peer_hbm_and_donor_stream(self):
        run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.placement import DonorStream
        from repro.launch.mesh import make_donor_mesh
        from repro.models import get_smoke_bundle
        from repro.serve import Request, ServeConfig, Server

        mesh = make_donor_mesh((2,), ("data",), 2)
        b = get_smoke_bundle("olmo-1b")
        params = b.init_params(jax.random.PRNGKey(0), "float32")
        srv = Server(
            b,
            ServeConfig(batch_slots=4, max_len=32,
                        policy="weights_peer_hbm"),
            params, mesh=mesh,
        )
        from repro.models.sharding import spec_axes
        donor_devs = set(mesh.devices[1].ravel())
        sharded = 0
        for leaf in jax.tree.leaves(srv.params):
            if "donor" in spec_axes(leaf.sharding.spec):
                sharded += 1
                assert {s.device for s in leaf.addressable_shards} & donor_devs
        assert sharded > 0, "no param leaf landed on the donor axis"
        req = Request(rid=0, prompt=np.arange(1, 6, dtype=np.int32),
                      max_new_tokens=2)
        srv.add_request(req)
        srv.run_until_done(200)
        assert req.done

        # Runtime.realize (the array-level realizer): a def-less stacked
        # tree under a STREAM peer placement lands donor-sharded on its
        # stack dim
        from repro.api import Runtime
        from repro.core.placement import Role
        from repro.models.sharding import spec_axes
        n, m = 6, 128
        stacked = jnp.arange(n * m, dtype=jnp.float32).reshape(n, m)
        placed = Runtime(b, mesh, "weights_peer_hbm").realize(
            {"w": stacked}, Role.PARAMS, specs=P()
        )
        assert spec_axes(placed["w"].sharding.spec) == {"donor"}
        assert {s.device for s in placed["w"].addressable_shards} & donor_devs

        # DonorStream: windows arrive locally, match the source, and the
        # staging buffer never holds more than the double buffer
        stack = jax.device_put(
            jnp.arange(n * m, dtype=jnp.float32).reshape(n, m),
            NamedSharding(mesh, P("donor")),
        )
        stream = DonorStream(stack, mesh, P(), n)
        for i in range(n):
            w = stream.window(i)
            np.testing.assert_array_equal(
                np.asarray(w), np.asarray(stack[i]))
            assert "donor" not in spec_axes(w.sharding.spec)  # staged locally
            assert len(stream._buf) <= 2           # double-buffered
        print("OK")
        """)

    def test_planner_pick_under_donor_mesh_is_realized(self):
        run_with_devices("""
        import jax, numpy as np
        from repro.core.placement import donor_allow_flags
        from repro.core.planner import plan
        from repro.launch.mesh import make_donor_mesh
        from repro.models import get_smoke_bundle
        from repro.serve import Request, ServeConfig, Server

        mesh = make_donor_mesh((2,), ("data",), 2)
        # an oversized-KV decode profile: only a peer tier both fits and
        # is realizable (host tiers don't exist on the CPU backend)
        from repro.core.planner import decode_profile, pool_capacities
        caps = pool_capacities()
        prof = decode_profile(
            name="big", param_bytes=2e9,
            kv_bytes=caps["hbm"], step_flops=1e12)
        flags = donor_allow_flags(mesh)
        flags["allow_host"] = False
        best, _ = plan(prof, **flags)
        assert best.policy in ("kv_peer_hbm", "weights_peer_hbm"), best
        # the engine realizes exactly that policy on the donor slice
        b = get_smoke_bundle("olmo-1b")
        params = b.init_params(jax.random.PRNGKey(0), "float32")
        srv = Server(
            b, ServeConfig(batch_slots=4, max_len=32, policy=best.policy),
            params, mesh=mesh)
        from repro.models.sharding import spec_axes
        donor_devs = set(mesh.devices[1].ravel())
        role_tree = (srv.engine.caches if best.policy == "kv_peer_hbm"
                     else srv.params)
        hit = 0
        for leaf in jax.tree.leaves(role_tree):
            if "donor" in spec_axes(leaf.sharding.spec):
                hit += 1
                assert {s.device for s in leaf.addressable_shards} & donor_devs
        assert hit > 0
        print("OK")
        """)


class TestPlacementPolicies:
    def test_opt_host_offload_runs_and_matches(self):
        run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import get_smoke_bundle
        from repro.core.placement import (
            OPT_HOST, HBM_RESIDENT, default_memory_kind, resolve_memory_kind)
        from repro.train import TrainConfig, init_train_state, make_train_step
        from repro.optim import AdamWConfig
        from repro.data import DataConfig, SyntheticLM
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 2), ("data", "model"))
        b = get_smoke_bundle("yi-6b")
        from repro.train.train_step import make_state_specs, repin_opt_state

        def run(policy):
            tcfg = TrainConfig(remat="none",
                optimizer=AdamWConfig(lr=1e-3, warmup_steps=1))
            params, opt, ef = init_train_state(
                b, mesh, jax.random.PRNGKey(0), tcfg, policy)
            _, opt_specs = make_state_specs(b, mesh, policy, tcfg.rules,
                                            tcfg.fsdp_axes)
            # the host kind the backend actually exposes (pinned_host on
            # TPU; the default kind on CPU where host DRAM == device mem)
            host_kind = resolve_memory_kind("pinned_host") or default_memory_kind()
            if policy.name == "opt_host":
                kinds = {x.sharding.memory_kind
                         for x in jax.tree.leaves(opt["master"])}
                assert kinds == {host_kind}, (kinds, host_kind)
            step = jax.jit(make_train_step(b, mesh, tcfg, policy))
            data = SyntheticLM(DataConfig(vocab=b.cfg.vocab, seq_len=16,
                                          global_batch=4))
            out = []
            for i, batch in zip(range(3), data):
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, ef, m = step(params, opt, ef, batch)
                # CPU backend: host re-pin happens outside jit
                opt = repin_opt_state(opt, opt_specs)
                out.append(float(m["loss"]))
            if policy.name == "opt_host":
                kinds = {x.sharding.memory_kind
                         for x in jax.tree.leaves(opt["master"])}
                assert kinds == {host_kind}, (kinds, host_kind)
            return out
        np.testing.assert_allclose(run(HBM_RESIDENT), run(OPT_HOST),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
        """)
