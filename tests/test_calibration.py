"""The calibrated hardware model: provenance-tracked constants, the
active-system registry, measurement fits, replay validation, and the
drift gate.

The invariant under test throughout: calibration changes *pricing only*.
A calibrated system re-prices every planner/datapath decision, but the
spec-sheet baseline (``repro.api.SPEC_SYSTEM``) is immutable, and
nothing here touches computed values (the serve-layer tests assert the
greedy-token side of that).
"""

import json
import warnings

import pytest

from repro.api import SPEC_SYSTEM
from repro.core.hardware import (
    AXIS_LINK,
    CALIBRATED_TERMS,
    Link,
    SystemSpec,
    axis_bandwidth,
    get_active_system,
    link_for_axis,
    set_active_system,
)
from repro.core.membench import Measurement, linear_fit
from repro.core.replay import ReplayLog


def _meas(nbytes, mean_s, name="m"):
    return Measurement(name=name, mean_s=mean_s, min_s=mean_s,
                       max_s=mean_s, std_s=0.0, repeats=1, nbytes=nbytes)


class TestProvenance:
    def test_every_term_defaults_to_spec(self):
        sys_ = SystemSpec()
        for term in CALIBRATED_TERMS:
            assert sys_.provenance_of(term) == "spec", term
            assert sys_.term_value(term) > 0

    def test_with_measurements_marks_measured(self):
        base = SystemSpec()
        cal = base.with_measurements(hbm_bandwidth=100e9, hbm_latency=2e-6)
        assert cal.provenance_of("hbm_bandwidth") == "measured"
        assert cal.provenance_of("hbm_latency") == "measured"
        assert cal.term_value("hbm_bandwidth") == 100e9
        assert cal.chip.hbm_bandwidth == 100e9
        # untouched terms keep spec provenance and spec values
        assert cal.provenance_of("ici_link_bandwidth") == "spec"
        assert cal.term_value("pcie_bandwidth") == base.term_value(
            "pcie_bandwidth")

    def test_with_overrides_marks_override(self):
        cal = SystemSpec().with_overrides(dcn_bandwidth=5e9)
        assert cal.provenance_of("dcn_bandwidth") == "override"
        assert cal.term_value("dcn_bandwidth") == 5e9

    def test_original_system_is_untouched(self):
        base = SystemSpec()
        before = base.term_value("hbm_bandwidth")
        base.with_measurements(hbm_bandwidth=1e9)
        assert base.term_value("hbm_bandwidth") == before
        assert base.provenance_of("hbm_bandwidth") == "spec"

    def test_unknown_term_raises_keyerror(self):
        with pytest.raises(KeyError, match="unknown calibratable term"):
            SystemSpec().with_measurements(warp_core_bandwidth=1.0)
        with pytest.raises(KeyError):
            SystemSpec().provenance_of("warp_core_bandwidth")

    def test_non_positive_measurement_raises(self):
        with pytest.raises(ValueError):
            SystemSpec().with_measurements(hbm_bandwidth=0.0)
        with pytest.raises(ValueError):
            SystemSpec().with_measurements(hbm_latency=-1e-6)

    def test_chained_derivations_accumulate(self):
        cal = (SystemSpec()
               .with_measurements(hbm_bandwidth=100e9)
               .with_overrides(ici_link_bandwidth=10e9))
        assert cal.provenance_of("hbm_bandwidth") == "measured"
        assert cal.provenance_of("ici_link_bandwidth") == "override"
        assert cal.term_value("hbm_bandwidth") == 100e9

    def test_describe_terms_covers_every_term(self):
        desc = SystemSpec().describe_terms()
        assert set(desc) == set(CALIBRATED_TERMS)
        for term, d in desc.items():
            assert d["provenance"] == "spec"
            assert d["value"] > 0


class TestActiveSystemRegistry:
    def test_default_active_system_is_the_spec_sheet(self):
        assert get_active_system() is SPEC_SYSTEM

    def test_set_returns_previous_and_installs(self):
        cal = SPEC_SYSTEM.with_measurements(hbm_bandwidth=50e9)
        prev = set_active_system(cal)
        try:
            assert prev is SPEC_SYSTEM
            assert get_active_system() is cal
        finally:
            set_active_system(prev)
        assert get_active_system() is SPEC_SYSTEM

    def test_set_rejects_non_system(self):
        with pytest.raises(TypeError):
            set_active_system("819GB/s")

    def test_datapath_defaults_resolve_to_active_system(self):
        """A None system resolves at call time, so activating a
        calibrated system re-prices module-level helpers."""
        from repro.core.datapath import read_bound
        from repro.core.hardware import MemoryTier

        base = read_bound(MemoryTier.HBM).bandwidth
        prev = set_active_system(
            SPEC_SYSTEM.with_measurements(hbm_bandwidth=1e9))
        try:
            slow = read_bound(MemoryTier.HBM).bandwidth
        finally:
            set_active_system(prev)
        assert slow == 1e9 and base > slow * 10


class TestAxisLinks:
    def test_donor_axes_are_mapped(self):
        assert AXIS_LINK["donor"] == Link.ICI
        assert AXIS_LINK["donor_pod"] == Link.DCN
        assert link_for_axis("donor") == Link.ICI
        assert link_for_axis("donor_pod") == Link.DCN

    def test_unknown_axis_warns_once_then_falls_back_to_ici(self):
        with pytest.warns(UserWarning, match="no AXIS_LINK entry"):
            assert link_for_axis("zz_mystery_axis") == Link.ICI
        # warn-once: the second lookup is silent
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert link_for_axis("zz_mystery_axis") == Link.ICI

    def test_unknown_axis_raises_when_strict(self):
        with pytest.raises(KeyError, match="zz_other_axis"):
            link_for_axis("zz_other_axis", strict=True)

    def test_axis_bandwidth_prices_under_given_system(self):
        cal = SPEC_SYSTEM.with_measurements(dcn_bandwidth=7e9)
        assert axis_bandwidth("pod", cal) == 7e9
        assert axis_bandwidth("data") == SPEC_SYSTEM.link_bandwidth(Link.ICI)


class TestLinearFit:
    def test_recovers_latency_and_bandwidth(self):
        lat, bw = 5e-6, 200e9
        pts = [_meas(n, lat + n / bw) for n in (2**16, 2**20, 2**24)]
        fit_lat, fit_bw = linear_fit(pts)
        assert fit_lat == pytest.approx(lat, rel=1e-6)
        assert fit_bw == pytest.approx(bw, rel=1e-6)

    def test_single_point_falls_back_to_effective_bandwidth(self):
        m = _meas(2**20, 1e-3)
        lat, bw = linear_fit([m])
        assert lat == 0.0
        assert bw == pytest.approx(m.bandwidth)

    def test_negative_intercept_clamped_to_zero(self):
        # noisy sweep whose least-squares intercept would be negative
        pts = [_meas(2**16, 1e-6), _meas(2**20, 6e-5)]
        lat, bw = linear_fit(pts)
        assert lat >= 0.0 and bw > 0


class TestReplayLog:
    def _log(self):
        log = ReplayLog()
        log.record("hbm_bandwidth", "read[1MB]", 1e-3, 2e-3,
                   nbytes=2**20, limiting_link="hbm", source="test")
        log.record("hbm_bandwidth", "read[16MB]", 1.0e-2, 1.1e-2,
                   nbytes=2**24, limiting_link="hbm", source="test")
        log.record("dcn_bandwidth", "permute[1MB]", 1e-3, 1e-3,
                   nbytes=2**20, limiting_link="dcn", source="test")
        return log

    def test_rel_error_and_per_term_aggregates(self):
        errs = self._log().per_term_error()
        hbm = errs["hbm_bandwidth"]
        assert hbm.count == 2
        assert hbm.mean_rel_error == pytest.approx((0.5 + 0.1 / 1.1) / 2)
        assert hbm.max_rel_error == pytest.approx(0.5)
        assert hbm.worst_name == "read[1MB]"
        assert errs["dcn_bandwidth"].mean_rel_error == pytest.approx(0.0)

    def test_gate_passes_and_fails(self):
        log = self._log()
        assert log.gate(1.0) == []
        violations = log.gate(0.2)
        assert len(violations) == 1
        assert "hbm_bandwidth" in violations[0]
        # per-term override tightens just one term
        assert len(log.gate(1.0, {"dcn_bandwidth": 0.0})) == 0
        assert len(log.gate(1.0, {"hbm_bandwidth": 0.1})) == 1

    def test_non_positive_measurements_are_dropped(self):
        log = ReplayLog()
        log.record("t", "bad", 1e-3, 0.0)
        assert len(log) == 0

    def test_json_round_trip_preserves_aggregates(self):
        log = self._log()
        back = ReplayLog.from_json(log.to_json())
        assert len(back) == len(log)
        for term, err in log.per_term_error().items():
            b = back.per_term_error()[term]
            assert b.count == err.count
            assert b.mean_rel_error == pytest.approx(err.mean_rel_error)
            assert b.worst_name == err.worst_name

    def test_record_cap_keeps_aggregates_exact(self):
        log = ReplayLog()
        n = 300     # past the per-term verbatim cap
        for i in range(n):
            log.record("t", f"r{i}", 2.0, 1.0)
        err = log.per_term_error()["t"]
        assert err.count == n
        assert err.mean_rel_error == pytest.approx(1.0)
        assert len(log.records("t")) < n


class TestCalibrationObject:
    def _cal(self):
        from repro.core.calibration import Calibration, TermCalibration

        cal = Calibration(backend="cpu", num_devices=1,
                          created="2026-08-08T00:00:00")
        cal.terms["hbm_bandwidth"] = TermCalibration(
            term="hbm_bandwidth", spec=819e9, measured=12e9,
            unit="B/s", source="read_sweep")
        cal.replay.record("hbm_bandwidth", "read[1MB]", 1e-4, 1.2e-4,
                          nbytes=2**20, limiting_link="hbm",
                          source="calibrate")
        return cal

    def test_apply_rewrites_terms_with_measured_provenance(self):
        calibrated = self._cal().apply(SPEC_SYSTEM)
        assert calibrated.term_value("hbm_bandwidth") == 12e9
        assert calibrated.provenance_of("hbm_bandwidth") == "measured"
        assert calibrated.provenance_of("ici_link_bandwidth") == "spec"

    def test_json_round_trip(self, tmp_path):
        from repro.core.calibration import Calibration

        path = self._cal().save(tmp_path / "calibration.json")
        obj = json.loads(path.read_text())
        assert obj["format_version"] == 1
        assert obj["provenance"] == {"hbm_bandwidth": "measured"}
        back = Calibration.load(path)
        assert back.backend == "cpu"
        assert back.terms["hbm_bandwidth"].measured == 12e9
        assert back.terms["hbm_bandwidth"].ratio == pytest.approx(
            12e9 / 819e9)
        assert len(back.replay) == 1

    def test_newer_format_is_rejected(self):
        from repro.core.calibration import Calibration

        with pytest.raises(ValueError, match="newer"):
            Calibration.from_json({"format_version": 99})

    def test_summary_names_uncalibrated_terms(self):
        text = self._cal().summary()
        assert "hbm_bandwidth" in text
        assert "spec provenance kept" in text
        assert "ici_link_bandwidth" in text


class TestCalibrateEndToEnd:
    """A real (tiny) calibration run on this host's devices."""

    @pytest.fixture(scope="class")
    def cal(self):
        from repro.core.calibration import calibrate

        return calibrate(sizes=(2**14, 2**17), repeats=2)

    def test_measures_hbm_and_replays(self, cal):
        assert "hbm_bandwidth" in cal.terms
        assert cal.terms["hbm_bandwidth"].measured > 0
        assert len(cal.replay) > 0
        assert "hbm_bandwidth" in cal.replay.per_term_error()

    def test_calibration_moves_planner_predictions(self, cal):
        """The acceptance criterion: the planner prices differently under
        the calibrated system than under the spec sheet (a CPU host is
        nowhere near 819 GB/s of HBM bandwidth)."""
        from repro.core.planner import predict, train_profile
        from repro.core.placement import get_policy

        prof = train_profile(
            name="cal-test", param_bytes=2 * 1e9, step_flops=6e12,
            activation_bytes=2**28, num_chips=4,
            data_axis_size=4, pod_axis_size=1,
        )
        policy = get_policy("hbm_resident")
        spec_pred = predict(prof, policy, SPEC_SYSTEM)
        cal_pred = predict(prof, policy, cal.apply(SPEC_SYSTEM))
        assert cal_pred.step_s != spec_pred.step_s
        assert cal_pred.step_s > spec_pred.step_s  # slower than the sheet

    def test_load_or_calibrate_round_trip(self, cal, tmp_path):
        from repro.core.calibration import Calibration, load_or_calibrate

        path = tmp_path / "calibration.json"
        cal.save(path)
        loaded = load_or_calibrate(path)
        assert isinstance(loaded, Calibration)
        assert set(loaded.terms) == set(cal.terms)
        # loading must not have touched the active system
        assert get_active_system() is SPEC_SYSTEM


class TestRuntimeCalibrate:
    def test_runtime_calibrate_writes_json_and_reprices(self, tmp_path):
        from repro.api import Runtime
        from repro.models import get_smoke_bundle

        bundle = get_smoke_bundle("olmo-1b")
        rt = Runtime(bundle)
        assert rt.system is SPEC_SYSTEM
        analytic = rt.decode_step_seconds(2, 32)

        path = tmp_path / "calibration.json"
        cal = rt.calibrate(path, activate=False,
                           sizes=(2**14, 2**17), repeats=2)
        assert path.exists(), "calibrate() must persist calibration.json"
        assert rt.calibration is cal
        assert rt.system.provenance_of("hbm_bandwidth") == "measured"
        # activate=False leaves the process-wide system alone
        assert get_active_system() is SPEC_SYSTEM
        # cached analytic estimates were dropped and re-priced
        assert rt.decode_step_seconds(2, 32) != analytic
        # calibration replay records flowed into the runtime's log
        assert len(rt.replay) > 0
