"""Lint framework tests: rules, pragmas, allowlists, registry — plus the
shared warn-once registry (repro.analysis.warnings_registry).

Pattern-rule fixtures assemble their trigger strings at runtime so this
file does not trip the repo-wide gate (tools/audit.py lints tests/ too).
"""

import textwrap
import warnings

import pytest

from repro.analysis import lint
from repro.analysis.warnings_registry import (
    mark,
    reset_warnings,
    warn_once,
    warned,
)


def _src(body: str) -> str:
    return textwrap.dedent(body)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_expected_rules_registered(self):
        names = set(lint.registered_rules())
        assert {
            "mutated-host-mirror-alias",
            "blocking-transfer-in-hot-path",
            "donate-without-out-shardings",
            "deprecated-policies",
            "deprecated-policy-specs",
            "deprecated-put-like",
            "deprecated-engine-import",
            "deprecated-stats-dict",
            "deprecated-default-system",
        } <= names

    def test_duplicate_registration_rejected(self):
        rule = lint.PatternRule("dup-test-rule", r"zzz", "no")
        lint.register(rule)
        try:
            with pytest.raises(ValueError, match="duplicate"):
                lint.register(lint.PatternRule("dup-test-rule", r"zzz", "no"))
        finally:
            del lint._RULES["dup-test-rule"]

    def test_nameless_rule_rejected(self):
        with pytest.raises(ValueError, match="name"):
            lint.register(lint.Rule())

    def test_get_rule(self):
        assert lint.get_rule("deprecated-policies").name == \
            "deprecated-policies"


# ---------------------------------------------------------------------------
# mutated-host-mirror-alias
# ---------------------------------------------------------------------------

RULE_MIRROR = [lint.get_rule("mutated-host-mirror-alias")]


class TestMutatedHostMirrorAlias:
    def test_self_attr_any_order(self):
        # mutation in another method, textually BEFORE the alias: still
        # flagged (method call order is not statically known)
        src = _src("""
            import numpy as np
            import jax.numpy as jnp

            class T:
                def poke(self):
                    self.buf[0] = 1

                def view(self):
                    return jnp.asarray(self.buf)
        """)
        vs = lint.lint_source(src, "x.py", rules=RULE_MIRROR)
        assert len(vs) == 1 and vs[0].rule == "mutated-host-mirror-alias"

    def test_local_mutated_after_alias(self):
        src = _src("""
            import numpy as np
            import jax.numpy as jnp

            def f():
                buf = np.zeros(4)
                v = jnp.asarray(buf)
                buf[0] = 1
                return v
        """)
        assert lint.lint_source(src, "x.py", rules=RULE_MIRROR) == []

        src_cls = _src("""
            import numpy as np
            import jax.numpy as jnp

            class T:
                def f(self):
                    buf = np.zeros(4)
                    v = jnp.asarray(buf)
                    buf[0] = 1
                    return v
        """)
        vs = lint.lint_source(src_cls, "x.py", rules=RULE_MIRROR)
        assert len(vs) == 1

    def test_local_mutated_before_alias_is_fine(self):
        src = _src("""
            import numpy as np
            import jax.numpy as jnp

            class T:
                def f(self):
                    buf = np.zeros(4)
                    buf[0] = 1
                    return jnp.asarray(buf)
        """)
        assert lint.lint_source(src, "x.py", rules=RULE_MIRROR) == []

    def test_nested_closure_scoped_separately(self):
        # the engine.py shape: a closure aliases its OWN parameter while
        # the enclosing function mutates a same-named local — not a race
        src = _src("""
            import numpy as np
            import jax.numpy as jnp

            class T:
                def outer(self):
                    toks = np.zeros((2, 1))
                    toks[0, 0] = 7

                    def inner(toks):
                        return jnp.asarray(toks)

                    return inner(toks)
        """)
        assert lint.lint_source(src, "x.py", rules=RULE_MIRROR) == []

    def test_fresh_copy_subscript_arg_is_fine(self):
        src = _src("""
            import numpy as np
            import jax.numpy as jnp

            class T:
                def f(self):
                    v = jnp.asarray(self.buf[0])
                    self.buf[0] = 1
                    return v
        """)
        assert lint.lint_source(src, "x.py", rules=RULE_MIRROR) == []


# ---------------------------------------------------------------------------
# blocking-transfer-in-hot-path
# ---------------------------------------------------------------------------

RULE_HOT = [lint.get_rule("blocking-transfer-in-hot-path")]

HOT_SRC = _src("""
    import numpy as np

    def decode(x):
        return np.asarray(x)

    def warmup(x):
        return np.asarray(x)

    def step(x):
        return x.count.item()
""")


class TestBlockingTransferInHotPath:
    def test_only_hot_functions_flagged(self):
        vs = lint.lint_source(
            HOT_SRC, "src/repro/serve/zz.py", rules=RULE_HOT
        )
        assert len(vs) == 2  # decode() and step(); warmup() is cold

    def test_path_filter(self):
        assert lint.lint_source(HOT_SRC, "src/repro/core/zz.py",
                                rules=RULE_HOT) == []
        assert lint.lint_source(HOT_SRC, "tests/zz.py",
                                rules=RULE_HOT) == []

    def test_scalar_casts_flagged(self):
        src = _src("""
            def decode_step(arr):
                return float(arr[0])
        """)
        vs = lint.lint_source(src, "src/repro/serve/zz.py", rules=RULE_HOT)
        assert len(vs) == 1 and "float()" in vs[0].message

    def test_build_helpers_are_not_hot(self):
        # _build_steps and friends configure the jits; they are not on
        # the per-token path and may cast config scalars freely
        src = _src("""
            def _build_steps(cfg):
                return int(cfg.prefill_chunk)
        """)
        assert lint.lint_source(src, "src/repro/serve/zz.py",
                                rules=RULE_HOT) == []


# ---------------------------------------------------------------------------
# donate-without-out-shardings
# ---------------------------------------------------------------------------

RULE_DONATE = [lint.get_rule("donate-without-out-shardings")]


class TestDonateWithoutOutShardings:
    def test_flags_missing_out_shardings(self):
        src = "import jax\nstep = jax.jit(lambda p: p, donate_argnums=(0,))\n"
        vs = lint.lint_source(src, "x.py", rules=RULE_DONATE)
        assert len(vs) == 1 and vs[0].line == 2

    def test_pinned_out_shardings_ok(self):
        src = ("import jax\n"
               "step = jax.jit(lambda p: p, donate_argnums=(0,),\n"
               "               out_shardings=None)\n")
        assert lint.lint_source(src, "x.py", rules=RULE_DONATE) == []

    def test_anchored_to_donate_kw_line_in_multiline_call(self):
        src = _src("""
            import jax

            step = jax.jit(
                lambda p: p,
                donate_argnums=(0,),
            )
        """)
        (v,) = lint.lint_source(src, "x.py", rules=RULE_DONATE)
        assert "donate_argnums" in src.splitlines()[v.line - 1]


# ---------------------------------------------------------------------------
# Pragmas / allowlists / driver
# ---------------------------------------------------------------------------

class TestSuppression:
    def test_per_line_pragma(self):
        src = ("import jax\n"
               "s = jax.jit(lambda p: p, donate_argnums=(0,))"
               "  # repro: lint-disable=donate-without-out-shardings\n")
        assert lint.lint_source(src, "x.py", rules=RULE_DONATE) == []

    def test_file_level_pragma(self):
        src = ("# repro: lint-disable=donate-without-out-shardings\n"
               "import jax\n"
               "s = jax.jit(lambda p: p, donate_argnums=(0,))\n")
        assert lint.lint_source(src, "x.py", rules=RULE_DONATE) == []

    def test_pragma_lists_multiple_rules(self):
        src = ("# repro: lint-disable=donate-without-out-shardings, "
               "mutated-host-mirror-alias\n"
               "import jax\n"
               "s = jax.jit(lambda p: p, donate_argnums=(0,))\n")
        assert lint.lint_source(src, "x.py",
                                rules=RULE_DONATE + RULE_MIRROR) == []

    def test_pragma_for_other_rule_does_not_suppress(self):
        src = ("import jax\n"
               "s = jax.jit(lambda p: p, donate_argnums=(0,))"
               "  # repro: lint-disable=mutated-host-mirror-alias\n")
        assert len(lint.lint_source(src, "x.py", rules=RULE_DONATE)) == 1

    def test_allowlist(self):
        rule = lint.PatternRule(
            "t-allow", r"forbidden_token_zz", "no",
            allow=("src/ok.py",),
        )
        src = "x = forbidden_token_zz\n"
        assert lint.lint_source(src, "src/ok.py", rules=[rule]) == []
        assert len(lint.lint_source(src, "src/bad.py", rules=[rule])) == 1


class TestPatternRules:
    def test_comment_text_not_matched(self):
        # trigger assembled at runtime so this file stays gate-clean
        trigger = "POLI" + "CIES"
        rules = [lint.get_rule("deprecated-policies")]
        assert lint.lint_source(f"# {trigger} in a comment\n", "x.py",
                                rules=rules) == []
        vs = lint.lint_source(f"y = {trigger}['kv_host']\n", "x.py",
                              rules=rules)
        assert len(vs) == 1 and vs[0].snippet

    def test_engine_import_rule(self):
        rules = [lint.get_rule("deprecated-engine-import")]
        bad = "from repro.serve." + "engine import Server\n"
        good = "from repro.serve import Server\n"
        assert len(lint.lint_source(bad, "x.py", rules=rules)) == 1
        assert lint.lint_source(good, "x.py", rules=rules) == []

    def test_syntax_error_source_still_pattern_checked(self):
        rules = [lint.get_rule("deprecated-put-like")]
        src = "def broken(:\n    x = put_" + "like(1)\n"
        assert len(lint.lint_source(src, "x.py", rules=rules)) == 1


class TestRepoIsClean:
    def test_lint_repo_has_no_errors(self):
        import pathlib

        # anchor off this module: src/repro/analysis/lint.py -> repo root
        root = pathlib.Path(lint.__file__).resolve().parents[3]
        violations = [
            v for v in lint.lint_repo(root) if v.severity == "error"
        ]
        assert violations == [], "\n".join(map(str, violations))


# ---------------------------------------------------------------------------
# Shared warn-once registry (satellite: resettable across tests)
# ---------------------------------------------------------------------------

class TestWarningsRegistry:
    def test_warn_once_is_once(self):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            assert warn_once("t:k1", "msg one") is True
            assert warn_once("t:k1", "msg one") is False
        assert len(rec) == 1 and "msg one" in str(rec[0].message)
        assert warned("t:k1")

    def test_mark_registers_without_warning(self):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            assert mark("t:k2") is True
            assert mark("t:k2") is False
        assert rec == [] and warned("t:k2")

    def test_reset_by_prefix(self):
        mark("pfx:a")
        mark("other:b")
        reset_warnings("pfx")
        assert not warned("pfx:a") and warned("other:b")

    def test_reset_exact_key(self):
        mark("solo-key")
        reset_warnings("solo-key")
        assert not warned("solo-key")

    def test_autouse_fixture_resets_between_tests(self):
        # conftest's autouse fixture must have cleared every key the
        # previous tests in this class marked before this one started
        assert not warned("t:k1")
        assert not warned("t:k2")

    def test_deprecation_shims_rewarn_after_reset(self):
        # the placement deprecation shims now flow through the shared
        # registry: a reset re-arms them (what the autouse fixture
        # guarantees test-to-test)
        from repro.core import placement

        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            placement._warn_deprecated("k-test", "shim message")
            placement._warn_deprecated("k-test", "shim message")
        assert len(rec) == 1
        reset_warnings("deprecated")
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            placement._warn_deprecated("k-test", "shim message")
        assert len(rec) == 1
