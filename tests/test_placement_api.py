"""Compositional placement API + Runtime facade + live migration.

Three contracts, per ISSUE 5:

* **Serialization is lossless** — JSON round-trip is identity for every
  registered policy; the compact grammar and ``policy()``/``PolicyBuilder``
  build the same values the registry holds.
* **Migration preserves values and lands where predicted** — for each
  pair of policies realizable on this host, ``Runtime.migrate`` moves a
  live pytree bit-identically onto exactly the shardings/memory kinds
  ``Runtime.specs`` predicts for the target policy; a donor-tier target
  on a donor-less mesh raises ``DonorAxisError`` (never a silent local
  landing).
* **Deprecated paths still work, loudly** — ``POLICIES`` and
  ``policy_specs`` resolve with a ``DeprecationWarning`` pointing at
  ``repro.api``, and ``POLICIES`` is a read-only live view of the
  registry.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.api import Runtime
from repro.core.hardware import MemoryTier
from repro.core.placement import (
    DonorAxisError,
    Placement,
    PlacementPolicy,
    PolicyBuilder,
    Role,
    Strategy,
    get_policy,
    parse_policy,
    policy,
    register_policy,
    registered_policies,
)
from repro.launch.mesh import make_donor_mesh, make_mesh_for
from repro.models import get_smoke_bundle

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Serialization + construction
# ---------------------------------------------------------------------------

class TestSerialization:
    def test_json_round_trip_identity_for_all_registered(self):
        for name, pol in registered_policies().items():
            assert PlacementPolicy.from_json(pol.to_json()) == pol, name

    def test_placement_str_round_trip(self):
        for pl in (
            Placement(),
            Placement(MemoryTier.HOST, Strategy.STREAM),
            Placement(MemoryTier.PEER_HBM),
            Placement(MemoryTier.PEER_HOST, Strategy.STREAM),
            Placement(MemoryTier.REMOTE_HBM),
        ):
            assert Placement.parse(pl.to_str()) == pl

    def test_compact_grammar(self):
        pol = parse_policy("kv=host:stream,params=peer_hbm")
        assert pol.placements[Role.KV_CACHE] == Placement(
            MemoryTier.HOST, Strategy.STREAM
        )
        assert pol.placements[Role.PARAMS] == Placement(MemoryTier.PEER_HBM)
        # aliases: kv/weights/opt, enum tier values, 'device'/'ddr'
        alias = parse_policy("weights=ddr:stream,opt=hbm_p")
        assert alias.placements[Role.PARAMS] == Placement(
            MemoryTier.HOST, Strategy.STREAM
        )
        assert alias.placements[Role.OPT_STATE] == Placement(
            MemoryTier.PEER_HBM
        )

    def test_registered_name_and_json_inputs(self):
        assert parse_policy("kv_host") is get_policy("kv_host")
        via_json = parse_policy(get_policy("kv_host").to_json())
        assert via_json == get_policy("kv_host")

    def test_parse_errors_are_loud(self):
        with pytest.raises(ValueError, match="unknown policy"):
            parse_policy("not_a_policy")
        with pytest.raises(ValueError, match="role"):
            parse_policy("bogus_role=hbm")
        with pytest.raises(ValueError, match="tier"):
            parse_policy("kv=bogus_tier")
        with pytest.raises(ValueError, match="strategy"):
            parse_policy("kv=host:bogus")

    def test_policy_constructor_and_builder_agree(self):
        a = policy(kv="host:stream", params="peer_hbm")
        b = (
            PolicyBuilder()
            .place("kv", "host:stream")
            .place(Role.PARAMS, Placement(MemoryTier.PEER_HBM))
            .build()
        )
        assert a.placements == b.placements
        assert a.name == b.name          # stable derived name
        assert a.name.startswith("custom(")

    def test_registry_rejects_silent_overwrite(self):
        mine = policy("test_registry_tmp", kv="host:stream")
        register_policy(mine)
        try:
            assert get_policy("test_registry_tmp") is mine
            with pytest.raises(ValueError, match="already registered"):
                register_policy(policy("test_registry_tmp", kv="hbm"))
            register_policy(
                policy("test_registry_tmp", kv="hbm"), overwrite=True
            )
            assert get_policy("test_registry_tmp").placements[
                Role.KV_CACHE
            ] == Placement()
        finally:
            from repro.core.placement import _REGISTRY

            _REGISTRY.pop("test_registry_tmp", None)

    def test_registered_policy_enters_planner_enumeration(self):
        from repro.core.planner import eligible_policies

        mine = policy("test_enum_tmp", kv="host:stream")
        register_policy(mine)
        try:
            assert mine in eligible_policies()
            assert mine not in eligible_policies(allow_host=False)
        finally:
            from repro.core.placement import _REGISTRY

            _REGISTRY.pop("test_enum_tmp", None)


# ---------------------------------------------------------------------------
# Deprecated surface
# ---------------------------------------------------------------------------

class TestDeprecatedPaths:
    def test_policies_view_warns_and_forwards(self):
        # the autouse warn-once reset (conftest) makes this first access
        # warn regardless of test order
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            from repro.core.placement import POLICIES

            assert POLICIES["kv_host"] is get_policy("kv_host")
            assert set(POLICIES) == set(registered_policies())
        assert any(
            issubclass(x.category, DeprecationWarning)
            and "repro.api" in str(x.message).lower()
            or "registered_policies" in str(x.message)
            for x in w
        )
        # a second access does NOT warn again (single warning per process)
        with warnings.catch_warnings(record=True) as w2:
            warnings.simplefilter("always")
            _ = POLICIES["hbm_resident"]
        assert not [
            x for x in w2 if issubclass(x.category, DeprecationWarning)
        ]
        # read-only: the closed-dict mutation idiom is gone
        with pytest.raises(TypeError, match="read-only"):
            POLICIES["mine"] = get_policy("kv_host")

    def test_policies_view_sees_later_registrations(self):
        from repro.core.placement import _REGISTRY, POLICIES

        mine = policy("test_view_tmp", kv="host:stream")
        register_policy(mine)
        try:
            assert POLICIES["test_view_tmp"] is mine
        finally:
            _REGISTRY.pop("test_view_tmp", None)

    def test_policy_specs_import_warns(self):
        import repro.models.sharding as sharding_mod

        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            fn = sharding_mod.policy_specs
        assert fn is sharding_mod._policy_specs
        assert any(
            issubclass(x.category, DeprecationWarning)
            and "Runtime" in str(x.message)
            for x in w
        )

    def test_put_like_import_warns(self):
        import repro.core.placement as placement_mod

        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            fn = placement_mod.put_like
        assert fn is placement_mod._put_like
        assert any(
            issubclass(x.category, DeprecationWarning) for x in w
        )


# ---------------------------------------------------------------------------
# Migration equivalence
# ---------------------------------------------------------------------------

def _realizable_policies(mesh):
    """Registered policies realizable on ``mesh`` (donor tiers need the
    axis; host tiers degrade gracefully on CPU)."""
    from repro.core.placement import validate_policy_for_mesh

    out = []
    for pol in registered_policies().values():
        try:
            validate_policy_for_mesh(pol, mesh)
        except DonorAxisError:
            continue
        out.append(pol)
    return out


def _assert_lands_as_predicted(tree, rt, role, defs):
    want = rt.specs(role, defs)
    for leaf, sharding in zip(
        jax.tree.leaves(tree), jax.tree.leaves(
            want, is_leaf=lambda x: hasattr(x, "memory_kind")
        )
    ):
        assert leaf.sharding.spec == sharding.spec, (
            leaf.sharding, sharding
        )
        assert leaf.sharding.memory_kind == sharding.memory_kind


class TestMigration:
    @pytest.fixture(scope="class")
    def bundle(self):
        return get_smoke_bundle("olmo-1b")

    def test_migrate_pairs_preserve_values_and_land_predicted(self, bundle):
        """For each ordered pair of realizable policies: migrate() is
        bit-exact and the result carries exactly the shardings/memory
        kinds Runtime.specs predicts for the target."""
        mesh = (
            make_donor_mesh((1,), ("data",), 2)
            if jax.device_count() >= 2
            else make_mesh_for((1,), ("data",))
        )
        policies = _realizable_policies(mesh)
        assert len(policies) >= 4
        defs = bundle.cache_defs(2, 16)
        for src in policies:
            rt = Runtime(bundle, mesh, src)
            caches = rt.realize(bundle.init_cache(2, 16), Role.KV_CACHE, defs)
            snap = [np.asarray(x) for x in jax.tree.leaves(caches)]
            for dst in policies:
                if dst.name == src.name:
                    continue
                moved = rt.migrate(caches, Role.KV_CACHE, dst, defs)
                for a, b in zip(snap, jax.tree.leaves(moved)):
                    np.testing.assert_array_equal(a, np.asarray(b))
                _assert_lands_as_predicted(moved, rt, Role.KV_CACHE, defs)
                assert rt.policy.name == dst.name
                # migrate back for the next pair (src is the fixture)
                caches = rt.migrate(moved, Role.KV_CACHE, src, defs)
                for a, b in zip(snap, jax.tree.leaves(caches)):
                    np.testing.assert_array_equal(a, np.asarray(b))

    def test_migrate_to_donor_tier_without_axis_raises(self, bundle):
        mesh = make_mesh_for((1,), ("data",))
        rt = Runtime(bundle, mesh, "hbm_resident")
        defs = bundle.cache_defs(2, 16)
        caches = rt.realize(bundle.init_cache(2, 16), Role.KV_CACHE, defs)
        with pytest.raises(DonorAxisError, match="donor"):
            rt.migrate(caches, Role.KV_CACHE, "kv_peer_hbm", defs)
        # the failed migration must not have adopted the target policy
        assert rt.policy.name == "hbm_resident"
        # ... and the tree is untouched (still the local placement)
        _assert_lands_as_predicted(caches, rt, Role.KV_CACHE, defs)

    def test_migrate_without_mesh_refuses(self, bundle):
        rt = Runtime(bundle, None, "hbm_resident")
        with pytest.raises(ValueError, match="mesh"):
            rt.migrate(
                bundle.init_cache(2, 16), Role.KV_CACHE, "kv_host",
                bundle.cache_defs(2, 16),
            )

    def test_migrate_accepts_bare_placement(self, bundle):
        mesh = make_mesh_for((1,), ("data",))
        rt = Runtime(bundle, mesh, "hbm_resident")
        defs = bundle.cache_defs(2, 16)
        caches = rt.realize(bundle.init_cache(2, 16), Role.KV_CACHE, defs)
        moved = rt.migrate(
            caches, Role.KV_CACHE,
            Placement(MemoryTier.HOST, Strategy.STREAM), defs,
        )
        assert rt.policy.placement(Role.KV_CACHE) == Placement(
            MemoryTier.HOST, Strategy.STREAM
        )
        # other roles keep the source policy's placements
        assert rt.policy.placement(Role.PARAMS) == Placement()
        _assert_lands_as_predicted(moved, rt, Role.KV_CACHE, defs)

    def test_migrate_rebuilds_registered_stream(self, bundle):
        mesh = make_mesh_for((1,), ("data",))
        rt = Runtime(bundle, mesh, "weights_stream")
        n, m = 4, 8
        stack = jnp.arange(n * m, dtype=jnp.float32).reshape(n, m)
        stream = rt.open_stream(stack, Role.PARAMS, n)
        assert rt.stream(Role.PARAMS) is stream
        _ = stream.window(0)                       # stage a window
        moved = rt.migrate(stack, Role.PARAMS, "hbm_resident", specs=P())
        rebuilt = rt.stream(Role.PARAMS)
        assert rebuilt is not stream               # staging buffers rebuilt
        np.testing.assert_array_equal(
            np.asarray(rebuilt.window(1)), np.asarray(moved[1])
        )

    def test_replan_compares_placements_not_names(self, bundle):
        """A custom spelling of the current placement is a no-op (no
        pointless cache move + jit rebuild); a genuinely different
        placement migrates."""
        from repro.serve import ServeConfig, Server

        mesh = make_mesh_for((1,), ("data",))
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        server = Server(
            bundle,
            ServeConfig(batch_slots=2, max_len=32, policy="kv_host"),
            params, mesh=mesh,
        )
        # same placements under a different (derived) name -> no-op
        assert server.replan("kv=host:stream") is False
        assert server.stats()["migrations"] == 0
        assert server.policy.name == "kv_host"
        # different placements -> migrates
        assert server.replan("hbm_resident") is True
        assert server.stats()["migrations"] == 1

    def test_custom_string_policy_serves_with_mid_run_migration(self, bundle):
        """Acceptance: a non-registered custom policy (string grammar)
        serves end-to-end through Runtime, and a live mid-serve
        migration leaves the greedy tokens identical to an uninterrupted
        static-policy run."""
        from repro.serve import Request, ServeConfig, Server

        mesh = make_mesh_for((1,), ("data",))
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")

        def run(policy_arg, migrate_at=None, target=None):
            server = Server(
                bundle,
                ServeConfig(batch_slots=2, max_len=32, prefill_chunk=4,
                            policy=policy_arg),
                params, mesh=mesh,
            )
            rng = np.random.default_rng(0)
            reqs = [
                Request(rid=i,
                        prompt=rng.integers(1, bundle.cfg.vocab, 6)
                        .astype(np.int32),
                        max_new_tokens=8)
                for i in range(3)
            ]
            server.add_requests(reqs)
            steps = 0
            while server.has_work():
                server.step()
                steps += 1
                if migrate_at is not None and steps == migrate_at:
                    assert server.replan(target) is True
                assert steps < 200
            return [r.out_tokens for r in reqs], server

        custom = "kv=host:stream"            # NOT a registered name
        assert custom not in registered_policies()
        base, _ = run(custom)
        moved, server = run(custom, migrate_at=3, target="hbm_resident")
        assert base == moved
        assert server.stats()["migrations"] == 1
        assert server.policy.name == "hbm_resident"


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="donor mesh needs >= 2 devices")
class TestDonorMigration:
    """The peer-tier half of the migration matrix (runs on the CI
    4-device leg): live KV moves local<->donor-sharded mid-serve with
    token equality."""

    def test_serve_migrates_kv_to_peer_and_back(self):
        from repro.serve import Request, ServeConfig, Server

        bundle = get_smoke_bundle("olmo-1b")
        mesh = make_donor_mesh((2,), ("data",), 2)
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")

        def run(migrations=()):
            server = Server(
                bundle,
                ServeConfig(batch_slots=2, max_len=32, prefill_chunk=4,
                            policy="hbm_resident"),
                params, mesh=mesh,
            )
            rng = np.random.default_rng(1)
            reqs = [
                Request(rid=i,
                        prompt=rng.integers(1, bundle.cfg.vocab, 5)
                        .astype(np.int32),
                        max_new_tokens=6)
                for i in range(3)
            ]
            server.add_requests(reqs)
            steps = 0
            sched = dict(migrations)
            while server.has_work():
                server.step()
                steps += 1
                if steps in sched:
                    assert server.replan(sched[steps]) is True
                assert steps < 300
            return [r.out_tokens for r in reqs], server

        base, _ = run()
        moved, server = run(migrations=((2, "kv_peer_hbm"),
                                        (5, "hbm_resident")))
        assert base == moved
        assert server.stats()["migrations"] == 2

        # donor landing is physical: migrate a cache tree and check the
        # donor axis + donor-slice devices appear on its shards
        from repro.models.sharding import spec_axes

        rt = Runtime(bundle, mesh, "hbm_resident")
        defs = bundle.cache_defs(2, 16)
        caches = rt.realize(bundle.init_cache(2, 16), Role.KV_CACHE, defs)
        moved = rt.migrate(caches, Role.KV_CACHE, "kv_peer_hbm", defs)
        donor_devs = set(mesh.devices[1].ravel())
        for leaf in jax.tree.leaves(moved):
            assert "donor" in spec_axes(leaf.sharding.spec)
            assert {s.device for s in leaf.addressable_shards} & donor_devs
