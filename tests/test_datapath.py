"""Property tests (hypothesis) for the datapath model — the paper's Fig. 3
rules as machine-checked invariants — plus the planner's decision logic."""

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import (
    get_active_system,
    Link,
    MemoryTier,
    PlacementPolicy,
    Role,
    WorkloadProfile,
    bound_matrix,
    collective_bound,
    copy_bound,
    migration_crossover_touches,
    plan,
    predict,
    read_bound,
    streaming_time,
    wire_bytes,
    write_bound,
)
from repro.core.placement import HBM_RESIDENT, OPT_HOST

TIERS = [t for t in MemoryTier if t != MemoryTier.VMEM]
tier_st = st.sampled_from(TIERS)


class TestDatapathInvariants:
    @given(tier_st)
    def test_read_write_symmetric_bounds(self, tier):
        # bounds are path properties; measured asymmetry is an efficiency
        # effect (paper Fig. 9), never a bound effect.
        assert read_bound(tier).bandwidth == write_bound(tier).bandwidth

    @given(tier_st, tier_st)
    def test_copy_bound_symmetric(self, a, b):
        assert copy_bound(a, b).bandwidth == pytest.approx(
            copy_bound(b, a).bandwidth
        )

    @given(tier_st, tier_st)
    def test_copy_never_beats_slower_endpoint(self, a, b):
        cb = copy_bound(a, b).bandwidth
        assert cb <= read_bound(a).bandwidth + 1e-9
        assert cb <= read_bound(b).bandwidth + 1e-9

    @given(tier_st)
    def test_same_tier_copy_halves(self, tier):
        # the paper's central rule: a link traversed twice contributes at
        # half bandwidth (DDR->DDR at 250 = C2C/2; here HBM->HBM = 819/2).
        assert copy_bound(tier, tier).bandwidth == pytest.approx(
            read_bound(tier).bandwidth / 2
        )

    def test_local_faster_than_peer_faster_than_remote(self):
        # the locality ordering the paper measures (Figs. 7, 11)
        assert (
            read_bound(MemoryTier.HBM).bandwidth
            > read_bound(MemoryTier.PEER_HBM).bandwidth
            > read_bound(MemoryTier.REMOTE_HBM).bandwidth
        )
        assert (
            read_bound(MemoryTier.HBM).latency
            < read_bound(MemoryTier.PEER_HBM).latency
            < read_bound(MemoryTier.REMOTE_HBM).latency
        )

    def test_limiting_link_identity(self):
        assert read_bound(MemoryTier.HOST).limiting_link == Link.PCIE
        assert read_bound(MemoryTier.PEER_HBM).limiting_link == Link.ICI
        assert read_bound(MemoryTier.REMOTE_HBM).limiting_link == Link.DCN

    @given(st.floats(1.0, 1e12), st.integers(2, 512))
    def test_wire_bytes_bounds(self, payload, n):
        ar = wire_bytes("all-reduce", payload, n)
        ag = wire_bytes("all-gather", payload, n)
        assert 0 <= ag < payload
        assert ag <= ar <= 2 * payload
        assert wire_bytes("all-reduce", payload, 1) == 0.0

    @given(st.integers(2, 64))
    def test_collective_bound_allreduce_is_half_gather(self, n):
        ar = collective_bound(n, Link.ICI, "all_reduce")
        ag = collective_bound(n, Link.ICI, "all_gather")
        assert ar == pytest.approx(ag / 2)

    def test_bound_matrix_shape(self):
        m = bound_matrix("copy")
        assert set(m) == {str(t) for t in TIERS}
        assert m["hbm"]["hbm"] == pytest.approx(819 / 2, rel=1e-3)

    @given(tier_st)
    def test_migration_crossover_positive(self, tier):
        x = migration_crossover_touches(tier)
        if read_bound(tier).bandwidth < get_active_system().chip.hbm_bandwidth:
            assert x > 0
            # at crossover, streaming == migrate+resident (paper Fig. 4)
            nbytes = 1e9
            stream = streaming_time(nbytes, tier, touches=x)
            migrate = (
                nbytes / copy_bound(tier, MemoryTier.HBM).bandwidth
                + streaming_time(nbytes, MemoryTier.HBM, touches=x)
            )
            assert stream == pytest.approx(migrate, rel=0.05)


class TestPlanner:
    def _profile(self, param_gb=1.0, flops=1e15):
        return WorkloadProfile(
            name="t",
            flops=flops,
            bytes_per_role={
                Role.PARAMS: param_gb * 1e9,
                Role.MASTER: 2 * param_gb * 1e9,
                Role.OPT_STATE: 4 * param_gb * 1e9,
            },
            touches_per_role={
                Role.PARAMS: 3, Role.MASTER: 2, Role.OPT_STATE: 2
            },
        )

    def test_small_model_prefers_hbm(self):
        best, _ = plan(self._profile(param_gb=0.5))
        assert best.policy == "hbm_resident"

    def test_oversized_model_offloads(self):
        # 8 GB params -> 56 GB of state: hbm_resident does not fit 16 GB,
        # opt_host (8+8=16... params+grads) borderline -> planner must not
        # pick an infeasible policy.
        best, preds = plan(self._profile(param_gb=4.0))
        assert best.policy != "hbm_resident"
        infeasible = {p.policy for p in preds if not p.fits}
        assert "hbm_resident" in infeasible

    def test_prediction_terms_positive(self):
        p = predict(self._profile(), OPT_HOST)
        assert p.pcie_s > 0 and p.hbm_s > 0 and p.compute_s > 0
        assert p.step_s >= max(p.compute_s, p.pcie_s)

    @given(st.floats(0.1, 8.0))
    @settings(max_examples=20, deadline=None)
    def test_offload_never_increases_hbm(self, gb):
        prof = self._profile(param_gb=gb)
        r = predict(prof, HBM_RESIDENT)
        o = predict(prof, OPT_HOST)
        assert o.hbm_bytes <= r.hbm_bytes

    # (the POLICIES registry contents are asserted in tests/test_planner.py,
    #  which collects even without hypothesis)
