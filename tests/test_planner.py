"""Planner v2: every predicted term must agree with the datapath bounds.

These are plain-pytest invariants (no hypothesis) so they run even on the
minimal container: the planner is the datapath model's consumer, and any
drift between ``predict`` and ``read_bound``/``copy_bound``/
``collective_bound`` silently invalidates every placement decision.
"""

import pytest

from repro.core import (
    get_active_system,
    CollectiveTerm,
    Link,
    MemoryTier,
    Role,
    WorkloadProfile,
    collective_bound,
    copy_bound,
    eligible_policies,
    plan,
    pool_capacities,
    predict,
    read_bound,
)
from repro.core.placement import (
    HBM_RESIDENT,
    KV_HOST,
    KV_PEER_HBM,
    KV_REMOTE_HBM,
    OPT_HOST,
    WEIGHTS_STREAM,
    registered_policies,
)

GB = 1e9


def _kv_profile(kv_gb=1.0, param_gb=2.0, chunks=4, **kw):
    return WorkloadProfile(
        name="t",
        flops=1e12,
        bytes_per_role={Role.PARAMS: param_gb * GB, Role.KV_CACHE: kv_gb * GB},
        touches_per_role={Role.PARAMS: 1.0, Role.KV_CACHE: 1.0},
        stream_chunks=chunks,
        **kw,
    )


class TestPredictMatchesDatapath:
    def test_hbm_resident_term_is_hbm_read_bound(self):
        prof = _kv_profile()
        p = predict(prof, HBM_RESIDENT)
        b = read_bound(MemoryTier.HBM)
        nbytes = 3.0 * GB
        assert p.hbm_s == pytest.approx(nbytes / b.bandwidth + 2 * b.latency)
        assert p.pcie_s == 0.0 and p.ici_s == 0.0 and p.dcn_s == 0.0

    def test_streamed_host_term_is_copy_bound(self):
        """A host-streamed role pays copy_bound(HOST, HBM) — the full
        PCIe+HBM path with per-chunk latency — plus the HBM compute pass."""
        chunks = 4
        prof = _kv_profile(chunks=chunks)
        p = predict(prof, KV_HOST)
        cb = copy_bound(MemoryTier.HOST, MemoryTier.HBM)
        assert cb.limiting_link == Link.PCIE
        assert p.pcie_s == pytest.approx(
            1.0 * GB / cb.bandwidth + chunks * cb.latency
        )
        hb = read_bound(MemoryTier.HBM)
        # params pass + the streamed KV's HBM pass
        assert p.hbm_s == pytest.approx(3.0 * GB / hb.bandwidth + 2 * hb.latency)

    def test_shared_link_halving_inherited(self):
        """The twice-traversed-link rule flows through predict: streaming a
        role from HOST to HOST-backed staging... i.e. a HOST->HOST copy
        halves PCIe; the planner's HOST->HBM path must NOT halve (each link
        crossed once), matching copy_bound exactly."""
        assert copy_bound(MemoryTier.HOST, MemoryTier.HOST).bandwidth == (
            pytest.approx(read_bound(MemoryTier.HOST).bandwidth / 2)
        )
        cb = copy_bound(MemoryTier.HOST, MemoryTier.HBM)
        assert cb.bandwidth == pytest.approx(
            min(get_active_system().link_bandwidth(Link.PCIE),
                get_active_system().link_bandwidth(Link.HBM_BUS))
        )

    def test_peer_policy_bounded_by_ici(self):
        prof = _kv_profile()
        p = predict(prof, KV_PEER_HBM)
        rb = read_bound(MemoryTier.PEER_HBM)
        assert rb.limiting_link == Link.ICI
        assert p.ici_s == pytest.approx(1.0 * GB / rb.bandwidth + rb.latency)
        # peer in-place reads never beat the ICI link
        assert 1.0 * GB / p.ici_s <= get_active_system().link_bandwidth(Link.ICI)

    def test_remote_policy_bounded_by_dcn(self):
        p = predict(_kv_profile(), KV_REMOTE_HBM)
        rb = read_bound(MemoryTier.REMOTE_HBM)
        assert rb.limiting_link == Link.DCN
        assert p.dcn_s == pytest.approx(1.0 * GB / rb.bandwidth + rb.latency)

    def test_collective_term_is_collective_bound(self):
        term = CollectiveTerm("all_reduce", Link.ICI, 16, 4 * GB)
        prof = _kv_profile(collectives=(term,))
        p = predict(prof, HBM_RESIDENT)
        assert p.collective_s == pytest.approx(
            4 * GB / collective_bound(16, Link.ICI, "all_reduce")
        )


class TestCapacityPools:
    def test_staging_buffer_charged_to_hbm(self):
        chunks = 4
        p = predict(_kv_profile(chunks=chunks), KV_HOST)
        # params resident + double-buffered staging window of the stream
        assert p.hbm_bytes == pytest.approx(2.0 * GB + 2 * GB / chunks)
        assert p.host_bytes == pytest.approx(1.0 * GB)

    def test_dual_pool_overflow_detected(self):
        caps = pool_capacities()
        # KV bigger than host DRAM: kv_host must overflow the host pool
        kv_gb = (caps["host"] + GB) / GB
        p = predict(_kv_profile(kv_gb=kv_gb), KV_HOST)
        assert not p.fits and "host" in p.overflow_pools

    def test_peer_pool_overflow_detected(self):
        caps = pool_capacities()
        kv_gb = (caps["peer_hbm"] + GB) / GB
        p = predict(_kv_profile(kv_gb=kv_gb), KV_PEER_HBM)
        assert not p.fits and "peer_hbm" in p.overflow_pools

    def test_all_tiers_have_pools(self):
        from repro.core.planner import _TIER_POOL

        for tier in MemoryTier:
            if tier == MemoryTier.VMEM:
                continue
            assert tier in _TIER_POOL


class TestPlan:
    def test_small_model_prefers_hbm(self):
        best, _ = plan(_kv_profile())
        assert best.policy == "hbm_resident"

    def test_oversized_kv_offloads(self):
        caps = pool_capacities()
        kv_gb = (caps["hbm"] + GB) / GB  # KV alone overflows local HBM
        best, preds = plan(_kv_profile(kv_gb=kv_gb, param_gb=1.0))
        assert best.policy != "hbm_resident"
        assert best.fits
        infeasible = {p.policy for p in preds if not p.fits}
        assert "hbm_resident" in infeasible

    def test_allow_flags_filter_tiers(self):
        names = {p.name for p in eligible_policies(allow_host=False)}
        assert "hbm_resident" in names
        assert not names & {"opt_host", "kv_host", "weights_stream",
                            "opt_peer_host"}
        names = {p.name for p in eligible_policies(allow_peer=False)}
        assert not names & {"kv_peer_hbm", "weights_peer_hbm",
                            "opt_peer_host"}
        names = {p.name for p in eligible_policies(allow_remote=False)}
        assert "kv_remote_hbm" not in names

    def test_plan_without_host_still_picks(self):
        caps = pool_capacities()
        kv_gb = (caps["hbm"] + GB) / GB
        best, preds = plan(
            _kv_profile(kv_gb=kv_gb, param_gb=1.0), allow_host=False
        )
        # host tiers unreachable: the planner must fall back to a peer tier
        assert best.policy in {"kv_peer_hbm", "kv_remote_hbm"}
        assert all(
            p.policy not in {"kv_host", "weights_stream", "opt_host"}
            for p in preds
        )

    def test_registry_covers_seed_and_peer_policies(self):
        assert {
            "hbm_resident", "opt_host", "kv_host", "weights_stream",
            "kv_peer_hbm", "weights_peer_hbm", "opt_peer_host",
            "kv_remote_hbm",
        } <= set(registered_policies())

    def test_offload_never_increases_hbm(self):
        for gb in (0.1, 1.0, 4.0, 8.0):
            prof = WorkloadProfile(
                name="t",
                flops=1e15,
                bytes_per_role={
                    Role.PARAMS: gb * GB,
                    Role.MASTER: 2 * gb * GB,
                    Role.OPT_STATE: 4 * gb * GB,
                },
                touches_per_role={
                    Role.PARAMS: 3, Role.MASTER: 2, Role.OPT_STATE: 2
                },
            )
            r = predict(prof, HBM_RESIDENT)
            o = predict(prof, OPT_HOST)
            w = predict(prof, WEIGHTS_STREAM)
            assert o.hbm_bytes <= r.hbm_bytes
            assert w.hbm_bytes <= r.hbm_bytes


class _FakeMesh:
    """Duck-typed mesh: donor_allow_flags only reads ``.shape``."""

    def __init__(self, **axes):
        self.shape = dict(axes)


class TestDonorMeshGating:
    """The auto-pick may select peer/remote tiers exactly when the mesh
    has the donor axis that realizes them (acceptance: ISSUE 2)."""

    def test_flags_follow_mesh_axes(self):
        from repro.core.placement import donor_allow_flags

        assert donor_allow_flags(None)["allow_peer"] is False
        assert donor_allow_flags(None)["allow_remote"] is False
        flags = donor_allow_flags(_FakeMesh(data=4, model=2))
        assert not flags["allow_peer"] and not flags["allow_remote"]
        flags = donor_allow_flags(_FakeMesh(donor=2, data=2))
        assert flags["allow_peer"] and not flags["allow_remote"]
        flags = donor_allow_flags(_FakeMesh(donor_pod=2, donor=2, data=2))
        assert flags["allow_peer"] and flags["allow_remote"]

    def test_plan_picks_peer_tier_under_donor_mesh(self):
        from repro.core.placement import donor_allow_flags

        caps = pool_capacities()
        # KV alone fits a donor's pool, but params+KV overflow local HBM
        # and host tiers are unreachable: only a peer tier can serve this.
        kv_gb = (caps["hbm"] - GB) / GB
        prof = _kv_profile(kv_gb=kv_gb, param_gb=2.0)
        flags = donor_allow_flags(_FakeMesh(donor=2, data=2))
        flags["allow_host"] = False
        best, preds = plan(prof, **flags)
        assert best.fits
        assert best.policy in {"kv_peer_hbm", "weights_peer_hbm"}
        # with no donor axis the prior restriction still holds
        flags = donor_allow_flags(_FakeMesh(data=4))
        flags["allow_host"] = False
        best, preds = plan(prof, **flags)
        assert {p.policy for p in preds} == {"hbm_resident"}
        assert not best.fits

    def test_validate_policy_for_mesh(self):
        from repro.core.placement import (
            DonorAxisError,
            validate_policy_for_mesh,
        )

        validate_policy_for_mesh(HBM_RESIDENT, None)
        validate_policy_for_mesh(KV_PEER_HBM, _FakeMesh(donor=2))
        validate_policy_for_mesh(KV_REMOTE_HBM, _FakeMesh(donor_pod=2))
        with pytest.raises(DonorAxisError, match="donor"):
            validate_policy_for_mesh(KV_PEER_HBM, None)
        with pytest.raises(DonorAxisError, match="kv_cache"):
            validate_policy_for_mesh(KV_PEER_HBM, _FakeMesh(data=4))
        with pytest.raises(DonorAxisError, match="donor_pod"):
            validate_policy_for_mesh(KV_REMOTE_HBM, _FakeMesh(donor=2))


class TestPerPoolOOMReport:
    def test_overflow_lists_every_pool(self):
        caps = pool_capacities()
        prof = _kv_profile(
            kv_gb=(caps["peer_hbm"] + GB) / GB,
            param_gb=(caps["hbm"] + GB) / GB,
        )
        p = predict(prof, KV_PEER_HBM)
        assert set(p.overflow_pools) == {"hbm", "peer_hbm"}

    def test_require_fit_raises_with_per_pool_report(self):
        from repro.core.planner import PlacementOOMError

        caps = pool_capacities()
        kv_gb = (caps["hbm"] + caps["host"] + GB) / GB  # fits nowhere
        with pytest.raises(PlacementOOMError) as exc:
            plan(
                _kv_profile(kv_gb=kv_gb, param_gb=1.0),
                require_fit=True,
            )
        msg = str(exc.value)
        # the report names the overflowing pool and capacity per policy
        assert "hbm_resident" in msg and "hbm " in msg and "cap" in msg
        assert "kv_host" in msg and "host" in msg
        assert exc.value.predictions


class TestServeIntegration:
    def test_server_auto_pick_logs_explain_table(self, caplog):
        import logging

        import jax

        from repro.models import get_smoke_bundle
        from repro.serve import ServeConfig, Server

        bundle = get_smoke_bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        with caplog.at_level(logging.INFO):
            server = Server(
                bundle, ServeConfig(batch_slots=2, max_len=64), params
            )
        assert server.policy.name in registered_policies()
        assert any(
            "planner picked" in r.getMessage() for r in caplog.records
        )
        # the auto-pick logs the top-candidate explain table, not just
        # the winner's name
        table = "\n".join(
            r.getMessage() for r in caplog.records if r.name == "repro.api"
        )
        assert "phase=serve picked=" in table
        assert "limited by" in table
