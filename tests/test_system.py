"""End-to-end behaviour tests for the framework.

The headline claims, executed: (1) the full train loop learns on a
deterministic stream; (2) checkpoint/restart reproduces the exact
trajectory; (3) the serving engine decodes greedily and matches a direct
decode loop; (4) the planner's placement choice responds to model size the
way the paper's Fig. 17 measurements do.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core.planner import decode_profile, plan
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh_for
from repro.models import get_smoke_bundle
from repro.optim import AdamWConfig
from repro.serve import Request, ServeConfig, Server
from repro.train import TrainConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def mesh():
    return make_mesh_for((1,), ("data",))


def _train(bundle, mesh, steps, seed=0, lr=3e-3, start_state=None, data_start=0):
    tcfg = TrainConfig(
        remat="none",
        optimizer=AdamWConfig(lr=lr, warmup_steps=5, weight_decay=0.0),
    )
    if start_state is None:
        params, opt, ef = init_train_state(
            bundle, mesh, jax.random.PRNGKey(seed), tcfg
        )
    else:
        params, opt, ef = start_state
    step = jax.jit(make_train_step(bundle, mesh, tcfg))
    data = SyntheticLM(
        DataConfig(vocab=bundle.cfg.vocab, seq_len=32, global_batch=8,
                   structure=1.0)
    )
    data.restore({"step": data_start, "seed": 0})
    losses = []
    for _, batch in zip(range(steps), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, ef, m = step(params, opt, ef, batch)
        losses.append(float(m["loss"]))
    return (params, opt, ef), losses


class TestTraining:
    def test_loss_decreases(self, mesh):
        bundle = get_smoke_bundle("granite-8b")
        _, losses = _train(bundle, mesh, steps=40)
        assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])

    def test_checkpoint_restart_exact(self, mesh, tmp_path):
        bundle = get_smoke_bundle("olmo-1b")
        state, losses_a = _train(bundle, mesh, steps=6)
        ck = Checkpointer(str(tmp_path))
        ck.save(6, state, blocking=True)
        # continue 4 more
        _, cont = _train(bundle, mesh, steps=4, start_state=state, data_start=6)
        # restart from checkpoint, continue 4
        restored, _ = ck.restore(state)
        restored = jax.tree.map(jnp.asarray, restored)
        _, cont2 = _train(
            bundle, mesh, steps=4, start_state=tuple(restored), data_start=6
        )
        np.testing.assert_allclose(cont, cont2, rtol=1e-5, atol=1e-6)

    def test_microbatched_matches_full_batch(self, mesh):
        bundle = get_smoke_bundle("olmo-1b")
        tcfg1 = TrainConfig(remat="none", n_microbatches=1,
                            optimizer=AdamWConfig(lr=1e-3, warmup_steps=1))
        tcfg4 = TrainConfig(remat="none", n_microbatches=4,
                            optimizer=AdamWConfig(lr=1e-3, warmup_steps=1))
        p1, o1, e1 = init_train_state(bundle, mesh, jax.random.PRNGKey(0), tcfg1)
        p4, o4, e4 = init_train_state(bundle, mesh, jax.random.PRNGKey(0), tcfg4)
        s1 = jax.jit(make_train_step(bundle, mesh, tcfg1))
        s4 = jax.jit(make_train_step(bundle, mesh, tcfg4))
        data = SyntheticLM(DataConfig(vocab=bundle.cfg.vocab, seq_len=16,
                                      global_batch=8))
        batch = {k: jnp.asarray(v) for k, v in next(iter(data)).items()}
        p1, o1, _, m1 = s1(p1, o1, e1, batch)
        p4, o4, _, m4 = s4(p4, o4, e4, batch)
        # Adam's step-1 update is ~sign(g)*lr, which amplifies bf16
        # accumulation-order noise on near-zero grads into full-lr param
        # diffs — so compare the accumulated GRADIENT statistics (the
        # mechanism under test), not post-Adam params.
        np.testing.assert_allclose(
            float(m1["loss"]), float(m4["loss"]), rtol=1e-3
        )
        np.testing.assert_allclose(
            float(m1["grad_norm"]), float(m4["grad_norm"]), rtol=1e-2
        )

    def test_remat_matches_no_remat(self, mesh):
        bundle = get_smoke_bundle("yi-6b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        data = SyntheticLM(DataConfig(vocab=bundle.cfg.vocab, seq_len=16,
                                      global_batch=2))
        batch = {k: jnp.asarray(v) for k, v in next(iter(data)).items()}
        l1, _ = bundle.train_loss(params, batch, remat="none")
        l2, _ = bundle.train_loss(params, batch, remat="full")
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
        g1 = jax.grad(lambda p: bundle.train_loss(p, batch, remat="none")[0])(params)
        g2 = jax.grad(lambda p: bundle.train_loss(p, batch, remat="full")[0])(params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)


class TestServing:
    def test_continuous_batching_drains(self):
        bundle = get_smoke_bundle("granite-8b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        server = Server(
            bundle, ServeConfig(batch_slots=2, max_len=64), params
        )
        rng = np.random.default_rng(0)
        reqs = [
            Request(rid=i,
                    prompt=rng.integers(0, bundle.cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=4)
            for i in range(5)  # more requests than slots -> queueing
        ]
        for r in reqs:
            server.add_request(r)
        server.run_until_done(max_steps=200)
        assert all(r.done and len(r.out_tokens) == 4 for r in reqs)

    def test_server_matches_direct_decode(self):
        bundle = get_smoke_bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        prompt = np.arange(1, 9, dtype=np.int32)
        server = Server(bundle, ServeConfig(batch_slots=1, max_len=64), params)
        req = Request(rid=0, prompt=prompt, max_new_tokens=5)
        server.add_request(req)
        server.run_until_done(max_steps=100)
        # direct: prefill + greedy decode loop with batch=1
        cache = bundle.init_cache(1, 64)
        logits, cache = bundle.prefill(
            params, {"tokens": jnp.asarray(prompt)[None]}, cache
        )
        toks = []
        lengths = jnp.asarray([len(prompt)], jnp.int32)
        tok = jnp.argmax(logits, -1)[:, None]
        toks.append(int(tok[0, 0]))
        for _ in range(4):
            logits, cache = bundle.decode_step(
                params, {"tokens": tok, "lengths": lengths}, cache
            )
            lengths = lengths + 1
            tok = jnp.argmax(logits, -1)[:, None]
            toks.append(int(tok[0, 0]))
        assert req.out_tokens == toks


class TestServeAdmission:
    """Regression: admission used to accept prompts with len(prompt)-1 >=
    max_len, advancing the length mirrors past the cache extent and
    silently clamping/corrupting KV writes — validation now happens on
    add_request, and SlotTable.free clears bookkeeping in one place."""

    def _server(self, max_len=16, slots=2):
        bundle = get_smoke_bundle("olmo-1b")
        params = bundle.init_params(jax.random.PRNGKey(0), "float32")
        return bundle, Server(
            bundle, ServeConfig(batch_slots=slots, max_len=max_len), params
        )

    def test_overlong_prompt_rejected(self):
        bundle, server = self._server(max_len=16)
        for bad_len in (16, 17, 40):
            with pytest.raises(ValueError, match="does not fit"):
                server.add_request(Request(
                    rid=bad_len,
                    prompt=np.arange(bad_len, dtype=np.int32) % bundle.cfg.vocab,
                    max_new_tokens=4,
                ))
        assert not server.has_work()

    def test_empty_prompt_rejected(self):
        _, server = self._server()
        with pytest.raises(ValueError, match="empty prompt"):
            server.add_request(Request(
                rid=0, prompt=np.zeros(0, np.int32), max_new_tokens=4
            ))

    def test_longest_admissible_prompt_serves_and_frees_slot(self):
        bundle, server = self._server(max_len=16, slots=1)
        prompt = (np.arange(15, dtype=np.int32) + 1) % bundle.cfg.vocab
        req = Request(rid=0, prompt=prompt, max_new_tokens=8)
        server.add_request(req)          # len(prompt) == max_len - 1: fits
        server.run_until_done(max_steps=100)
        assert req.done and len(req.out_tokens) >= 1
        # lengths never ran past the cache extent
        assert server.table.lengths.max() == 0  # slot freed -> bookkeeping clear
        assert server.table.slots == [None]
        # the freed slot is reusable for a fresh request
        req2 = Request(rid=1, prompt=prompt[:4], max_new_tokens=2)
        server.add_request(req2)
        server.run_until_done(max_steps=100)
        assert req2.done and len(req2.out_tokens) == 2


class TestPlannerIntegration:
    def test_decode_placement_flips_with_model_size(self):
        # small model: everything fits -> hbm_resident; model >> HBM: the
        # planner must pick an offload policy (the paper's Fig. 17 regime)
        small = decode_profile(
            name="s", param_bytes=2e9, kv_bytes=1e9, step_flops=1e12
        )
        big = decode_profile(
            name="b", param_bytes=200e9, kv_bytes=100e9, step_flops=1e12
        )
        best_small, _ = plan(small)
        best_big, _ = plan(big)
        assert best_small.policy == "hbm_resident"
        assert best_big.policy != "hbm_resident"
