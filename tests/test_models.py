"""Per-architecture smoke tests (required deliverable): every assigned arch
instantiates a REDUCED same-family config and runs one forward/train step on
CPU asserting output shapes + no NaNs; plus cache-consistency and MoE
behaviour tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    SHAPES,
    get_config,
    list_archs,
    shape_applicable,
    smoke_config,
)
from repro.models import get_smoke_bundle
from repro.models.moe import apply_moe, capacity, moe_defs
from repro.models.sharding import materialize

ALL_ARCHS = list_archs()


def _batch_for(cfg, B, S, key=2, with_labels=True):
    enc_dec = cfg.family == "audio" and cfg.n_encoder_layers
    text_len = S if enc_dec else S - cfg.frontend_tokens
    toks = jax.random.randint(
        jax.random.PRNGKey(key), (B, text_len), 0, cfg.vocab
    )
    batch = {"tokens": toks}
    if with_labels:
        batch["labels"] = jnp.roll(toks, -1, axis=1)
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = (
            jax.random.normal(
                jax.random.PRNGKey(3), (B, cfg.frontend_tokens, cfg.d_model)
            ) * 0.02
        )
    if cfg.frontend == "audio_stub":
        batch["frame_embeds"] = (
            jax.random.normal(
                jax.random.PRNGKey(3), (B, cfg.frontend_tokens, cfg.d_model)
            ) * 0.02
        )
    return batch


class TestArchSmoke:
    """One reduced-config forward/train step per assigned architecture."""

    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_train_step_no_nans(self, arch):
        b = get_smoke_bundle(arch)
        params = b.init_params(jax.random.PRNGKey(0), "float32")
        batch = _batch_for(b.cfg, B=2, S=32)
        loss, metrics = b.train_loss(params, batch, remat="none")
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), (arch, loss)
        grads = jax.grad(
            lambda p: b.train_loss(p, batch, remat="none")[0]
        )(params)
        finite = jax.tree.map(lambda g: bool(jnp.isfinite(g).all()), grads)
        assert all(jax.tree.leaves(finite)), arch

    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_prefill_decode_shapes(self, arch):
        b = get_smoke_bundle(arch)
        cfg = b.cfg
        params = b.init_params(jax.random.PRNGKey(1), "float32")
        B, S = 2, 32
        enc_dec = cfg.family == "audio" and cfg.n_encoder_layers
        batch = _batch_for(cfg, B, S, with_labels=False)
        cache = b.init_cache(B, max_len=S + 8)
        logits, cache = b.prefill(params, batch, cache)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), arch
        text_len = S if enc_dec else S - cfg.frontend_tokens
        lengths = jnp.full((B,), text_len, jnp.int32)
        tok = jnp.argmax(logits, -1)[:, None]
        logits2, cache = b.decode_step(
            params, {"tokens": tok, "lengths": lengths}, cache
        )
        assert logits2.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits2)).all(), arch

    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_full_config_consistency(self, arch):
        """The FULL configs are never materialized on CPU, but their param
        math must be coherent: defs exist, counts match the analytic
        formula, stages cover all layers in order."""
        cfg = get_config(arch)
        codes = cfg.layer_codes()
        assert len(codes) == cfg.n_layers
        rebuilt = "".join(c * n for c, n, _ in cfg.stages())
        assert rebuilt == codes
        assert cfg.num_params() > 0
        assert cfg.active_params() <= cfg.num_params() + 1e-9


class TestCacheConsistency:
    """prefill-then-decode == full forward at the next position."""

    @pytest.mark.parametrize("arch", ["granite-8b", "mamba2-780m", "yi-6b"])
    def test_decode_matches_forward(self, arch):
        b = get_smoke_bundle(arch)
        cfg = b.cfg
        params = b.init_params(jax.random.PRNGKey(1), "float32")
        B, S = 2, 16
        toks = jax.random.randint(jax.random.PRNGKey(9), (B, S + 1), 0, cfg.vocab)
        # full forward over S+1 tokens: logits at position S
        from repro.models.transformer import lm_forward

        logits_full, _ = lm_forward(params, toks, cfg)
        want = logits_full[:, S]
        # prefill S tokens then decode token S
        # f32 cache: the consistency check tests LOGIC; the default bf16
        # cache adds ~1e-2 quantization noise (covered by smoke tests).
        cache = b.init_cache(B, max_len=S + 8, dtype="float32")
        _, cache = b.prefill(params, {"tokens": toks[:, :S]}, cache)
        got, _ = b.decode_step(
            params,
            {"tokens": toks[:, S:S + 1],
             "lengths": jnp.full((B,), S, jnp.int32)},
            cache,
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3
        )


class TestMoE:
    def _setup(self, top_k=2, E=8, cf=2.0):
        from repro.configs import MoESpec

        spec = MoESpec(n_experts=E, top_k=top_k, d_ff_expert=16,
                       capacity_factor=cf)
        params = materialize(moe_defs(32, spec), jax.random.PRNGKey(0), "float32")
        return spec, params

    def test_output_finite_and_shaped(self):
        spec, params = self._setup()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
        out, aux = apply_moe(params, x, spec, group_size=64)
        assert out.shape == x.shape
        assert bool(jnp.isfinite(out).all()) and aux > 0

    def test_capacity_bounds(self):
        from repro.configs import MoESpec

        spec = MoESpec(n_experts=8, top_k=2, d_ff_expert=4)
        c = capacity(256, spec)
        assert c >= spec.top_k and c % 4 == 0

    def test_combine_weights_convex(self):
        """Each token's total combine weight is in [0, 1]: 1 when every
        choice landed in capacity, less when dropped."""
        spec, params = self._setup(cf=0.25)  # force drops
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 32))
        out, _ = apply_moe(params, x, spec, group_size=128)
        assert bool(jnp.isfinite(out).all())

    def test_moe_period(self):
        cfg = get_config("llama4-maverick-400b-a17b")
        moe_layers = [
            i for i in range(cfg.n_layers) if cfg.moe.is_moe_layer(i)
        ]
        assert len(moe_layers) == cfg.n_layers // 2
        assert all(i % 2 == 1 for i in moe_layers)

    def test_deepseek_first_dense(self):
        cfg = get_config("deepseek-v2-236b")
        assert not cfg.moe.is_moe_layer(0)
        assert cfg.moe.is_moe_layer(1)


class TestShapeRegistry:
    def test_all_cells_defined(self):
        assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
        n_cells = 0
        for arch in ALL_ARCHS:
            for shape in SHAPES:
                ok, why = shape_applicable(arch, shape)
                if ok:
                    n_cells += 1
                else:
                    assert shape == "long_500k" and why
        assert n_cells == 34  # 40 - 6 documented long_500k skips

    def test_long500k_runs_for_subquadratic(self):
        for arch in ["mamba2-780m", "zamba2-1.2b", "gemma3-27b",
                     "llama4-maverick-400b-a17b"]:
            ok, _ = shape_applicable(arch, "long_500k")
            assert ok, arch
