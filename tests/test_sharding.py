"""Sharding rules: logical-axis resolution, divisibility drop, FSDP extend,
and hypothesis properties of spec_for."""

import hypothesis.strategies as st
import jax
import pytest
from hypothesis import given, settings
from jax.sharding import PartitionSpec as P

from repro.models.sharding import (
    DEFAULT_RULES,
    Param,
    defs_to_shapes,
    fsdp_extend,
    spec_for,
    stack_defs,
    use_sharding,
)


@pytest.fixture(scope="module")
def mesh():
    # 1-device "mesh" cannot exercise divisibility; build a fake 4x4 mesh
    # shape via an abstract mesh over the single device replicated? Not
    # possible — use jax.sharding.AbstractMesh which needs no devices.
    return jax.sharding.AbstractMesh((4, 4), ("data", "model"))


class TestSpecFor:
    def test_tp_rules(self, mesh):
        spec = spec_for((64, 32, 128), ("embed", "heads", None), mesh)
        assert spec == P(None, "model")

    def test_divisibility_drop(self, mesh):
        # 6 heads don't divide the 4-way model axis -> dropped (Megatron
        # KV replication emerges)
        spec = spec_for((64, 6, 128), ("embed", "kv_heads", None), mesh)
        assert spec == P()

    def test_axis_used_once(self, mesh):
        spec = spec_for((32, 64), ("heads", "d_ff"), mesh)
        assert spec == P("model")  # d_ff dropped, model taken by heads

    def test_batch_multi_axis(self):
        mesh3 = jax.sharding.AbstractMesh((2, 4, 4), ("pod", "data", "model"))
        spec = spec_for((64, 128), ("batch", None), mesh3)
        assert spec == P(("pod", "data"))

    def test_missing_axis_skipped(self, mesh):
        # 'pod' not in this mesh: silently skipped
        spec = spec_for((64,), ("batch",), mesh)
        assert spec == P("data")

    def test_rules_overlay_keeps_defaults(self, mesh):
        # passing only an override must NOT erase the TP rules
        spec = spec_for(
            (8, 64, 32), ("batch", "seq", "d_ff"), mesh, {"seq": ("model",)}
        )
        assert spec == P("data", "model")

    @given(
        st.lists(
            st.sampled_from([4, 8, 12, 16, 64, 6, 10]), min_size=1, max_size=4
        )
    )
    @settings(max_examples=50, deadline=None)
    def test_always_divisible(self, dims):
        mesh = jax.sharding.AbstractMesh((4, 4), ("data", "model"))
        axes = ["heads", "d_ff", "batch", "vocab"][: len(dims)]
        spec = spec_for(tuple(dims), tuple(axes), mesh)
        msizes = {"data": 4, "model": 4}
        for dim, entry in zip(dims, tuple(spec)):
            if entry is None:
                continue
            parts = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for p_ in parts:
                total *= msizes[p_]
            assert dim % total == 0


class TestFsdp:
    def test_prefers_non_layer_dim(self, mesh):
        spec = fsdp_extend(
            P(None, "model"), (32, 160, 64, 128), mesh, ("data",),
            ("layers", "experts", "embed", "d_ff"),
        )
        assert spec == P(None, "model", "data")

    def test_falls_back_to_layer_dim(self, mesh):
        spec = fsdp_extend(
            P(), (32, 5, 3), mesh, ("data",), ("layers", None, None)
        )
        assert spec == P("data")

    def test_no_dim_fits(self, mesh):
        spec = fsdp_extend(P(), (5, 3), mesh, ("data",), (None, None))
        assert spec == P()

    def test_already_used(self, mesh):
        spec = fsdp_extend(P("data"), (4, 8), mesh, ("data",), (None, None))
        assert spec == P("data")


class TestParamDefs:
    def test_stack_defs(self):
        defs = {"w": Param((4, 8), ("embed", "d_ff"))}
        stacked = stack_defs(defs, 3)
        assert stacked["w"].shape == (3, 4, 8)
        assert stacked["w"].axes == ("layers", "embed", "d_ff")

    def test_defs_to_shapes_dtypes(self):
        defs = {
            "w": Param((4,), (None,)),
            "t": Param((2,), (None,), dtype="int32"),
        }
        shapes = defs_to_shapes(defs, "bfloat16")
        assert shapes["w"].dtype == jax.numpy.bfloat16
        assert shapes["t"].dtype == jax.numpy.int32

    def test_shard_noop_without_mesh(self):
        from repro.models.sharding import shard

        x = jax.numpy.ones((4, 4))
        assert shard(x, "batch", None) is x
