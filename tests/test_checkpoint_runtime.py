"""Fault-tolerance substrate: checkpoint atomicity/roundtrip, supervisor
restart-on-failure, straggler detection, elastic reshard, data pipeline."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.runtime import (
    StepTimeMonitor,
    StragglerConfig,
    Supervisor,
    SupervisorConfig,
)


def _tree():
    return {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.int32)},
        "scalar": jnp.float32(3.5),
    }


class TestCheckpointer:
    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        t = _tree()
        ck.save(7, t, extra={"foo": 1}, blocking=True)
        restored, manifest = ck.restore(jax.tree.map(jnp.zeros_like, t))
        assert manifest["step"] == 7 and manifest["extra"]["foo"] == 1
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save_then_wait(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree(), blocking=False)
        ck.wait()
        assert ck.latest_step() == 1

    def test_atomicity_tmp_never_visible(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(3, _tree(), blocking=True)
        names = os.listdir(tmp_path)
        assert not any(n.endswith(".tmp") for n in names)
        assert ck.all_steps() == [3]

    def test_gc_keeps_last_k(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, _tree(), blocking=True)
        assert ck.all_steps() == [3, 4]

    def test_elastic_restore_new_sharding(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        t = {"w": jnp.arange(16.0).reshape(4, 4)}
        ck.save(1, t, blocking=True)
        dev = jax.devices()[0]
        shardings = {"w": jax.sharding.SingleDeviceSharding(dev)}
        restored, _ = ck.restore(t, shardings=shardings)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))


class TestSupervisor:
    def test_restart_on_failure(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        sup = Supervisor(ck, SupervisorConfig(checkpoint_every=2, max_restarts=2))
        calls = {"n": 0}

        def step_fn(state, batch):
            calls["n"] += 1
            if calls["n"] == 5:  # simulated node failure after ckpt at 4
                raise RuntimeError("node lost")
            return {"x": state["x"] + 1}, {}

        data = iter([{} for _ in range(50)])
        state, step = sup.run({"x": jnp.float32(0)}, step_fn, data, n_steps=8)
        assert step == 8
        assert sup.restarts == 1
        # state resumed from step-4 checkpoint: exactly 8 net increments
        assert float(state["x"]) == 8.0

    def test_exceeding_restarts_raises(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        sup = Supervisor(ck, SupervisorConfig(checkpoint_every=1, max_restarts=1))

        def step_fn(state, batch):
            raise RuntimeError("always fails")

        with pytest.raises(RuntimeError):
            sup.run({"x": jnp.float32(0)}, step_fn, iter([{}] * 10), n_steps=3)


class TestStraggler:
    def test_detection_with_fake_clock(self):
        fired = []
        mon = StepTimeMonitor(
            StragglerConfig(window=20, threshold=2.0, patience=2,
                            warmup_steps=0),
            on_straggler=fired.append,
        )
        for _ in range(10):
            mon.record(0.1)
        assert not mon.flags
        mon.record(0.5)   # 5x median -> flag 1
        mon.record(0.5)   # flag 2 -> patience reached
        assert len(mon.flags) == 2
        assert fired and fired[0]["ratio"] > 2
        s = mon.summary()
        assert s["flags"] == 2 and s["median_s"] == pytest.approx(0.1)

    def test_warmup_ignored(self):
        mon = StepTimeMonitor(StragglerConfig(warmup_steps=3))
        assert mon.record(100.0) is False  # compile step ignored

    def test_flagged_steps_stay_out_of_the_median_window(self):
        """Regression: flagged step times used to be appended to the
        rolling window, inflating the median until a persistent straggler
        stopped exceeding threshold*median and went unflagged."""
        import statistics

        mon = StepTimeMonitor(
            StragglerConfig(window=20, threshold=2.0, patience=100,
                            warmup_steps=0)
        )
        for _ in range(10):
            mon.record(0.1)
        # a long run of stragglers: every one must keep being flagged
        # against the *clean* 0.1 median
        for _ in range(15):
            assert mon.record(0.5) is True
        assert len(mon.flags) == 15
        assert all(f["median"] == pytest.approx(0.1) for f in mon.flags)
        assert 0.5 not in mon.times
        assert statistics.median(mon.times) == pytest.approx(0.1)
        s = mon.summary()
        assert s["flags"] == 15
        assert s["median_s"] == pytest.approx(0.1)


class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(vocab=97, seq_len=16, global_batch=4, seed=5)
        a = next(iter(SyntheticLM(cfg)))
        b = next(iter(SyntheticLM(cfg)))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_shifted(self):
        cfg = DataConfig(vocab=97, seq_len=16, global_batch=2, structure=1.0)
        batch = next(iter(SyntheticLM(cfg)))
        a, c = (
            6364136223846793005 % 97,
            1442695040888963407 % 97,
        )
        want = (a * batch["tokens"][:, :1].astype(np.int64) + c) % 97
        np.testing.assert_array_equal(batch["labels"][:, 0], want[:, 0])

    def test_process_shards_disjoint(self):
        cfg = DataConfig(vocab=97, seq_len=8, global_batch=4)
        full = next(iter(SyntheticLM(cfg)))
        p0 = next(iter(SyntheticLM(cfg, process_index=0, process_count=2)))
        p1 = next(iter(SyntheticLM(cfg, process_index=1, process_count=2)))
        np.testing.assert_array_equal(
            np.concatenate([p0["tokens"], p1["tokens"]]), full["tokens"]
        )

    def test_state_restore(self):
        cfg = DataConfig(vocab=97, seq_len=8, global_batch=2)
        it = SyntheticLM(cfg)
        next(it); next(it)
        state = it.state()
        third = next(it)
        it2 = SyntheticLM(cfg)
        it2.restore(state)
        np.testing.assert_array_equal(next(it2)["tokens"], third["tokens"])

    def test_prefetcher(self):
        cfg = DataConfig(vocab=17, seq_len=4, global_batch=2)
        src = SyntheticLM(cfg)
        pre = Prefetcher(src, depth=2)
        direct = SyntheticLM(cfg)
        for _ in range(3):
            np.testing.assert_array_equal(
                next(pre)["tokens"], next(direct)["tokens"]
            )
        pre.close()
        assert not pre._thread.is_alive()

    def test_prefetcher_close_under_backpressure_joins_worker(self):
        """Regression: close() drained the queue once but never joined, so
        a producer blocked in q.put repopulated the queue and leaked the
        thread."""
        def endless():
            i = 0
            while True:
                yield i
                i += 1

        pre = Prefetcher(endless(), depth=1)
        assert next(pre) == 0
        time.sleep(0.1)   # let the producer block on the full queue
        pre.close()
        assert not pre._thread.is_alive()
        # idempotent: a second close on a dead worker is a no-op
        pre.close()

    def test_prefetcher_close_reraises_producer_error(self):
        """Regression: the error sentinel could be swallowed by close()'s
        drain; the producer's exception must surface."""
        def broken():
            yield {"x": 1}
            raise RuntimeError("producer exploded")

        pre = Prefetcher(broken(), depth=1)
        assert next(pre) == {"x": 1}
        time.sleep(0.1)   # let the producer hit the exception
        with pytest.raises(RuntimeError, match="producer exploded"):
            pre.close()

    def test_prefetcher_error_surfaces_on_next_too(self):
        def broken():
            raise RuntimeError("early boom")
            yield  # pragma: no cover

        pre = Prefetcher(broken(), depth=1)
        with pytest.raises(RuntimeError, match="early boom"):
            next(pre)
