"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes and dtypes per the task contract; every kernel asserts
allclose against ref.py, and the chunked/jnp variants are cross-checked
against brute-force semantics (sequential scan for SSD, full-matrix
attention for the chunked evaluator).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.blocked_matmul import best_tiling, blocked_matmul, traffic_model
from repro.kernels.flash_attention import vmem_footprint_bytes

jax.config.update("jax_platform_name", "cpu")


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(
        atol=3e-5, rtol=1e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D",
    [(1, 4, 4, 128, 32), (2, 8, 2, 256, 64), (1, 8, 1, 512, 64)],
)
@pytest.mark.parametrize(
    "kind,kw",
    [
        ("causal", {}),
        ("sliding", {"window": 64}),
        ("chunked", {"chunk": 128}),
        ("bidirectional", {}),
    ],
)
def test_flash_attention_matches_ref(B, Hq, Hkv, S, D, kind, kw, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    out = ops.attention(q, k, v, kind=kind, backend="pallas", **kw)
    want = ops.attention(q, k, v, kind=kind, backend="ref", **kw)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,Smax,D", [(2, 4, 2, 256, 32), (3, 8, 8, 512, 64)])
def test_flash_decode_matches_ref(B, Hq, Hkv, Smax, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (B, Hkv, Smax, D), dtype)
    vc = jax.random.normal(ks[2], (B, Hkv, Smax, D), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, Smax + 1, size=B), jnp.int32
    )
    out = ops.decode_attention(q, kc, vc, lengths, backend="pallas")
    want = ops.decode_attention(q, kc, vc, lengths, backend="ref")
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_chunked_attention_matches_full():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, H, S, D = 2, 4, 4096, 32
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    for kind, kw in [("causal", {}), ("sliding", {"window": 512})]:
        full = ref.attention(q, k, v, kind=kind, **kw)
        chunked = ref.attention_chunked(q, k, v, kind=kind, block_q=512, **kw)
        np.testing.assert_allclose(chunked, full, atol=3e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (1, 128, 2, 16, 8, 32), (2, 256, 4, 32, 16, 64), (1, 64, 1, 64, 32, 64),
])
def test_ssd_scan_pallas_and_chunked_vs_sequential(B, T, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = (jax.random.normal(ks[0], (B, T, H, P)) * 0.5).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, T, H))) * 0.1)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = (jax.random.normal(ks[3], (B, T, N)) * 0.5)
    Cm = (jax.random.normal(ks[4], (B, T, N)) * 0.5)
    want = ref.ssd_scan_sequential(x, dt, A, Bm, Cm)
    chk = ref.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    pls = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, backend="pallas")
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-4, rtol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(chk, np.float32), np.asarray(want, np.float32), **tol
    )
    np.testing.assert_allclose(
        np.asarray(pls, np.float32), np.asarray(want, np.float32), **tol
    )


def test_ssd_prefill_state_matches_decode_continuation():
    """State handoff: scan T tokens, then decode-step one more ==
    scanning T+1 tokens."""
    B, T, H, P, N = 1, 64, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, T + 1, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T + 1, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T + 1, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, T + 1, N)) * 0.5
    y_full = ref.ssd_scan_sequential(x, dt, A, Bm, Cm)
    _, state = ref.ssd_scan(
        x[:, :T], dt[:, :T], A, Bm[:, :T], Cm[:, :T],
        chunk=32, return_state=True,
    )
    y_last, _ = ref.ssd_decode_step(
        x[:, T], dt[:, T], A, Bm[:, T], Cm[:, T], state
    )
    np.testing.assert_allclose(y_last, y_full[:, T], atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,N,K,bm,bn,bk", [
    (256, 128, 512, 128, 128, 128),
    (128, 128, 128, 128, 128, 128),
    (512, 256, 256, 256, 128, 256),
])
def test_blocked_matmul(M, N, K, bm, bn, bk, dtype):
    a = jax.random.normal(jax.random.PRNGKey(5), (M, K), dtype)
    b = jax.random.normal(jax.random.PRNGKey(6), (K, N), dtype)
    out = blocked_matmul(a, b, bm=bm, bn=bn, bk=bk, out_dtype=jnp.float32)
    want = jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32)
    )
    tol = dict(atol=1.5, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=1e-3, rtol=1e-5
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **tol)


def test_pallas_attention_grads_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, H, S, D = 1, 4, 128, 32
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))

    def loss(backend):
        return lambda q, k, v: jnp.sum(
            ops.attention(q, k, v, kind="causal", backend=backend) ** 2
        )

    g_p = jax.grad(loss("pallas"), argnums=(0, 1, 2))(q, k, v)
    g_r = jax.grad(loss("ref"), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_p, g_r):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_matmul_traffic_model_and_tiling():
    t = traffic_model(1024, 1024, 1024, 256, 256, 256)
    # each A byte read N/bn=4 times etc.
    assert t["hbm_bytes"] == (1024 * 1024 * 4 * 2 + 1024 * 1024) * 2
    bm, bn, bk = best_tiling(4096, 4096, 4096)
    assert 4096 % bm == 0 and 4096 % bn == 0 and 4096 % bk == 0
    big = traffic_model(4096, 4096, 4096, bm, bn, bk)
    small = traffic_model(4096, 4096, 4096, 128, 128, 128)
    assert big["arithmetic_intensity"] >= small["arithmetic_intensity"]
    assert vmem_footprint_bytes(128, 128, 64) < 16 * 2**20
