"""Roofline engine: term arithmetic, link attribution, report round-trip."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_analysis import CollectiveStat, HloCost
from repro.core.roofline import (
    RooflineReport,
    load_reports,
    markdown_table,
    report_from_compiled,
    report_from_cost,
    save_reports,
)


def _cost():
    c = HloCost(flops=197e12, hbm_bytes=819e9)  # exactly 1 s each
    c.collectives = [
        CollectiveStat("all-reduce", 1e9, 50e9, 16, ("model",), 1.0),
        CollectiveStat("all-reduce", 1e9, 25e9, 2, ("pod",), 1.0),
    ]
    return c


class TestTerms:
    def test_term_seconds(self):
        r = report_from_cost(
            _cost(), arch="a", shape="s", mesh_name="m", num_chips=256,
            model_flops=197e12 * 256,
        )
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(1.0)
        # 50 GB over ICI (50 GB/s) + 25 GB over DCN (25 GB/s) = 2 s
        assert r.collective_s == pytest.approx(2.0)
        assert r.dominant == "collective"
        assert r.useful_ratio == pytest.approx(1.0)
        # ideal 1 s of useful compute over a 2 s bound
        assert r.roofline_fraction == pytest.approx(0.5)

    def test_link_attribution(self):
        r = report_from_cost(
            _cost(), arch="a", shape="s", mesh_name="m", num_chips=256,
            model_flops=1.0,
        )
        assert r.collective_by_link["ici"] == pytest.approx(50e9)
        assert r.collective_by_link["dcn"] == pytest.approx(25e9)

    def test_bw_fraction(self):
        r = report_from_cost(
            HloCost(flops=1.0, hbm_bytes=819e9),
            arch="a", shape="s", mesh_name="m", num_chips=1,
            model_flops=1.0, model_bytes=819e9 / 2,
        )
        assert r.bw_fraction == pytest.approx(0.5)


class TestRoundTrip:
    def test_save_load_markdown(self, tmp_path):
        r = report_from_cost(
            _cost(), arch="a", shape="s", mesh_name="m", num_chips=4,
            model_flops=1e12,
        )
        p = str(tmp_path / "r.json")
        save_reports([r], p)
        (r2,) = load_reports(p)
        assert r2 == r
        table = markdown_table([r])
        assert "| a | s | m |" in table


class TestFromCompiled:
    def test_matmul_report(self):
        D = 128

        def f(a, b):
            return jnp.dot(a, b)

        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        compiled = jax.jit(f).lower(x, x).compile()
        r = report_from_compiled(
            compiled, arch="mm", shape="t", mesh_name="1",
            mesh_axes={"data": 1}, model_flops=2.0 * D**3,
        )
        assert r.useful_ratio == pytest.approx(1.0, rel=0.01)
        assert r.dominant == "memory"   # tiny matmul is bandwidth-bound
        assert r.collective_s == 0.0
