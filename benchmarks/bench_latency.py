"""Paper Figs. 11/12: memory access latency via pointer chase.

Measured: a dependent-gather chain (each load's address depends on the
previous load) over growing buffers — the multichase methodology; cache-
tier breaks show up as latency steps on real hardware.  Analytic: per-tier
TPU latencies from the hardware model."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import MemoryTier, get_active_system, read_bound
from repro.core.membench import measure

SIZES = [2**12, 2**16, 2**20, 2**23]   # elements (x4 bytes)
CHAIN = 2048                            # dependent loads per call


def _chase(perm: jax.Array) -> jax.Array:
    def body(i, idx):
        return perm[idx]

    return jax.lax.fori_loop(0, CHAIN, body, jnp.int32(0))


def main() -> None:
    chase = jax.jit(_chase)
    rng = np.random.default_rng(0)
    for n in SIZES:
        # random cyclic permutation -> defeats prefetch, like multichase
        perm = np.empty(n, np.int32)
        order = rng.permutation(n)
        perm[order[:-1]] = order[1:]
        perm[order[-1]] = order[0]
        x = jnp.asarray(perm)
        m = measure(lambda x=x: chase(x), name=f"chase[{n*4}B]", repeats=5)
        emit(m.name, m.us_per_call, f"{m.mean_s/CHAIN*1e9:.1f}ns/load")

    for t in MemoryTier:
        b = read_bound(t) if t != MemoryTier.VMEM else None
        lat = (
            get_active_system().chip.vmem_latency
            if t == MemoryTier.VMEM
            else b.latency
        )
        emit(f"analytic_latency[{t}]", lat * 1e6, f"{lat*1e9:.0f}ns")


if __name__ == "__main__":
    main()
