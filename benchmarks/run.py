"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--only NAME]`` — each module prints
``name,us_per_call,derived`` CSV rows.  Mapping to the paper (also in
DESIGN.md §6):

  bench_datapath_bounds   Fig. 3 + Table II (+ hardware constants)
  bench_membw             Figs. 2, 7, 8
  bench_copy              Figs. 5, 9, 10
  bench_latency           Figs. 11, 12
  bench_pingpong          Fig. 13
  bench_internode         Fig. 14
  bench_gemm              Figs. 15, 16 + Table III
  bench_llm_inference     Fig. 17
  bench_collectives       Figs. 18, 19
  bench_managed_vs_system Fig. 4
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

MODULES = [
    "bench_datapath_bounds",
    "bench_membw",
    "bench_copy",
    "bench_latency",
    "bench_pingpong",
    "bench_internode",
    "bench_gemm",
    "bench_llm_inference",
    "bench_collectives",
    "bench_managed_vs_system",
]

#: modules centered on the datapath model — the CI smoke-check mode.
#: ``--analytic`` runs exactly these.  bench_datapath_bounds is pure
#: analysis on one device; when >= 2 devices are visible it additionally
#: times the measured donor column (peer/remote gather + stream).
ANALYTIC_MODULES = [
    "bench_datapath_bounds",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single module")
    ap.add_argument(
        "--analytic", action="store_true",
        help="datapath-model smoke modules only (adds the measured donor "
             "column when >= 2 devices are visible)",
    )
    ap.add_argument(
        "--calibration", default=None, metavar="PATH",
        help="activate a measurement-calibrated hardware model from this "
             "calibration.json (created by tools/calibrate.py) so every "
             "analytic row reports both spec and calibrated bounds; "
             "defaults to ./calibration.json when that file exists",
    )
    args = ap.parse_args()

    cal_path = args.calibration
    if cal_path is None and os.path.exists("calibration.json"):
        cal_path = "calibration.json"
    if cal_path is not None:
        from repro.core.calibration import Calibration
        from repro.core.hardware import set_active_system

        cal = Calibration.load(cal_path)
        set_active_system(cal.apply())
        print(f"# calibration: {cal_path} (backend={cal.backend}, "
              f"{len(cal.terms)} measured terms)")

    if args.only:
        mods = [args.only]
    elif args.analytic:
        mods = ANALYTIC_MODULES
    else:
        mods = MODULES
    failures = 0
    for name in mods:
        print(f"# ==== {name} ====")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0.00,FAILED")
        print(f"# {name} done in {time.time()-t0:.1f}s")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
