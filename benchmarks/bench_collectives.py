"""Paper Figs. 18/19: collective scaling (all-reduce / all-gather) by
buffer size and by axis locality.

The paper's conclusion — Superchip locality matters more than memory type —
maps to axis choice: the same collective over the 'model' (ICI) vs 'pod'
(DCN) axis.  Measured: psum/all_gather over an 8-device host mesh in a
subprocess.  Analytic: algorithmic-bandwidth scaling per axis."""

from __future__ import annotations

from benchmarks.common import emit, run_with_devices
from repro.core import collective_bound
from repro.core.hardware import Link

CODE = """
import jax, jax.numpy as jnp, time
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("pod", "model"))
for op in ("psum", "all_gather"):
    for axis in ("model", "pod"):
        for log2 in (16, 22):
            n = 2 ** log2 // 4
            x = jnp.ones((n,), jnp.float32)
            if op == "psum":
                body = lambda v: jax.lax.psum(v, axis)
            else:
                body = lambda v: jax.lax.all_gather(v, axis)
            f = jax.jit(shard_map(body, mesh=mesh, in_specs=P(None),
                                  out_specs=P(None) if op == "psum"
                                  else P(None), check_rep=False))
            out = f(x); jax.block_until_ready(out)
            reps = 10
            t0 = time.perf_counter()
            for _ in range(reps):
                out = f(x)
            jax.block_until_ready(out)
            dt = (time.perf_counter() - t0) / reps
            print(f"measured_{op}[{axis},{n*4}B],{dt*1e6:.2f},"
                  f"{n*4/dt/1e9:.2f}GB/s")
"""


def main() -> None:
    print(run_with_devices(CODE).strip())
    # analytic: per-chip algorithmic bandwidth, ICI vs DCN axes
    for kind in ("all_reduce", "all_gather"):
        for axis, link, size in (
            ("model", Link.ICI, 16),
            ("data", Link.ICI, 16),
            ("pod", Link.DCN, 2),
        ):
            bw = collective_bound(size, link, kind)
            for nbytes in (2**20, 2**26, 2**32):
                t = nbytes / bw
                emit(
                    f"analytic_{kind}[{axis},{nbytes}B]",
                    t * 1e6,
                    f"{nbytes/t/1e9:.1f}GB/s algo-bw",
                )


if __name__ == "__main__":
    main()
