"""Paper Figs. 5/9/10: copy throughput across (source, destination)
placement pairs — the ``cudaMemcpy`` matrix as ``device_put`` between
memory kinds, plus the analytic TPU matrix with its asymmetry notes."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import SingleDeviceSharding

from benchmarks.common import emit
from repro.core import MemoryTier, copy_bound
from repro.core.membench import measure

SIZES = [2**22, 2**26]  # 4 MiB, 64 MiB


def main() -> None:
    dev = jax.devices()[0]
    kinds = ["device"]
    if "pinned_host" in {m.kind for m in dev.addressable_memories()}:
        kinds.append("pinned_host")

    # plain device_put transfers (outside jit: the CPU backend has no
    # in-jit host-placement runtime; device_put is exactly cudaMemcpy here)
    for src in kinds:
        for dst in kinds:
            dst_sharding = SingleDeviceSharding(dev, memory_kind=dst)
            for nbytes in SIZES:
                x = jax.device_put(
                    jnp.ones((nbytes // 4,), jnp.float32),
                    SingleDeviceSharding(dev, memory_kind=src),
                )
                m = measure(
                    lambda x=x, s_=dst_sharding: jax.device_put(x, s_),
                    name=f"copy[{src}->{dst},{nbytes}]",
                    nbytes=nbytes,
                )
                emit(m.name, m.us_per_call, f"{m.gbps:.2f}GB/s")

    # analytic TPU copy matrix (Fig. 5/9 bound rows)
    tiers = [t for t in MemoryTier if t != MemoryTier.VMEM]
    for src in tiers:
        for dst in tiers:
            b = copy_bound(src, dst)
            emit(
                f"analytic_copy[{src}->{dst}]",
                b.latency * 1e6,
                f"{b.bandwidth/1e9:.1f}GB/s via {b.limiting_link}",
            )


if __name__ == "__main__":
    main()
