"""Paper Figs. 15/16 + Table III: GEMM throughput vs dtype and operand
placement.

Measured: jnp.dot and the Pallas blocked matmul (interpret) on CPU-sized
matrices — validates the harness and the tiling sweep.  Analytic: the TPU
datapath verdict for the paper's experiment — per dtype (Table III) and
per operand placement (A/B resident in HBM vs streamed from host/peer),
reporting compute-vs-movement bound exactly like Fig. 15's colour map.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import MemoryTier, get_active_system, read_bound
from repro.core.membench import measure
from repro.kernels.blocked_matmul import best_tiling, blocked_matmul, traffic_model


def measured() -> None:
    N = 512
    for dtype in (jnp.float32, jnp.bfloat16):
        a = jax.random.normal(jax.random.PRNGKey(0), (N, N), dtype)
        b = jax.random.normal(jax.random.PRNGKey(1), (N, N), dtype)
        f = jax.jit(lambda a, b: jnp.dot(a, b))
        m = measure(
            lambda: f(a, b), name=f"xla_gemm[{N},{dtype.__name__}]",
            flops=2 * N**3, repeats=5,
        )
        emit(m.name, m.us_per_call, f"{m.tflops:.3f}TF/s")

    # Pallas tiling sweep (interpret mode: correctness + traffic model)
    for bm, bn, bk in ((128, 128, 128), (256, 256, 256)):
        a = jax.random.normal(jax.random.PRNGKey(0), (512, 512), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (512, 512), jnp.float32)
        m = measure(
            lambda: blocked_matmul(a, b, bm=bm, bn=bn, bk=bk),
            name=f"pallas_gemm[512,bm{bm}]", flops=2 * 512**3, repeats=2,
        )
        t = traffic_model(512, 512, 512, bm, bn, bk, 4)
        emit(m.name, m.us_per_call,
             f"AI={t['arithmetic_intensity']:.1f}flops/B")


def analytic() -> None:
    c = get_active_system().chip
    N = 16384  # paper uses 4 GB square matrices; bf16 16k^2 = 512 MB each
    flops = 2.0 * N**3

    # Table III analogue: dtype sweep, HBM-resident
    for dtype, peak in c.peak_flops_by_dtype.items():
        itemsize = {"bfloat16": 2, "float32": 4, "int8": 1}[dtype]
        t = traffic_model(N, N, N, *best_tiling(N, N, N), itemsize=itemsize)
        t_mem = t["hbm_bytes"] / c.hbm_bandwidth
        t_cmp = flops / peak
        bound = "compute" if t_cmp > t_mem else "memory"
        emit(
            f"analytic_gemm[hbm,{dtype}]",
            max(t_cmp, t_mem) * 1e6,
            f"{flops/max(t_cmp,t_mem)/1e12:.1f}TF/s {bound}-bound",
        )

    # Fig. 15 analogue: operand placement sweep at bf16.  Reads dominate
    # (the paper's key asymmetry): destination placement never appears in
    # the bound because C is written once but A/B stream repeatedly.
    bm, bn, bk = best_tiling(N, N, N)
    reuse_a = N // bn   # times each A byte is re-read
    reuse_b = N // bm
    for pa in (MemoryTier.HBM, MemoryTier.HOST, MemoryTier.PEER_HBM):
        for pb in (MemoryTier.HBM, MemoryTier.HOST, MemoryTier.PEER_HBM):
            nbytes = N * N * 2
            t_a = nbytes * reuse_a / read_bound(pa).bandwidth
            t_b = nbytes * reuse_b / read_bound(pb).bandwidth
            t_cmp = flops / c.peak_bf16_flops
            t_total = max(t_cmp, t_a + t_b)
            bound = "compute" if t_cmp >= t_a + t_b else "memory"
            emit(
                f"analytic_gemm[A={pa},B={pb}]",
                t_total * 1e6,
                f"{flops/t_total/1e12:.1f}TF/s {bound}-bound",
            )


def main() -> None:
    measured()
    analytic()


if __name__ == "__main__":
    main()
